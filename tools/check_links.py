#!/usr/bin/env python
"""Markdown link checker for README + docs/ (no network, no dependencies).

Checks every ``[text](target)`` in the given markdown files/directories:

  * relative file targets must exist (relative to the file containing the
    link), including the file part of ``path#anchor`` targets;
  * ``#anchor`` / ``path#anchor`` targets must match a heading in the
    target file (GitHub-style slugs);
  * ``http(s)://`` targets are reported but not fetched (offline CI).

Exit status 1 if any link is broken.  Usage::

    python tools/check_links.py README.md docs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces to dashes."""
    text = re.sub(r"[`*_\[\]()]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return re.sub(r" ", "-", text)


def anchors_of(md_path: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {github_slug(h) for h in HEADING_RE.findall(text)}


def iter_md_files(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            raise SystemExit(f"not a markdown file or directory: {a}")
    return files


def check_file(md: Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue  # offline: existence not checkable, format accepted
        path_part, _, anchor = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{md}: missing anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = iter_md_files(argv or ["README.md", "docs"])
    errors: list[str] = []
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
