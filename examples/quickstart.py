"""Quickstart: GraphMP in ~40 lines.

Generates a power-law graph, preprocesses it into destination-interval ELL
shards on disk (the paper's 3-step pipeline), then runs PageRank with the
VSW engine — all vertices resident, edges streamed through the compressed
cache, inactive shards Bloom-skipped.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.core import apps
from repro.core.engine import VSWEngine
from repro.graph.generate import rmat_edges, materialize
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list


def main():
    with tempfile.TemporaryDirectory() as td:
        print("1) generate a 2^14-vertex, ~260k-edge RMAT graph")
        src, dst = materialize(rmat_edges(scale=14, edge_factor=16, seed=0))
        write_edge_list(f"{td}/edges", [(src, dst)])

        print("2) preprocess: degree scan -> Algorithm-1 intervals -> ELL shards")
        store = preprocess_graph(f"{td}/edges", f"{td}/graph",
                                 threshold_edge_num=1 << 15)
        print(f"   {store.num_shards} shards, {store.num_edges} edges, "
              f"{store.num_vertices} vertices")

        print("3) PageRank under VSW (compressed cache, selective scheduling)")
        engine = VSWEngine(store, apps.pagerank(), cache_mode="auto",
                           cache_budget_bytes=1 << 28)
        result = engine.run(max_iters=30)
        top = np.argsort(result.values)[-5:][::-1]
        print(f"   {result.iterations} iterations, "
              f"{result.total_seconds:.2f}s total")
        print(f"   cache hit ratio {engine.cache.stats.hit_ratio:.2f}, "
              f"disk bytes {engine.cache.stats.disk_bytes/1e6:.1f}MB")
        print(f"   top-5 vertices by rank: {top.tolist()}")


if __name__ == "__main__":
    main()
