"""Quickstart: GraphMP in five lines of API.

    generate -> preprocess -> GraphSession -> session.run(...) -> stats

A ``GraphSession`` is the unified entry point: it owns the on-disk shard
store, ONE compressed edge cache shared by every application, and the
device-resident vertex arrays — so running PageRank, then SSSP, then CC
pays the disk read once (the paper's "preprocess once, serve many
applications" economics, §2.2/§2.4.2).  Under the hood each run is the VSW
engine: all vertices device-resident, edges streamed shard-by-shard through
the cache, inactive shards Bloom-skipped.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro import (GraphSession, materialize, preprocess_graph, rmat_edges,
                   write_edge_list)


def main():
    with tempfile.TemporaryDirectory() as td:
        print("1) generate a 2^14-vertex, ~260k-edge RMAT graph")
        src, dst = materialize(rmat_edges(scale=14, edge_factor=16, seed=0))
        write_edge_list(f"{td}/edges", [(src, dst)])

        print("2) preprocess: degree scan -> Algorithm-1 intervals -> ELL shards")
        store = preprocess_graph(f"{td}/edges", f"{td}/graph",
                                 threshold_edge_num=1 << 15)
        print(f"   {store.num_shards} shards, {store.num_edges} edges, "
              f"{store.num_vertices} vertices")

        print("3) one session, three applications, one shared cache")
        # prefetch_depth=1: double-buffer shard fetch/staging behind the SpMV
        with GraphSession(f"{td}/graph", cache_mode=1,
                          cache_budget_bytes=1 << 28,
                          prefetch_depth=1) as session:
            result = session.run("pagerank", max_iters=30)
            top = np.argsort(result.values)[-5:][::-1]
            print(f"   pagerank: {result.iterations} iterations, "
                  f"{result.total_seconds:.2f}s, "
                  f"{result.edges_per_second()/1e6:.1f}M edges/s")
            print(f"   top-5 vertices by rank: {top.tolist()}")
            disk_after_pr = session.stats.disk_bytes

            dist = session.run("sssp", source=int(top[0]), max_iters=100)
            comp = session.run("cc", max_iters=100)
            print(f"   sssp reached {int(np.isfinite(dist.values).sum())} "
                  f"vertices; cc found {len(np.unique(comp.values))} components")
            print(f"   disk bytes: {disk_after_pr/1e6:.1f}MB for pagerank, "
                  f"+{(session.stats.disk_bytes - disk_after_pr)/1e6:.2f}MB "
                  f"for sssp+cc (warm cache), "
                  f"hit ratio {session.stats.hit_ratio:.2f}")

            print("4) batched multi-source: 8 landmark SSSPs, ONE edge sweep")
            disk_before = session.stats.disk_bytes
            landmarks = top.tolist() + [0, 1, 2]
            batch = session.run_batch("sssp", sources=landmarks,
                                      max_iters=100)
            reached = [int(np.isfinite(r.values).sum()) for r in batch]
            print(f"   {len(batch)} frontiers, per-landmark iterations "
                  f"{[r.iterations for r in batch]}, reached {reached}")
            print(f"   extra disk for all {len(batch)} queries: "
                  f"{(session.stats.disk_bytes - disk_before)/1e6:.2f}MB")


if __name__ == "__main__":
    main()
