"""Serve a small model with batched requests: prefill + batched greedy
decode through the KV-cache engine (contiguous or ring-buffer SWA cache
depending on the arch).

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x22b]
(archs run at reduced scale so this works on CPU)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=48)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (args.batch, 24)))}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.stub_frames, cfg.d_model)),
            jnp.float32)
    if cfg.modality_stub == "image_patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.img_patches, cfg.d_model)),
            jnp.float32)
        S = 24 + cfg.img_patches
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (args.batch, S, 3)).astype(jnp.int32)
    engine = ServeEngine(model, params)
    toks, stats = engine.generate(batch, num_tokens=args.tokens)
    # greedy decode is deterministic: same prompt rows -> same outputs
    toks2, _ = engine.generate(batch, num_tokens=args.tokens)
    assert (toks == toks2).all()
    print(f"{args.arch} (reduced): batch={args.batch} generated "
          f"{stats.tokens_generated} tokens, "
          f"prefill {stats.prefill_seconds:.2f}s, "
          f"{stats.tokens_per_second:.0f} tok/s decode")
    print("sample:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
