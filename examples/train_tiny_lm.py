"""Train a small LM end-to-end on CPU: a scaled-down stablelm-family config
(~25M params by default; --full trains ~110M) for a few hundred steps with
checkpointing, demonstrating the training substrate on real hardware.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import OptConfig, make_init_state, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true", help="~110M params")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    base = get_config("stablelm-1.6b")
    if args.full:
        cfg = dataclasses.replace(base, num_layers=8, d_model=768, num_heads=12,
                                  num_kv_heads=12, d_ff=2048, vocab_size=32768)
    else:
        cfg = dataclasses.replace(base, num_layers=6, d_model=384, num_heads=6,
                                  num_kv_heads=6, d_ff=1024, vocab_size=8192)
    model = build_model(cfg)
    n = model.param_count()
    print(f"model: {n/1e6:.1f}M params, {cfg.num_layers}L x d{cfg.d_model}")
    opt = OptConfig(peak_lr=1e-3, warmup_steps=args.steps // 10,
                    decay_steps=args.steps)
    state = make_init_state(model, opt)(jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    pf = Prefetcher(data)
    losses = []
    t0 = time.time()
    with tempfile.TemporaryDirectory() as td:
        ck = CheckpointManager(td)
        try:
            for step in range(args.steps):
                batch = {k: jnp.asarray(v) for k, v in pf.next().items()}
                state, metrics = step_fn(state, batch)
                losses.append(float(metrics["loss"]))
                if (step + 1) % 25 == 0:
                    tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
                    print(f"step {step+1:4d} loss {losses[-1]:.4f} "
                          f"({tok_s:.0f} tok/s)")
                if (step + 1) % 100 == 0:
                    ck.save(step + 1, state)
        finally:
            pf.close()
            ck.wait()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s)")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
