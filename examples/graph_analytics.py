"""End-to-end driver (the paper's kind of workload): out-of-core analytics on
a graph bigger than the configured cache, PR + SSSP + CC from one
preprocessing pass, with fault injection + resume.

    PYTHONPATH=src python examples/graph_analytics.py [--scale 18]

At --scale 18 this is ~4M edges through real disk shards; scale up if you
have the time/disk.  Demonstrates:
  * one preprocessing, three applications (paper §2.2);
  * cache-mode auto-selection under a deliberately tight budget;
  * Bloom-filter selective scheduling kicking in as SSSP/CC converge;
  * checkpoint + resume mid-PageRank (fault tolerance).
"""
import argparse
import tempfile
import time

import numpy as np

from repro.core import apps
from repro.core.engine import VSWEngine
from repro.graph.generate import rmat_edges, materialize
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=17)
    ap.add_argument("--edge-factor", type=int, default=16)
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        src, dst = materialize(rmat_edges(scale=args.scale,
                                          edge_factor=args.edge_factor, seed=1))
        write_edge_list(f"{td}/edges", [(src, dst)])
        store = preprocess_graph(f"{td}/edges", f"{td}/graph",
                                 threshold_edge_num=1 << 17)
        print(f"preprocessed {store.num_edges} edges -> {store.num_shards} "
              f"shards in {time.time()-t0:.1f}s "
              f"(io: {store.io.read/1e6:.0f}MB read, "
              f"{store.io.written/1e6:.0f}MB written)")

        budget = int(store.total_shard_bytes() * 0.4)  # graph > cache
        for name, prog, iters in (("pagerank", apps.pagerank(), 30),
                                  ("sssp", apps.sssp(0), 100),
                                  ("cc", apps.cc(), 100)):
            eng = VSWEngine(store, prog, cache_mode="auto",
                            cache_budget_bytes=budget)
            res = eng.run(max_iters=iters)
            st = eng.cache.stats
            skipped = sum(h.shards_skipped for h in res.history)
            print(f"{name:9s} iters={res.iterations:3d} "
                  f"time={res.total_seconds:6.2f}s mode={eng.cache.mode} "
                  f"hit={st.hit_ratio:.2f} skipped_shards={skipped} "
                  f"disk={st.disk_bytes/1e6:.0f}MB")

        # fault tolerance: checkpoint PR at iteration 10, resume, same answer
        full = VSWEngine(store, apps.pagerank()).run(max_iters=20).values
        eng = VSWEngine(store, apps.pagerank())
        eng.run(max_iters=10, checkpoint_dir=f"{td}/ck", checkpoint_every=10)
        resumed = VSWEngine(store, apps.pagerank()).run(
            max_iters=20, checkpoint_dir=f"{td}/ck", resume=True)
        err = float(np.abs(resumed.values - full).max())
        print(f"resume-after-'failure' max deviation vs uninterrupted: {err:.2e}")
        assert err < 1e-6


if __name__ == "__main__":
    main()
