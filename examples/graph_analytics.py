"""End-to-end driver (the paper's kind of workload): out-of-core analytics on
a graph bigger than the configured cache, PR + SSSP + CC served by ONE
GraphSession from one preprocessing pass, with fault injection + resume.

    PYTHONPATH=src python examples/graph_analytics.py [--scale 18]

At --scale 18 this is ~4M edges through real disk shards; scale up if you
have the time/disk.  Demonstrates:
  * one preprocessing, one session, three applications sharing the
    compressed cache (paper §2.2) — watch the per-app disk-byte deltas;
  * the packed single-file backend (zero-copy mmap'd shard views) with the
    async shard pipeline (``prefetch_depth=2``) overlapping disk +
    decompression + staging with the SpMV — watch ``stall`` stay near zero;
  * cache-mode auto-selection under a deliberately tight budget;
  * live iteration monitoring via ``session.iter_run`` (Bloom-filter
    selective scheduling kicking in as SSSP converges);
  * checkpoint + resume mid-PageRank (fault tolerance) through the session.
"""
import argparse
import tempfile
import time

import numpy as np

from repro import (GraphSession, materialize, preprocess_graph, rmat_edges,
                   write_edge_list)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=17)
    ap.add_argument("--edge-factor", type=int, default=16)
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        src, dst = materialize(rmat_edges(scale=args.scale,
                                          edge_factor=args.edge_factor, seed=1))
        write_edge_list(f"{td}/edges", [(src, dst)])
        store = preprocess_graph(f"{td}/edges", f"{td}/graph",
                                 threshold_edge_num=1 << 17)
        print(f"preprocessed {store.num_edges} edges -> {store.num_shards} "
              f"shards in {time.time()-t0:.1f}s "
              f"(io: {store.io.read/1e6:.0f}MB read, "
              f"{store.io.written/1e6:.0f}MB written)")

        budget = int(store.total_shard_bytes() * 0.4)  # graph > cache
        # packed backend: auto-packs graph/ into one mmap'd file on first use;
        # prefetch_depth=2 streams shards through the async pipeline
        session = GraphSession(f"{td}/graph", backend="packed",
                               cache_mode="auto", cache_budget_bytes=budget,
                               prefetch_depth=2)
        print(f"session: {session!r}")
        last_disk = 0
        for name, kwargs, iters in (("pagerank", {}, 30),
                                    ("sssp", {"source": 0}, 100),
                                    ("cc", {}, 100)):
            res = session.run(name, max_iters=iters, **kwargs)
            st = session.stats
            skipped = sum(h.shards_skipped for h in res.history)
            stall = sum(h.stall_seconds for h in res.history)
            print(f"{name:9s} iters={res.iterations:3d} "
                  f"time={res.total_seconds:6.2f}s mode={session.cache.mode} "
                  f"hit={st.hit_ratio:.2f} skipped_shards={skipped} "
                  f"disk_delta={(st.disk_bytes - last_disk)/1e6:.0f}MB "
                  f"stall={stall:.2f}s "
                  f"rate={res.edges_per_second()/1e6:.1f}M edges/s")
            last_disk = st.disk_bytes

        # live monitoring: stream IterationStats as BFS converges
        print("bfs       live:", end=" ")
        for it in session.iter_run("bfs", source=0, max_iters=100):
            if it.iteration % 5 == 0:
                print(f"[{it.iteration}] active={it.active_ratio:.4f}"
                      f"{'*' if it.selective_enabled else ''}", end=" ")
        print()

        # fault tolerance: checkpoint PR at iteration 10, resume, same answer
        full = GraphSession(store).run("pagerank", max_iters=20).values
        ck_sess = GraphSession(store)
        ck_sess.run("pagerank", max_iters=10,
                    checkpoint_dir=f"{td}/ck", checkpoint_every=10)
        resumed = GraphSession(store).run(
            "pagerank", max_iters=20, checkpoint_dir=f"{td}/ck", resume=True)
        err = float(np.abs(resumed.values - full).max())
        print(f"resume-after-'failure' max deviation vs uninterrupted: {err:.2e}")
        assert err < 1e-6


if __name__ == "__main__":
    main()
