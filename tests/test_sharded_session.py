"""ShardedVSWEngine through the GraphSession surface.

The multi-device legs run in subprocesses with XLA_FLAGS-forced CPU device
counts (the main test process must keep seeing exactly 1 device); the
host-side pieces (shard assignment, cache partitioning, config validation)
run in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900,
                     extra_env: dict | None = None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


# a non-divisible graph (500 % 8 != 0) built once per subprocess; prefetch
# on so the per-device pipeline lanes are exercised
_BUILD_STORE = """
    import tempfile
    import numpy as np
    from repro.graph.generate import rmat_edges, materialize
    from repro.graph.storage import write_edge_list
    from repro.graph.preprocess import preprocess_graph

    src, dst = materialize(rmat_edges(scale=9, edge_factor=8, seed=7))
    n = 500
    keep = (src < n) & (dst < n)
    src, dst = src[keep], dst[keep]
    base = tempfile.mkdtemp()
    write_edge_list(base + "/el", [(src, dst)])
    preprocess_graph(base + "/el", base + "/store",
                     threshold_edge_num=2048, ell_max_width=256,
                     num_vertices=n)
"""


def test_sharded_session_bitwise_identity():
    """pagerank / sssp / bfs / cc values and iteration counts are BITWISE
    identical across 1, 2, 4 and 8 devices on a non-divisible |V|."""
    out = run_with_devices(_BUILD_STORE + """
    from repro.session import GraphSession

    ref = {}
    for D in (1, 2, 4, 8):
        with GraphSession(base + "/store", num_devices=D,
                          prefetch_depth=2) as s:
            for app, kw in (("pagerank", dict(max_iters=20)),
                            ("sssp", dict(source=3)),
                            ("bfs", dict(source=3)),
                            ("cc", {})):
                r = s.run(app, **kw)
                v = np.asarray(r.values)
                if D == 1:
                    ref[app] = (v, r.iterations, r.converged)
                else:
                    rv, ri, rc = ref[app]
                    assert (v == rv).all(), \\
                        (D, app, float(np.abs(v - rv).max()))
                    assert r.iterations == ri, (D, app, r.iterations, ri)
                    assert r.converged == rc, (D, app)
    print("OK")
    """)
    assert "OK" in out


def test_sharded_session_batch_and_device_accounting():
    """run_batch matches single-device bitwise, and each iteration's
    device_disk_bytes tuple sums to its aggregate disk_bytes (Table-3
    accounting stays honest across cache partitions)."""
    out = run_with_devices(_BUILD_STORE + """
    from repro.session import GraphSession

    with GraphSession(base + "/store", num_devices=1) as s1:
        want = [np.asarray(r.values)
                for r in s1.run_batch("sssp", sources=[0, 3, 17])]
    with GraphSession(base + "/store", num_devices=8,
                      prefetch_depth=2) as s8:
        got = [np.asarray(r.values)
               for r in s8.run_batch("sssp", sources=[0, 3, 17])]
        for w, g in zip(want, got):
            assert (w == g).all(), float(np.abs(w - g).max())

        hist = s8.run("pagerank", max_iters=5).history
        assert hist, "no iterations recorded"
        for st in hist:
            assert len(st.device_disk_bytes) == 8
            assert len(st.device_stall_seconds) == 8
            assert len(st.device_fetch_seconds) == 8
            assert sum(st.device_disk_bytes) == st.disk_bytes
        rep = s8.cache_report()
        assert rep["policy"] == "partitioned"
        assert rep["num_partitions"] == 8
        assert len(rep["partitions"]) == 8
    print("OK")
    """)
    assert "OK" in out


def test_sharded_session_env_knob():
    """GRAPHMP_DEVICES routes a default-config session to the sharded
    engine with no code changes."""
    out = run_with_devices(_BUILD_STORE + """
    from repro.core.distributed import ShardedVSWEngine
    from repro.core.engine import EngineConfig
    from repro.session import GraphSession

    assert EngineConfig.from_env().num_devices == 8
    with GraphSession(base + "/store") as s:
        assert s.config.num_devices == 8
        r = s.run("cc")
        assert isinstance(s.engine("cc"), ShardedVSWEngine)
        assert len(r.history[0].device_disk_bytes) == 8
    print("OK")
    """, extra_env={"GRAPHMP_DEVICES": "8"})
    assert "OK" in out


def test_sharded_session_mutation_epochs():
    """Epoch pinning and incremental recompute carry over: a mutable
    8-device session tracks a 1-device one bitwise through a commit."""
    out = run_with_devices(_BUILD_STORE + """
    from repro.session import GraphSession

    edits = [(int(s), int(d)) for s, d in zip(src[:40] // 2, dst[:40] // 3)]
    results = {}
    for D in (1, 8):
        with GraphSession(base + "/store", num_devices=D, mutable=True,
                          prefetch_depth=2) as s:
            before = s.run("cc")
            s.apply_mutations(inserts=edits)
            after = s.run_incremental("cc", prev=before)
            results[D] = (np.asarray(before.values), np.asarray(after.values))
    assert (results[1][0] == results[8][0]).all()
    assert (results[1][1] == results[8][1]).all()
    print("OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# host-side pieces: no mesh needed (run in the single-device main process)

def test_engine_config_num_devices_validation():
    from repro.core.engine import EngineConfig

    assert EngineConfig().num_devices == 1
    assert EngineConfig(num_devices=4).num_devices == 4
    for bad in (0, -1, True, 1.5, "8"):
        with pytest.raises(ValueError):
            EngineConfig(num_devices=bad)


def test_make_data_mesh_too_few_devices():
    from repro.dist.context import make_data_mesh

    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_data_mesh(4096)
    with pytest.raises(ValueError):
        make_data_mesh(0)


def test_assign_shards_contiguous_and_balanced():
    from repro.core.distributed import assign_shards

    intervals = np.array([0, 10, 30, 60, 100, 130, 150])
    nnz = [10, 20, 30, 40, 20, 20]
    owner, bounds = assign_shards(intervals, nnz, 3)
    # contiguous, non-decreasing ownership tiling all shards
    assert owner.shape == (6,)
    assert (np.diff(owner) >= 0).all()
    assert owner.min() == 0 and owner.max() <= 2
    # bounds tile [0, n) and agree with ownership
    assert bounds[0] == 0 and bounds[-1] == 150
    assert (np.diff(bounds) >= 0).all()
    for p in range(6):
        d = owner[p]
        assert bounds[d] <= intervals[p] < bounds[d + 1]
    # more devices than shards: trailing devices own nothing, bounds collapse
    owner2, bounds2 = assign_shards(np.array([0, 7, 19]), [5, 5], 4)
    assert len(owner2) == 2 and bounds2[0] == 0 and bounds2[-1] == 19
    assert (np.diff(bounds2) >= 0).all()  # collapsed intervals are empty, not inverted
    # zero nnz metadata falls back to uniform weights
    owner3, _ = assign_shards(np.array([0, 5, 10, 15, 20]), [0, 0, 0, 0], 2)
    assert (owner3 == np.array([0, 0, 1, 1])).all()


def test_partitioned_cache_budget_and_routing(graph_store):
    from repro.core.cache import PartitionedShardCache

    P_ = graph_store.num_shards
    owner = np.arange(P_, dtype=np.int64) % 3
    budget = 1 << 20
    pc = PartitionedShardCache(graph_store, owner, 3, budget_bytes=budget)
    # the per-partition budgets split the global one EXACTLY (no rounding
    # slack: the strict-budget contract survives partitioning)
    assert sum(p.budget for p in pc.parts) == budget == pc.budget
    for p in range(P_):
        shard = pc.get(p)
        assert shard.start_vertex == graph_store.intervals[p]
        # the fetch landed in the owner's partition only
        assert pc.parts[owner[p]].stats.misses >= 1
    assert pc.stats.misses == P_
    # repeat hits are served and counted
    pc.get(0)
    assert pc.stats.hits >= 1
    rep = pc.report()
    assert rep["policy"] == "partitioned" and rep["num_partitions"] == 3
    assert len(rep["partitions"]) == 3
    assert pc.cached_bytes == sum(p.cached_bytes for p in pc.parts)
    # frozen store: nothing is epoch-stale, so a bare invalidate is a no-op
    assert pc.invalidate() == 0
    # explicit ids drop across whichever partitions own them
    assert pc.invalidate(range(P_)) == P_
    assert pc.cached_shards == 0
    with pytest.raises(ValueError):
        PartitionedShardCache(graph_store, owner, 2)  # owner id out of range
