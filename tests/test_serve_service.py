"""GraphService: concurrency hammer, policy behavior, and stats regression.

The acceptance bar (ISSUE 5): under >= 8 client threads x >= 64 mixed
queries against ONE service, every future's result is bitwise-identical to
a solo ``session.run`` of the same query.  Plus drain-on-close semantics,
admission rejection, memoization correctness, and exact percentile math.
"""
import threading
import time

import numpy as np
import pytest

from repro.serve.graph_service import (AdmissionError, GraphService,
                                       ServiceClosed, ServiceConfig,
                                       ServiceStats, percentile)
from repro.session import GraphSession

MAX_ITERS = {"sssp": 100, "bfs": 100, "cc": 300, "pagerank": 20}


def _mixed_queries(n):
    """64 deterministic mixed queries: sssp/bfs landmarks + global apps."""
    qs = []
    for i in range(20):
        qs.append(("sssp", {"source": (i * 37) % n}))
    for i in range(20):
        qs.append(("bfs", {"source": (i * 53 + 5) % n}))
    qs += [("cc", {})] * 12
    qs += [("pagerank", {})] * 12
    assert len(qs) == 64
    return qs


@pytest.fixture(scope="module")
def solo(graph_store):
    """Memoized solo ``session.run`` ground truth (one session, any query)."""
    cache = {}
    sess = GraphSession(graph_store)

    def get(app, **params):
        key = (app, tuple(sorted(params.items())))
        if key not in cache:
            cache[key] = sess.run(app, max_iters=MAX_ITERS[app],
                                  **params).values
        return cache[key]

    yield get
    sess.close()


# ---------------------------------------------------------------------------
# the hammer
# ---------------------------------------------------------------------------
def test_concurrency_hammer_bitwise_identical(graph_store, solo):
    """8 client threads x 64 mixed queries: every result equals its solo
    run bit for bit, regardless of how the service coalesced them."""
    n = graph_store.num_vertices
    queries = _mixed_queries(n)
    results: dict[int, np.ndarray] = {}
    errors: list[BaseException] = []
    lock = threading.Lock()

    with GraphSession(graph_store) as sess:
        svc = GraphService(sess, ServiceConfig(
            max_batch=8, max_wait_ms=20.0, max_inflight=2, memoize=True))
        with svc:
            def client(tid):
                # thread t takes queries t, t+8, t+16, ... (all mixed up)
                try:
                    futs = [(i, svc.submit(app,
                                           max_iters=MAX_ITERS[app], **params))
                            for i, (app, params) in enumerate(queries)
                            if i % 8 == tid]
                    for i, f in futs:
                        with lock:
                            results[i] = f.result(timeout=300).values
                except BaseException as exc:  # noqa: BLE001 — surfaced below
                    with lock:
                        errors.append(exc)

            threads = [threading.Thread(target=client, args=(t,))
                       for t in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors
            snap = svc.stats.snapshot()

    assert len(results) == 64
    for i, (app, params) in enumerate(queries):
        np.testing.assert_array_equal(
            results[i], solo(app, **params),
            err_msg=f"query {i} ({app} {params}) diverged from solo run")
    assert snap["completed"] == 64
    assert snap["failed"] == 0 and snap["rejected"] == 0
    # the mix repeats queries, so coalescing + memo must actually engage:
    # strictly fewer engine executions than requests
    executions = sum(snap["batch_occupancy"].values())
    assert executions + snap["memo_hits"] <= 64
    assert sum(k * v for k, v in snap["batch_occupancy"].items()) \
        + snap["memo_hits"] == 64


# ---------------------------------------------------------------------------
# lifecycle: drain, refuse-after-close, no-drain cancellation
# ---------------------------------------------------------------------------
def _parked_service(sess, **overrides):
    """A service whose dispatcher holds batches open (so submissions stay
    PENDING deterministically until close() or the batch fills)."""
    kw = dict(max_batch=64, max_wait_ms=60_000.0, max_inflight=1,
              memoize=False)
    kw.update(overrides)
    return GraphService(sess, ServiceConfig(**kw))


def test_close_drains_pending_requests(graph_store, solo):
    with GraphSession(graph_store) as sess:
        svc = _parked_service(sess)
        sources = [0, 5, 9]
        futs = [svc.submit("sssp", source=s, max_iters=100) for s in sources]
        assert svc.queue_depth == len(sources)  # parked, not yet dispatched
        svc.close()  # drain=True: pending work runs to completion
        for s, f in zip(sources, futs):
            assert f.done()
            np.testing.assert_array_equal(f.result().values,
                                          solo("sssp", source=s))
        with pytest.raises(ServiceClosed):
            svc.submit("sssp", source=1)
        svc.close()  # idempotent


def test_close_without_drain_fails_pending(graph_store):
    with GraphSession(graph_store) as sess:
        svc = _parked_service(sess)
        futs = [svc.submit("sssp", source=s) for s in (1, 2, 3)]
        svc.close(drain=False)
        for f in futs:
            with pytest.raises(ServiceClosed):
                f.result(timeout=10)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_rejects_unserved_app(graph_store):
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(apps=("sssp",))) as svc:
            svc.submit("sssp", source=0, max_iters=2).result(timeout=60)
            with pytest.raises(AdmissionError, match="not served"):
                svc.submit("cc")
            with pytest.raises(AdmissionError, match="not served"):
                svc.submit("nonsense")
            assert svc.stats.snapshot()["rejected"] == 2


def test_admission_rejects_when_queue_full(graph_store):
    with GraphSession(graph_store) as sess:
        svc = _parked_service(sess, max_queue=3)
        futs = [svc.submit("sssp", source=s) for s in (0, 1, 2)]
        with pytest.raises(AdmissionError, match="queue full"):
            svc.submit("sssp", source=3)
        svc.close()  # drains the three admitted requests
        assert all(f.done() and f.exception() is None for f in futs)
        assert svc.stats.snapshot()["rejected"] == 1


def test_submit_validates_parameters(graph_store):
    with GraphSession(graph_store) as sess:
        with GraphService(sess) as svc:
            with pytest.raises(TypeError, match="source"):
                svc.submit("sssp")  # batchable app needs its frontier
            with pytest.raises(ValueError, match=">= 0"):
                svc.submit("sssp", source=-3)


# ---------------------------------------------------------------------------
# memoization
# ---------------------------------------------------------------------------
def test_memoization_serves_repeats_without_sweeps(graph_store, solo):
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(max_batch=4, max_wait_ms=5.0,
                                              memoize=True)) as svc:
            first = svc.submit("sssp", source=5, max_iters=100).result(60)
            again = svc.submit("sssp", source=5, max_iters=100).result(60)
            snap = svc.stats.snapshot()
            assert snap["memo_hits"] == 1
            assert snap["cache_served_fraction"] == pytest.approx(0.5)
            # memoized answers stay CORRECT, not just fast
            np.testing.assert_array_equal(again.values,
                                          solo("sssp", source=5))
            np.testing.assert_array_equal(again.values, first.values)
            # different params are different memo entries
            shorter = svc.submit("sssp", source=5, max_iters=1).result(60)
            assert svc.stats.snapshot()["memo_hits"] == 1
            assert not np.array_equal(shorter.values, first.values)


def test_memo_byte_budget_bounds_residency(graph_store):
    """A result bigger than the whole memo byte budget is never memoized —
    entry COUNT alone must not bound a cache of length-n vectors."""
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(memoize=True,
                                              memo_budget_bytes=8)) as svc:
            svc.submit("sssp", source=1, max_iters=50).result(60)
            svc.submit("sssp", source=1, max_iters=50).result(60)
            assert svc.stats.snapshot()["memo_hits"] == 0
            assert svc._memo_bytes == 0


def test_memoization_disabled_reruns(graph_store):
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(memoize=False)) as svc:
            svc.submit("cc").result(60)
            svc.submit("cc").result(60)
            snap = svc.stats.snapshot()
            assert snap["memo_hits"] == 0
            assert snap["completed"] == 2


# ---------------------------------------------------------------------------
# coalescing behavior
# ---------------------------------------------------------------------------
def test_coalesces_full_batch_deterministically(graph_store, solo):
    """With max_wait long and max_batch == the submission count, all four
    queries must ride ONE [n, 4] sweep (occupancy histogram pins it)."""
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(
                max_batch=4, max_wait_ms=30_000.0, memoize=False)) as svc:
            futs = [svc.submit("sssp", source=s, max_iters=100)
                    for s in (0, 5, 9, 42)]
            for s, f in zip((0, 5, 9, 42), futs):
                np.testing.assert_array_equal(f.result(timeout=300).values,
                                              solo("sssp", source=s))
            assert dict(svc.stats.snapshot()["batch_occupancy"]) == {4: 1}


def test_incompatible_params_do_not_coalesce(graph_store):
    """Same family but different non-source params (max_iters) must land in
    different sweeps — coalescing them would change results."""
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(
                max_batch=8, max_wait_ms=50.0, memoize=False)) as svc:
            f1 = svc.submit("sssp", source=0, max_iters=100)
            f2 = svc.submit("sssp", source=0, max_iters=1)
            r1, r2 = f1.result(60), f2.result(60)
            occ = svc.stats.snapshot()["batch_occupancy"]
            assert sum(occ.values()) == 2  # two separate executions
            assert not np.array_equal(r1.values, r2.values)


def test_ppr_served_via_k1_microbatch(graph_store):
    """"ppr" has no solo program; a single submission is a K=1 batch and
    must match run_batch's own K=1 answer."""
    with GraphSession(graph_store) as sess:
        want = sess.run_batch("ppr", sources=[7], max_iters=25)[0]
        with GraphSession(graph_store) as sess2:
            with GraphService(sess2, ServiceConfig(memoize=False)) as svc:
                got = svc.submit("ppr", seed=7, max_iters=25).result(300)
        np.testing.assert_allclose(got.values, want.values, atol=1e-6)


# ---------------------------------------------------------------------------
# ServiceStats: the percentile math cannot drift
# ---------------------------------------------------------------------------
def test_percentile_is_nearest_rank():
    vals = [10.0, 20.0, 30.0, 40.0]
    assert percentile(vals, 25) == 10.0   # ceil(1.0) = 1st smallest
    assert percentile(vals, 50) == 20.0   # ceil(2.0) = 2nd
    assert percentile(vals, 75) == 30.0
    assert percentile(vals, 76) == 40.0   # ceil(3.04) = 4th
    assert percentile(vals, 100) == 40.0
    assert percentile([], 50) == 0.0
    with pytest.raises(ValueError):
        percentile(vals, 0)
    with pytest.raises(ValueError):
        percentile(vals, 101)


def test_service_stats_values_within_reservoir_error():
    """Synthetic recorded sequence -> p50/p95/p99 within the latency
    reservoir's DOCUMENTED relative error of the exact nearest-rank
    values, occupancy and derived fractions exact (regression-pins the
    reporting math; the bound itself is pinned in tests/test_obs.py)."""
    stats = ServiceStats()
    rng = np.random.default_rng(0)
    ms = np.arange(1, 101, dtype=np.float64)  # 1..100 ms
    for v in rng.permutation(ms):
        stats.record_latency(v / 1e3)
    for occ in (1, 2, 2, 4, 16):
        stats.record_batch(occ)
    stats.record_latency(0.0, memo_hit=True)  # one memo-served request
    stats.record_rejected()
    snap = stats.snapshot()
    # quantiles carry the log-bin estimate error: rel <= sqrt(growth) - 1
    rel = stats.latency_hist.growth ** 0.5 - 1
    # N=101 latencies (100 synthetic + the memo hit at 0 ms):
    # p50 -> ceil(50.5) = 51st smallest = 50 ms; p95 -> ceil(95.95) = 96th
    # = 95 ms; p99 -> ceil(99.99) = 100th = 99 ms
    assert snap["p50_ms"] == pytest.approx(50.0, rel=rel)
    assert snap["p95_ms"] == pytest.approx(95.0, rel=rel)
    assert snap["p99_ms"] == pytest.approx(99.0, rel=rel)
    assert snap["mean_ms"] == pytest.approx(5050.0 / 101)  # mean stays EXACT
    assert snap["batch_occupancy"] == {1: 1, 2: 2, 4: 1, 16: 1}
    assert snap["completed"] == 101
    assert snap["memo_hits"] == 1
    assert snap["rejected"] == 1
    assert snap["cache_served_fraction"] == pytest.approx(1 / 101)


def test_service_stats_per_app_histograms():
    stats = ServiceStats()
    for _ in range(10):
        stats.record_latency(0.010, app="bfs")
    stats.record_latency(1.0, app="ppr")
    rel = stats.latency_hist.growth ** 0.5 - 1
    assert stats._app_hist("bfs").quantile(50) == pytest.approx(0.010,
                                                                rel=rel)
    assert stats._app_hist("ppr").quantile(50) == pytest.approx(1.0, rel=rel)
    assert stats.latency_hist.count == 11


def test_service_stats_queue_depth_tracking():
    stats = ServiceStats()
    stats.record_submitted(queue_depth=1)
    stats.record_submitted(queue_depth=2)
    stats.record_dequeued(queue_depth=0)
    snap = stats.snapshot()
    assert snap["submitted"] == 2
    assert snap["queue_depth"] == 0
    assert snap["queue_peak"] == 2


# ---------------------------------------------------------------------------
# live reconfiguration (the adaptive controller's write path)
# ---------------------------------------------------------------------------
def test_reconfigure_applies_to_parked_requests(graph_store, solo):
    """Requests parked behind a huge straggler window must dispatch as soon
    as reconfigure() shrinks it — the dispatcher may not cache the old
    config across waits."""
    with GraphSession(graph_store) as sess:
        with _parked_service(sess) as svc:
            futs = [svc.submit("sssp", source=s, max_iters=100)
                    for s in (0, 5)]
            assert svc.queue_depth == 2  # parked behind the 60 s window
            new = svc.reconfigure(max_wait_ms=0.0)
            assert new.max_wait_ms == 0.0 and svc.config is new
            for s, f in zip((0, 5), futs):
                np.testing.assert_array_equal(f.result(timeout=300).values,
                                              solo("sssp", source=s))


def test_reconfigure_validates_fields():
    with pytest.raises(ValueError, match="fair_weights"):
        ServiceConfig(fair_weights={"bfs": 0.0})
    assert ServiceConfig(fair_weights={"b": 2, "a": 1}).fair_weights == \
        (("a", 1.0), ("b", 2.0))
    assert ServiceConfig().weight_for("anything") == 1.0


def test_reconfigure_rejects_fixed_fields_and_closed_service(graph_store):
    with GraphSession(graph_store) as sess:
        svc = GraphService(sess, ServiceConfig())
        with pytest.raises(ValueError, match="not reconfigurable"):
            svc.reconfigure(max_inflight=4)  # sizes a real thread pool
        with pytest.raises(ValueError, match="max_batch"):
            svc.reconfigure(max_batch=0)  # construction-grade validation
        assert not svc.is_closed
        svc.close()
        assert svc.is_closed
        with pytest.raises(ServiceClosed):
            svc.reconfigure(max_batch=4)


# ---------------------------------------------------------------------------
# fair-share dispatch
# ---------------------------------------------------------------------------
def test_fair_share_orders_ready_groups(graph_store):
    """White-box: with every group past its deadline, dispatch must
    alternate apps by stride pass — bfs, ppr, bfs — not serve both full
    bfs groups before the lone ppr (the old full-group-first starvation)."""
    with GraphSession(graph_store) as sess:
        svc = _parked_service(sess, max_batch=2)
        try:
            with svc._cond:
                svc._paused = True  # park the dispatcher (mutation barrier)
            for s in (0, 1, 2, 3):
                svc.submit("bfs", source=s, max_iters=5)
            svc.submit("ppr", seed=1, max_iters=5)
            far_future = time.perf_counter() + 1e6  # everything expired
            order = []
            with svc._cond:
                cfg = svc.config
                while svc._pending:
                    key = svc._ready_group(cfg, far_future)
                    assert key is not None
                    group = svc._take_group(key, cfg)
                    order.append(tuple(r.app for r in group))
            assert order == [("bfs", "bfs"), ("ppr",), ("bfs", "bfs")]
        finally:
            with svc._cond:
                svc._paused = False
                svc._cond.notify_all()
            svc.close(drain=False)


def test_fair_share_hammer_bfs_flood_does_not_starve_ppr(graph_store):
    """8 threads, 7 flooding cheap bfs + 1 submitting a few ppr queries:
    the ppr client must finish while the flood is still running (under the
    old policy the perpetually-full bfs groups preempt the expired ppr
    group until the flood drains)."""
    n = graph_store.num_vertices
    done_t = {}
    errors = []
    lock = threading.Lock()
    with GraphSession(graph_store) as sess:
        with GraphService(sess, ServiceConfig(
                max_batch=4, max_wait_ms=5.0, max_inflight=1,
                memoize=False)) as svc:
            svc.warmup(apps=("bfs",))

            def bfs_flood(tid):
                try:
                    for i in range(24):
                        svc.submit("bfs", source=(tid * 31 + i) % n,
                                   max_iters=3).result(timeout=300)
                except BaseException as exc:  # noqa: BLE001
                    with lock:
                        errors.append(exc)
                with lock:
                    done_t[f"bfs{tid}"] = time.perf_counter()

            def ppr_client():
                try:
                    for i in range(3):
                        svc.submit("ppr", seed=i, max_iters=3) \
                           .result(timeout=300)
                except BaseException as exc:  # noqa: BLE001
                    with lock:
                        errors.append(exc)
                with lock:
                    done_t["ppr"] = time.perf_counter()

            threads = [threading.Thread(target=bfs_flood, args=(t,))
                       for t in range(7)]
            threads.append(threading.Thread(target=ppr_client))
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors
            # liveness: the 3 ppr queries did not queue behind ~168 bfs
            last_bfs = max(v for k, v in done_t.items() if k != "ppr")
            assert done_t["ppr"] < last_bfs
            # both apps flowed through the per-app latency reservoirs
            assert svc.stats._app_hist("ppr").count == 3
            assert svc.stats._app_hist("bfs").count == 7 * 24


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------
def test_service_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        ServiceConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        ServiceConfig(max_wait_ms=-1)
    with pytest.raises(ValueError, match="max_inflight"):
        ServiceConfig(max_inflight=0)
    with pytest.raises(ValueError, match="max_queue"):
        ServiceConfig(max_queue=0)
    assert ServiceConfig(apps=["sssp"]).apps == ("sssp",)
    assert ServiceConfig().replace(max_batch=4).max_batch == 4


def test_session_service_factory(graph_store):
    """GraphSession.service() wires overrides through to the config."""
    with GraphSession(graph_store) as sess:
        with sess.service(max_batch=3, max_wait_ms=1.0) as svc:
            assert isinstance(svc, GraphService)
            assert svc.config.max_batch == 3
            assert svc.session is sess
            r = svc.submit("bfs", source=2, max_iters=50).result(timeout=300)
            np.testing.assert_array_equal(
                r.values, sess.run("bfs", source=2, max_iters=50).values)


def test_warmup_precompiles_bucket_sizes(graph_store):
    with GraphSession(graph_store) as sess:
        with sess.service(max_batch=4, memoize=False) as svc:
            svc.warmup(apps=("sssp",))
            t0 = time.perf_counter()
            svc.submit("sssp", source=3, max_iters=2).result(timeout=60)
            # not a timing assertion (CI noise) — just that warmed engines
            # exist and serve; the padded bucket engines are session-cached
            assert time.perf_counter() - t0 < 60
            assert len(sess._engines) >= 2  # K=1,2,4 sssp_multi buckets


# ---------------------------------------------------------------------------
# app-zoo hammer: lp + k-core + walks + ppr concurrently (ISSUE 9)
# ---------------------------------------------------------------------------
ZOO_MAX_ITERS = {"lp": 400, "kcore": 400, "random_walk": 100, "ppr": 20}


def _zoo_queries(n):
    """64 distinct queries, 16 per app (distinct => no memo hits, so the
    per-app accounting below is exact)."""
    qs = []
    for i in range(16):
        qs.append(("lp", {"source": (i * 29) % n}))
    for i in range(16):
        qs.append(("kcore", {"k": i}))
    for i in range(16):
        qs.append(("random_walk",
                   {"source": (i * 13 + 2) % n, "length": 8, "seed": 5}))
    for i in range(16):
        qs.append(("ppr", {"seed": (i * 17 + 1) % n}))
    assert len(qs) == 64
    return qs


@pytest.fixture(scope="module")
def zoo_solo(graph_store):
    """Solo ground truth for the zoo hammer: alias apps run as their own
    K=1 micro-batches (that IS their solo form)."""
    cache = {}
    sess = GraphSession(graph_store)

    def get(app, **params):
        key = (app, tuple(sorted(params.items())))
        if key not in cache:
            params = dict(params)
            max_iters = ZOO_MAX_ITERS[app]
            if app == "kcore":
                res = sess.run("kcore", k=params.pop("k"),
                               max_iters=max_iters)
            elif app == "lp":
                res = sess.run_batch("lp", sources=[params.pop("source")],
                                     max_iters=max_iters)[0]
            elif app == "random_walk":
                res = sess.run_batch(
                    "random_walk", sources=[params.pop("source")],
                    max_iters=max_iters, **params)[0]
            else:  # ppr
                res = sess.run_batch("ppr", sources=[params.pop("seed")],
                                     max_iters=max_iters)[0]
            cache[key] = np.asarray(res.values)
        return cache[key]

    yield get
    sess.close()


def test_mixed_zoo_hammer_bitwise_and_fair(graph_store, zoo_solo):
    """8 threads x 64 mixed zoo queries (lp + kcore + walks + ppr) through
    one service: exact apps (lp/kcore/random_walk) match their solo runs
    bit for bit however they were coalesced; ppr (float-accumulating,
    exact=False) to tolerance; per-app latency accounting sees exactly the
    16 requests each app submitted."""
    n = graph_store.num_vertices
    queries = _zoo_queries(n)
    results: dict[int, np.ndarray] = {}
    errors: list[BaseException] = []
    lock = threading.Lock()

    with GraphSession(graph_store) as sess:
        svc = GraphService(sess, ServiceConfig(
            max_batch=8, max_wait_ms=20.0, max_inflight=2, memoize=True))
        with svc:
            def client(tid):
                try:
                    futs = [(i, svc.submit(
                                app, max_iters=ZOO_MAX_ITERS[app], **params))
                            for i, (app, params) in enumerate(queries)
                            if i % 8 == tid]
                    for i, f in futs:
                        with lock:
                            results[i] = np.asarray(
                                f.result(timeout=300).values)
                except BaseException as exc:  # noqa: BLE001 — surfaced below
                    with lock:
                        errors.append(exc)

            threads = [threading.Thread(target=client, args=(t,))
                       for t in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors
            snap = svc.stats.snapshot()
            # fair-share accounting: every app's reservoir saw its 16
            per_app = {app: svc.stats._app_hist(app).count
                       for app in ZOO_MAX_ITERS}
            assert per_app == {app: 16 for app in ZOO_MAX_ITERS}, per_app

    assert len(results) == 64
    for i, (app, params) in enumerate(queries):
        want = zoo_solo(app, **params)
        if app == "ppr":
            np.testing.assert_allclose(
                results[i], want, atol=1e-6,
                err_msg=f"query {i} ({app} {params}) diverged from solo")
        else:
            np.testing.assert_array_equal(
                results[i], want,
                err_msg=f"query {i} ({app} {params}) diverged from solo")
    assert snap["completed"] == 64
    assert snap["failed"] == 0 and snap["rejected"] == 0
    # distinct queries => no memo hits; coalescing must still have engaged
    assert snap["memo_hits"] == 0
    executions = sum(snap["batch_occupancy"].values())
    assert executions < 64
    assert sum(k * v for k, v in snap["batch_occupancy"].items()) == 64
