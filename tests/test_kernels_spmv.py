"""Pallas kernel sweeps vs the pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.core.semiring import SEMIRINGS
from repro.kernels.spmv import ops, ref, spmv
from repro.kernels.spmv.ops import (describe_dispatch, ell_fold,
                                    ell_gather_fold, ell_spmv, ell_spmv_batch)

SEMIS = list(SEMIRINGS)
SHAPES = [(8, 128), (64, 256), (256, 128), (512, 640)]
DTYPES = [np.float32, np.dtype("bfloat16")]


def _make(rng, n, R, W, dtype):
    cols = rng.integers(-1, n, size=(R, W)).astype(np.int32)
    vals = rng.random((R, W)).astype(np.float32).astype(dtype)
    x = (rng.random(n).astype(np.float32) + 0.1).astype(dtype)
    row_map = np.sort(rng.integers(0, max(R // 2, 1), size=R)).astype(np.int32)
    return cols, vals, x, row_map


@pytest.mark.parametrize("semiring", SEMIS)
@pytest.mark.parametrize("shape", SHAPES)
def test_ell_spmv_vs_ref(semiring, shape):
    R, W = shape
    rng = np.random.default_rng(R * W)
    cols, vals, x, row_map = _make(rng, 1000, R, W, np.float32)
    out = ell_spmv(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                   jnp.asarray(row_map), R, semiring, use_pallas=True)
    want = ref.ell_spmv_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                            jnp.asarray(row_map), R, semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("semiring", SEMIS)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_ell_fold_dtypes(semiring, dtype):
    rng = np.random.default_rng(3)
    cols, vals, x, _ = _make(rng, 300, 64, 256, dtype)
    xg = x[np.where(cols >= 0, cols, 0)]
    out = ell_fold(jnp.asarray(xg), jnp.asarray(vals), jnp.asarray(cols),
                   semiring, use_pallas=True)
    want = ref.ell_fold_ref(jnp.asarray(xg), jnp.asarray(vals), jnp.asarray(cols),
                            semiring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype != np.float32 else 1e-6)


@pytest.mark.parametrize("semiring", SEMIS)
def test_ell_gather_fold_vs_ref(semiring):
    rng = np.random.default_rng(9)
    VB = 512
    cols, vals, x, _ = _make(rng, VB, 128, 384, np.float32)
    out = ell_gather_fold(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                          semiring, use_pallas=True)
    want = ref.ell_gather_fold_ref(jnp.asarray(x), jnp.asarray(cols),
                                   jnp.asarray(vals), semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@given(st.integers(0, 10_000), st.sampled_from(SEMIS))
@settings(max_examples=20, deadline=None)
def test_property_random_small(seed, semiring):
    rng = np.random.default_rng(seed)
    R = 8 * rng.integers(1, 5)
    W = 128 * rng.integers(1, 3)
    cols, vals, x, row_map = _make(rng, int(rng.integers(2, 500)), R, W, np.float32)
    out = ell_spmv(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                   jnp.asarray(row_map), R, semiring, use_pallas=True)
    want = ref.ell_spmv_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                            jnp.asarray(row_map), R, semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_all_masked_rows_give_identity():
    for semiring in SEMIS:
        sem = SEMIRINGS[semiring]
        cols = jnp.full((8, 128), -1, jnp.int32)
        vals = jnp.zeros((8, 128), jnp.float32)
        x = jnp.ones((16,), jnp.float32)
        out = ell_spmv(x, cols, vals, jnp.zeros((8,), jnp.int32), 8, semiring,
                       use_pallas=True)
        assert np.asarray(out)[1:].tolist() == [sem.identity] * 7


# ---------------------------------------------------------------------------
# fused gather→fold kernel + batched native layout + dispatch
# ---------------------------------------------------------------------------
EXACT_SEMIS = ["min_plus", "max_src"]  # no float re-association: bitwise


def _make_batch(rng, n, R, W, K):
    cols = rng.integers(-1, n, size=(R, W)).astype(np.int32)
    vals = rng.random((R, W)).astype(np.float32)
    x = rng.random((n, K)).astype(np.float32)
    row_map = np.sort(rng.integers(0, max(R // 2, 1), size=R)).astype(np.int32)
    return cols, vals, x, row_map


@pytest.mark.parametrize("semiring", EXACT_SEMIS)
@pytest.mark.parametrize("k", [1, 5])
def test_fused_vs_unfused_bitwise(semiring, k):
    """The fused in-kernel-gather path is bitwise-identical to the unfused
    XLA-gather + fold kernel on exact (min/max) semirings."""
    rng = np.random.default_rng(42 + k)
    n, R, W = 700, 64, 256
    cols, vals, x, row_map = _make_batch(rng, n, R, W, k)
    fused = spmv.ell_spmv_fused_pallas(
        jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals), semiring,
        interpret=True)
    xg = x[np.where(cols >= 0, cols, 0)]
    unfused = spmv.ell_fold_batch_pallas(
        jnp.asarray(xg), jnp.asarray(vals), jnp.asarray(cols), semiring,
        interpret=True)
    assert np.array_equal(np.asarray(fused), np.asarray(unfused))
    want = ref.ell_fold_batch_ref(jnp.asarray(xg), jnp.asarray(vals),
                                  jnp.asarray(cols), semiring)
    assert np.array_equal(np.asarray(fused), np.asarray(want))


@pytest.mark.parametrize("semiring", SEMIS)
def test_batch_native_layout_vs_ref(semiring):
    """ell_fold_batch_pallas consumes [R, W, K] natively — no transpose
    round-trip — and matches the oracle."""
    rng = np.random.default_rng(5)
    cols, vals, x, _ = _make_batch(rng, 400, 72, 384, 6)
    xg = x[np.where(cols >= 0, cols, 0)]
    out = spmv.ell_fold_batch_pallas(jnp.asarray(xg), jnp.asarray(vals),
                                     jnp.asarray(cols), semiring,
                                     interpret=True)
    want = ref.ell_fold_batch_ref(jnp.asarray(xg), jnp.asarray(vals),
                                  jnp.asarray(cols), semiring)
    assert out.shape == (72, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def _count_gathers_outside_pallas(jaxpr) -> int:
    """Walk a jaxpr (descending into pjit etc.) counting gather ops that are
    NOT inside a pallas_call — i.e. XLA-materialized gathers in HBM."""
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue  # in-kernel gathers read from VMEM, not HBM
        if eqn.primitive.name == "gather":
            count += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                count += _count_gathers_outside_pallas(inner)
    return count


def test_fused_path_has_no_hbm_gather():
    """The fused kernel never materializes a gathered copy: zero XLA gathers
    in the jaxpr.  The unfused Pallas path gathers exactly once (never the
    double gather the pre-fix layout churn risked)."""
    rng = np.random.default_rng(0)
    n, R, W, k = 600, 16, 128, 3
    cols, vals, x, row_map = _make_batch(rng, n, R, W, k)
    args = (jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
            jnp.asarray(row_map))
    fused_jaxpr = jax.make_jaxpr(
        lambda *a: ell_spmv_batch(*a, R, "min_plus", use_pallas=True))(*args)
    assert _count_gathers_outside_pallas(fused_jaxpr.jaxpr) == 0

    # different shape (fresh trace) + a limit of 0 forces the unfused path
    cols2, vals2, x2, row_map2 = _make_batch(rng, n, R, W * 2, k)
    args2 = (jnp.asarray(x2), jnp.asarray(cols2), jnp.asarray(vals2),
             jnp.asarray(row_map2))
    old = ops.FUSED_X_BYTES_LIMIT
    ops.FUSED_X_BYTES_LIMIT = 0
    try:
        fold_jaxpr = jax.make_jaxpr(
            lambda *a: ell_spmv_batch(*a, R, "min_plus", use_pallas=True))(*args2)
    finally:
        ops.FUSED_X_BYTES_LIMIT = old
    assert _count_gathers_outside_pallas(fold_jaxpr.jaxpr) == 1


def test_dispatch_table_cpu():
    """docs/ARCHITECTURE.md dispatch table, executable form (CPU backend)."""
    assert describe_dispatch(False, n=1000, k=1) == "jnp"
    assert describe_dispatch(False, n=1000, k=16) == "jnp"
    # auto on an interpreting backend: single-column keeps the cheap Pallas
    # referee path, batched falls back to jnp
    assert describe_dispatch("auto", n=1000, k=1) == "pallas:interpret:gather+fold"
    assert describe_dispatch("auto", n=1000, k=16) == "jnp"
    # forced Pallas: fused when the frontier fits VMEM, fold otherwise
    assert describe_dispatch(True, n=1000, k=16) == "pallas:interpret:fused"
    big = ops.FUSED_X_BYTES_LIMIT  # bytes -> elements: guaranteed too big
    assert describe_dispatch(True, n=big, k=16) == "pallas:interpret:gather+fold"


def test_resolve_no_dead_interpret_flag():
    """use_pallas=False short-circuits; 'auto'/True interpret only off the
    compiled backends (the old code forced interpret on GPU)."""
    assert ops._resolve(False) == (False, False)
    use, interp = ops._resolve("auto")
    assert use is True
    assert interp == (jax.default_backend() not in ops._COMPILED_BACKENDS)


def test_compiled_dispatch_is_tpu_only(monkeypatch):
    """GPU backends run grid programs in parallel, so the kernels' sequential
    W-axis accumulation must never compile there: 'auto' demotes to the
    fully-XLA-compiled jnp path, forced True keeps the interpret referee."""
    assert ops._COMPILED_BACKENDS == ("tpu",)
    for backend in ("gpu", "cuda", "rocm"):
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        assert ops._resolve("auto") == (True, True)
        assert describe_dispatch("auto", n=1000, k=1) == "jnp"
        assert describe_dispatch("auto", n=1000, k=16) == "jnp"
        assert describe_dispatch(True, n=1000, k=16) == "pallas:interpret:fused"


def test_vmem_block_bytes_padding():
    """VMEM tiles the two minor dims to (8 sublane, 128 lane): a K=1 column
    occupies 128 lanes per row, which the unpadded n*k*itemsize model
    under-counted by 128x."""
    assert spmv.vmem_block_bytes((1000, 1), 4) == 1000 * 128 * 4
    assert spmv.vmem_block_bytes((32, 100, 16), 4) == 32 * 104 * 128 * 4
    # aligned shapes pad to themselves
    assert spmv.vmem_block_bytes((256, 8, 128), 4) == 256 * 8 * 128 * 4


def test_fused_gate_uses_padded_bytes():
    """The fused K=1 gate must admit only frontiers whose PADDED footprint
    fits — n rows cost n*512 bytes in f32, not n*4."""
    limit = ops.FUSED_X_BYTES_LIMIT
    n_fits = limit // (128 * 4)  # padded bytes land exactly on the limit
    assert ops._fused_fits(n_fits, 1, 4)
    assert not ops._fused_fits(n_fits + 8, 1, 4)
    # the old unpadded model would have admitted that frontier easily
    assert (n_fits + 8) * 1 * 4 < limit


def test_batch_tiles_respect_padded_budget():
    """Auto-shrunk [tr, tw, K] tiles fit TILE_BYTES_BUDGET under the padded
    model (or sit at the (SUBLANE, LANE) floor, the smallest legal tile)."""
    for (R, W, K) in [(512, 1024, 1), (512, 1024, 16), (64, 256, 4)]:
        tr, tw = spmv._batch_tiles(R, W, K, 4)
        at_floor = tr <= min(R, spmv.SUBLANE) and tw <= min(W, spmv.LANE)
        assert (spmv.vmem_block_bytes((tr, tw, K), 4)
                <= spmv.TILE_BYTES_BUDGET) or at_floor


@pytest.mark.parametrize("semiring", EXACT_SEMIS)
def test_ops_batch_paths_agree_bitwise(semiring):
    """Public ell_spmv_batch: forced-Pallas (fused), forced-jnp, and auto all
    agree bitwise on exact semirings."""
    rng = np.random.default_rng(17)
    cols, vals, x, row_map = _make_batch(rng, 500, 32, 128, 4)
    args = (jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
            jnp.asarray(row_map), 32, semiring)
    outs = [np.asarray(ell_spmv_batch(*args, use_pallas=up))
            for up in (True, False, "auto")]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])
