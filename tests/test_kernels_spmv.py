"""Pallas kernel sweeps vs the pure-jnp oracle (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.core.semiring import SEMIRINGS
from repro.kernels.spmv import ref
from repro.kernels.spmv.ops import ell_fold, ell_gather_fold, ell_spmv

SEMIS = list(SEMIRINGS)
SHAPES = [(8, 128), (64, 256), (256, 128), (512, 640)]
DTYPES = [np.float32, np.dtype("bfloat16")]


def _make(rng, n, R, W, dtype):
    cols = rng.integers(-1, n, size=(R, W)).astype(np.int32)
    vals = rng.random((R, W)).astype(np.float32).astype(dtype)
    x = (rng.random(n).astype(np.float32) + 0.1).astype(dtype)
    row_map = np.sort(rng.integers(0, max(R // 2, 1), size=R)).astype(np.int32)
    return cols, vals, x, row_map


@pytest.mark.parametrize("semiring", SEMIS)
@pytest.mark.parametrize("shape", SHAPES)
def test_ell_spmv_vs_ref(semiring, shape):
    R, W = shape
    rng = np.random.default_rng(R * W)
    cols, vals, x, row_map = _make(rng, 1000, R, W, np.float32)
    out = ell_spmv(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                   jnp.asarray(row_map), R, semiring, use_pallas=True)
    want = ref.ell_spmv_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                            jnp.asarray(row_map), R, semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("semiring", SEMIS)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_ell_fold_dtypes(semiring, dtype):
    rng = np.random.default_rng(3)
    cols, vals, x, _ = _make(rng, 300, 64, 256, dtype)
    xg = x[np.where(cols >= 0, cols, 0)]
    out = ell_fold(jnp.asarray(xg), jnp.asarray(vals), jnp.asarray(cols),
                   semiring, use_pallas=True)
    want = ref.ell_fold_ref(jnp.asarray(xg), jnp.asarray(vals), jnp.asarray(cols),
                            semiring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype != np.float32 else 1e-6)


@pytest.mark.parametrize("semiring", SEMIS)
def test_ell_gather_fold_vs_ref(semiring):
    rng = np.random.default_rng(9)
    VB = 512
    cols, vals, x, _ = _make(rng, VB, 128, 384, np.float32)
    out = ell_gather_fold(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                          semiring, use_pallas=True)
    want = ref.ell_gather_fold_ref(jnp.asarray(x), jnp.asarray(cols),
                                   jnp.asarray(vals), semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@given(st.integers(0, 10_000), st.sampled_from(SEMIS))
@settings(max_examples=20, deadline=None)
def test_property_random_small(seed, semiring):
    rng = np.random.default_rng(seed)
    R = 8 * rng.integers(1, 5)
    W = 128 * rng.integers(1, 3)
    cols, vals, x, row_map = _make(rng, int(rng.integers(2, 500)), R, W, np.float32)
    out = ell_spmv(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                   jnp.asarray(row_map), R, semiring, use_pallas=True)
    want = ref.ell_spmv_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                            jnp.asarray(row_map), R, semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_all_masked_rows_give_identity():
    for semiring in SEMIS:
        sem = SEMIRINGS[semiring]
        cols = jnp.full((8, 128), -1, jnp.int32)
        vals = jnp.zeros((8, 128), jnp.float32)
        x = jnp.ones((16,), jnp.float32)
        out = ell_spmv(x, cols, vals, jnp.zeros((8,), jnp.int32), 8, semiring,
                       use_pallas=True)
        assert np.asarray(out)[1:].tolist() == [sem.identity] * 7
