"""GraphDelta subsystem: mutable overlay, epochs, compaction, incremental.

The contract under test (ISSUE 6):

  * a run over ``DeltaGraphStore(base) + apply(edits)`` is bitwise-identical
    (min-propagation apps) to a run over a freshly preprocessed graph holding
    the merged edge set — across every storage backend, cache mode, and
    prefetch depth;
  * epoch-grained invalidation: mutating one shard drops exactly that
    shard's cache entry (``stale_drops``), clean shards stay resident, and
    the serve memo survives a mutation for incremental-capable apps;
  * ``compact()`` folds only dirty shards into the base; a reopened store is
    indistinguishable from a fresh preprocess of the merged edges;
  * ``run_incremental`` continues a previous fixpoint after monotone deltas
    in fewer iterations and fewer disk bytes than a cold run, and falls back
    to a cold run whenever the shortcut would be unsound (deletes, weight
    increases, unconverged prev, non-incremental apps);
  * a mid-run mutation raises ``ConcurrentMutationError`` (the engine pins
    the epoch at run start) instead of mixing epochs into one result.
"""
import threading

import numpy as np
import pytest

from repro.graph.compact import compact
from repro.graph.delta import (DeltaBudgetError, DeltaGraphStore,
                               _ell_to_csr_triples)
from repro.graph.preprocess import preprocess_graph
from repro.graph.source import ConcurrentMutationError, graph_token
from repro.graph.storage import GraphStore, write_edge_list
from repro.session import GraphSession

from tests._hypo import HAVE_HYPOTHESIS, given, settings, st

try:
    import networkx as nx
except ImportError:  # pragma: no cover - exercised on minimal installs
    nx = None

needs_networkx = pytest.mark.skipif(nx is None,
                                    reason="networkx not installed")

N = 384
# 1 seed vertex / N = 0.0026 must still trigger selective scheduling
THRESH = 0.05


# ---------------------------------------------------------------------------
# graph construction helpers
# ---------------------------------------------------------------------------
def _random_edges(seed, n=N, m=2000, symmetric=False):
    """Deduplicated random (src, dst, weight) arrays, no self-loops."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    _, idx = np.unique(dst.astype(np.int64) * n + src, return_index=True)
    src, dst = src[idx], dst[idx]
    w = rng.uniform(0.5, 2.0, src.size).astype(np.float32)
    return src.astype(np.int64), dst.astype(np.int64), w


def _fresh_inserts(seed, src, dst, n=N, count=50, symmetric=False):
    """``count`` (s, d, w) triples absent from the given edge set."""
    rng = np.random.default_rng(seed + 7)
    have = set(zip(src.tolist(), dst.tolist()))
    out = []
    while len(out) < count:
        s, d = int(rng.integers(0, n)), int(rng.integers(0, n))
        if s == d or (s, d) in have:
            continue
        w = float(rng.uniform(0.5, 2.0))
        have.add((s, d))
        out.append((s, d, w))
        if symmetric and (d, s) not in have:
            have.add((d, s))
            out.append((d, s, w))
    return out


def _preprocess(tmp, name, src, dst, w, n=N, threshold=512, width=64):
    e, g = tmp / f"el_{name}", tmp / f"g_{name}"
    write_edge_list(e, [(src, dst)], weighted=True)
    np.save(e / "weights_00000.npy", np.asarray(w, dtype=np.float32))
    preprocess_graph(e, g, threshold_edge_num=threshold, ell_max_width=width,
                     num_vertices=n)
    return g


def _merged(src, dst, w, inserts):
    ins = np.array(inserts, dtype=np.float64)
    return (np.concatenate([src, ins[:, 0].astype(np.int64)]),
            np.concatenate([dst, ins[:, 1].astype(np.int64)]),
            np.concatenate([w, ins[:, 2].astype(np.float32)]))


@pytest.fixture(scope="module")
def graphs(tmp_path_factory):
    """(base_dir, merged_dir, base edges, inserts) shared across tests that
    only READ the base directory (every mutation happens in an overlay)."""
    tmp = tmp_path_factory.mktemp("delta_graphs")
    src, dst, w = _random_edges(0)
    inserts = _fresh_inserts(0, src, dst)
    base = _preprocess(tmp, "base", src, dst, w)
    ms, md, mw = _merged(src, dst, w, inserts)
    merged = _preprocess(tmp, "merged", ms, md, mw)
    return base, merged, (src, dst, w), inserts


# ---------------------------------------------------------------------------
# overlay == pre-merged, across backends / cache modes / prefetch depths
# ---------------------------------------------------------------------------
MATRIX = [pytest.param(b, d, m, id=f"{b}-depth{d}-mode{m}")
          for b in ("npz", "packed", "memory")
          for d, m in ((0, "auto"), (2, "auto"), (0, 0))]


@pytest.mark.parametrize("backend,depth,mode", MATRIX)
def test_overlay_matches_premerged(graphs, backend, depth, mode):
    base, merged, _, inserts = graphs
    with GraphSession(merged, selective_threshold=THRESH) as ref, \
            GraphSession(base, backend=backend, mutable=True,
                         prefetch_depth=depth, cache_mode=mode,
                         selective_threshold=THRESH) as sess:
        assert isinstance(sess.store, DeltaGraphStore)
        sess.apply_mutations(inserts=inserts)
        assert sess.store.epoch() == 1
        for app, kw in (("sssp", {"source": 0}), ("bfs", {"source": 0}),
                        ("cc", {})):
            got = sess.run(app, **kw).values
            want = ref.run(app, **kw).values
            assert np.array_equal(got, want), app  # bitwise, not just close
        pr = sess.run("pagerank", max_iters=15).values
        pr_ref = ref.run("pagerank", max_iters=15).values
        np.testing.assert_allclose(pr, pr_ref, atol=1e-6)
        assert sess.store.num_edges == ref.store.num_edges


def test_noop_upsert_preserves_content_and_size(graphs):
    """Re-inserting an existing edge with its existing weight yields the
    same edge set, ELL shape and canonical blob size (the edge may move to
    the end of its destination row, so raw bytes are not compared)."""
    base, _, (src, dst, w), _ = graphs
    store = DeltaGraphStore(GraphStore(base))
    before = store.read_shard(0)
    edges_before = sorted(zip(*_ell_to_csr_triples(before)))
    nbytes_before = store.shard_nbytes(0)
    iv = store.intervals
    sel = (dst >= iv[0]) & (dst < iv[1])
    i = int(np.nonzero(sel)[0][0])
    store.apply(inserts=[(int(src[i]), int(dst[i]), float(w[i]))])
    assert store.dirty_shards() == [0]
    after = store.read_shard(0)
    assert sorted(zip(*_ell_to_csr_triples(after))) == edges_before
    assert after.shape == before.shape
    assert store.shard_nbytes(0) == nbytes_before


def test_upsert_collapses_and_updates_weight(tmp_path):
    src = np.array([0, 1, 2]); dst = np.array([1, 2, 0])
    w = np.array([1.0, 1.0, 1.0], dtype=np.float32)
    g = _preprocess(tmp_path, "tri", src, dst, w, n=3, threshold=8, width=8)
    store = DeltaGraphStore(GraphStore(g))
    store.apply(updates=[(0, 1, 5.0)])  # weight upsert, no new edge
    assert store.num_edges == 3
    _, s, v = _ell_to_csr_triples(store.read_shard(0))
    assert v[s == 0] == pytest.approx([5.0])
    in_deg, out_deg = store.read_vertex_info()
    assert in_deg.tolist() == [1, 1, 1] and out_deg.tolist() == [1, 1, 1]


def test_delete_semantics_and_validation(tmp_path):
    src = np.array([0, 1, 2]); dst = np.array([1, 2, 0])
    w = np.ones(3, dtype=np.float32)
    g = _preprocess(tmp_path, "tri", src, dst, w, n=3, threshold=8, width=8)
    store = DeltaGraphStore(GraphStore(g))
    store.apply(deletes=[(1, 2)])
    assert store.num_edges == 2
    in_deg, out_deg = store.read_vertex_info()
    assert in_deg.tolist() == [1, 1, 0] and out_deg.tolist() == [1, 0, 1]
    # deleting an absent edge is a no-op commit for that key
    e = store.apply(deletes=[(1, 2)])
    assert store.num_edges == 2 and e == store.epoch()
    # in one batch, deletes are applied after inserts: the delete wins
    store.apply(inserts=[(1, 2, 9.0)], deletes=[(1, 2)])
    assert store.num_edges == 2
    store.apply(inserts=[(1, 2, 9.0)])
    assert store.num_edges == 3
    with pytest.raises(ValueError, match="vertex set is fixed"):
        store.apply(inserts=[(0, 99)])


def test_epoch_log_and_monotonicity(tmp_path):
    src, dst, w = _random_edges(3, n=64, m=300)
    g = _preprocess(tmp_path, "mono", src, dst, w, n=64, threshold=128,
                    width=32)
    store = DeltaGraphStore(GraphStore(g))
    assert store.monotone_since(0) and store.epoch() == 0
    ins = _fresh_inserts(3, src, dst, n=64, count=4)
    store.apply(inserts=ins)
    assert store.monotone_since(0) is True
    # lowering an existing weight stays monotone; raising one does not
    s0, d0, w0 = ins[0]
    store.apply(updates=[(s0, d0, w0 / 2)])
    assert store.monotone_since(0) is True
    store.apply(updates=[(s0, d0, w0 * 10)])
    assert store.monotone_since(0) is False
    assert store.monotone_since(store.epoch()) is True  # empty suffix
    affected = store.affected_sources_since(0)
    assert s0 in affected.tolist()
    assert store.affected_sources_since(store.epoch()).size == 0


# ---------------------------------------------------------------------------
# epoch-grained cache invalidation
# ---------------------------------------------------------------------------
def test_cache_retains_clean_shards(tmp_path):
    # many small shards so a single-shard mutation is <10% of the graph
    src, dst, w = _random_edges(5, m=4000)
    g = _preprocess(tmp_path, "many", src, dst, w, threshold=128, width=32)
    with GraphSession(g, mutable=True, selective_threshold=THRESH) as sess:
        P = sess.store.num_shards
        assert P >= 10
        sess.warm()
        rep0 = sess.cache_report()
        assert rep0["hot_shards"] + rep0["cold_shards"] == P
        lo = int(sess.store.intervals[0])
        # force every edit into shard 0 (distinct sources, one destination)
        ins = [(s, lo, wt) for s, _d, wt in _fresh_inserts(5, src, dst,
                                                           count=3)]
        sess.apply_mutations(inserts=ins)
        assert sess.store.dirty_shards() == [0]
        misses0 = sess.stats.misses
        sess.warm()  # re-touch every shard: only the dirty one re-reads
        rep1 = sess.cache_report()
        assert rep1["stale_drops"] == 1
        assert sess.stats.misses - misses0 == 1
        resident1 = rep1["hot_shards"] + rep1["cold_shards"]
        assert resident1 == P  # dirty shard re-admitted after re-read
        # >= 80% of entries survived the mutation (here: all but one)
        assert (P - rep1["stale_drops"]) / P >= 0.8


def test_frozen_store_epoch_and_token(graphs):
    base, _, _, _ = graphs
    store = GraphStore(base)
    assert store.epoch() == 0 and store.shard_epoch(0) == 0
    tok = graph_token(store)
    assert tok[1] == "mtime"  # frozen: falls back to property.json mtime
    overlay = DeltaGraphStore(store)
    assert graph_token(overlay)[1] == "mtime"  # pristine overlay: epoch 0
    overlay.apply(inserts=_fresh_inserts(1, *_random_edges(0)[:2], count=1))
    assert graph_token(overlay) == (str(store.path), "epoch", 1)


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["npz", "packed", "memory"])
def test_compaction_roundtrip(graphs, backend, tmp_path):
    base, merged, (src, dst, w), inserts = graphs
    if backend != "memory":
        # compaction rewrites the base in place: work on a private copy
        import shutil
        priv = tmp_path / "priv"
        shutil.copytree(base, priv)
        base = priv
    with GraphSession(merged, selective_threshold=THRESH) as ref:
        want = ref.run("sssp", source=0).values
        ref_nbytes = [ref.store.shard_nbytes(p)
                      for p in range(ref.store.num_shards)]
        ref_edges = ref.store.num_edges
    with GraphSession(base, backend=backend, mutable=True,
                      selective_threshold=THRESH) as sess:
        sess.apply_mutations(inserts=inserts)
        dirty = sess.store.dirty_shards()
        report = compact(sess.store)
        assert report.shards_rewritten == tuple(dirty)
        assert report.bytes_written > 0
        assert sess.store.dirty_shards() == []
        assert sess.store.delta_nbytes() == 0
        assert sess.store.epoch() == 1  # compaction does NOT bump the epoch
        if backend == "packed":
            # append-only rewrite: superseded segments become dead bytes
            assert report.dead_bytes > 0
        else:
            assert report.dead_bytes == 0
        # the session keeps serving correct results over the compacted base
        assert np.array_equal(sess.run("sssp", source=0).values, want)
        # idempotent: nothing left to fold
        assert compact(sess.store).shards_rewritten == ()
    if backend == "memory":
        return  # RAM-resident: compaction cannot (and must not) touch disk
    with GraphSession(base, backend=backend,
                      selective_threshold=THRESH) as reopened:
        assert np.array_equal(reopened.run("sssp", source=0).values, want)
        assert reopened.store.num_edges == ref_edges
        if backend == "npz":
            # disk-byte accounting matches a fresh pack of the merged graph
            got = [reopened.store.shard_nbytes(p)
                   for p in range(reopened.store.num_shards)]
            assert got == ref_nbytes


def test_delta_budget_autocompact_and_error(graphs, tmp_path):
    import shutil
    base, _, (src, dst, _w), _ = graphs
    priv = tmp_path / "priv"
    shutil.copytree(base, priv)
    ins = _fresh_inserts(9, src, dst, count=4)
    store = DeltaGraphStore(GraphStore(priv), delta_budget_bytes=1,
                            auto_compact=True)
    store.apply(inserts=ins[:2])
    assert store.dirty_shards() == []  # budget blown -> auto-compacted
    assert store.epoch() == 1
    frozen = DeltaGraphStore(GraphStore(priv), delta_budget_bytes=1,
                             auto_compact=False)
    with pytest.raises(DeltaBudgetError):
        frozen.apply(inserts=ins[2:])


# ---------------------------------------------------------------------------
# incremental recompute
# ---------------------------------------------------------------------------
@needs_networkx
def test_incremental_sssp_matches_networkx(graphs):
    base, _, (src, dst, w), inserts = graphs
    G = nx.DiGraph()
    G.add_nodes_from(range(N))
    G.add_weighted_edges_from(zip(src.tolist(), dst.tolist(),
                                  np.asarray(w, np.float64).tolist()))
    for s, d, wt in inserts:
        G.add_edge(s, d, weight=wt)
    lengths = nx.single_source_dijkstra_path_length(G, 0)
    want = np.full(N, np.inf)
    for v, dist in lengths.items():
        want[v] = dist
    # cache off: per-iteration disk bytes then reflect every shard fetch, so
    # the incremental-vs-cold I/O comparison is honest, not hidden by hits
    with GraphSession(base, mutable=True, selective_threshold=THRESH,
                      cache_budget_bytes=0) as sess:
        prev = sess.run("sssp", source=0)
        assert prev.converged and prev.epoch == 0 and prev.tag == "sssp:(0,)"
        sess.apply_mutations(inserts=inserts)
        inc = sess.run_incremental("sssp", source=0, prev=prev)
        inc_bytes = sum(h.disk_bytes for h in inc.history)
        cold = sess.run("sssp", source=0)
        cold_bytes = sum(h.disk_bytes for h in cold.history)
    np.testing.assert_allclose(inc.values, want, atol=1e-5)
    assert np.array_equal(inc.values, cold.values)
    assert inc.iterations < cold.iterations
    assert inc_bytes < cold_bytes  # frontier-local: fewer shards touched
    assert inc.epoch == 1


@needs_networkx
def test_incremental_cc_matches_networkx(tmp_path):
    # symmetric graph: directed min-label propagation == connected components
    src, dst, w = _random_edges(11, m=600, symmetric=True)
    inserts = _fresh_inserts(11, src, dst, count=20, symmetric=True)
    g = _preprocess(tmp_path, "sym", src, dst, w)
    G = nx.Graph()
    G.add_nodes_from(range(N))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    G.add_edges_from((s, d) for s, d, _ in inserts)
    want = np.empty(N)
    for comp in nx.connected_components(G):
        want[list(comp)] = min(comp)
    with GraphSession(g, mutable=True, selective_threshold=THRESH) as sess:
        prev = sess.run("cc")
        sess.apply_mutations(inserts=inserts)
        inc = sess.run_incremental("cc", prev=prev)
        cold = sess.run("cc")
    assert np.array_equal(inc.values, want)
    assert np.array_equal(inc.values, cold.values)


def test_incremental_fastpath_and_fallbacks(graphs):
    base, _, (src, dst, _w), inserts = graphs
    with GraphSession(base, mutable=True, selective_threshold=THRESH) as sess:
        prev = sess.run("sssp", source=0)
        # unchanged epoch: previous fixpoint returned as-is, zero sweeps
        again = sess.run_incremental("sssp", source=0, prev=prev)
        assert again.iterations == 0 and again.converged
        assert np.array_equal(again.values, prev.values)
        # wrong source: refuse to continue a different query's fixpoint
        with pytest.raises(ValueError, match="incremental recompute"):
            sess.run_incremental("sssp", source=1, prev=prev)
        # a delete breaks monotonicity: falls back to a correct cold run
        sess.apply_mutations(inserts=inserts,
                             deletes=[(int(src[0]), int(dst[0]))])
        assert not sess.store.monotone_since(prev.epoch)
        inc = sess.run_incremental("sssp", source=0, prev=prev)
        cold = sess.run("sssp", source=0)
        assert np.array_equal(inc.values, cold.values)
        # pagerank is not incremental-capable: full run, still correct
        pr_prev = sess.run("pagerank", max_iters=10)
        sess.apply_mutations(inserts=_fresh_inserts(21, src, dst, count=3))
        pr_inc = sess.run_incremental("pagerank", max_iters=10, prev=pr_prev)
        pr_cold = sess.run("pagerank", max_iters=10)
        np.testing.assert_allclose(pr_inc.values, pr_cold.values, atol=1e-6)


# ---------------------------------------------------------------------------
# epoch pinning: mutations cannot tear a running sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 2])
def test_mid_run_mutation_raises(graphs, depth):
    base, _, (src, dst, _w), _ = graphs
    with GraphSession(base, mutable=True, prefetch_depth=depth) as sess:
        gen = sess.iter_run("pagerank", max_iters=10)
        next(gen)  # run is now mid-flight, epoch pinned at 0
        sess.store.apply(inserts=_fresh_inserts(31, src, dst, count=1))
        with pytest.raises(ConcurrentMutationError):
            for _ in gen:
                pass
        # the NEXT run re-syncs to the new epoch and completes fine
        res = sess.run("pagerank", max_iters=5)
        assert res.epoch == 1


# ---------------------------------------------------------------------------
# serving: memo keyed by epoch, apply_mutations drains + refreshes
# ---------------------------------------------------------------------------
def test_service_memo_refresh_across_mutation(graphs):
    base, _, (src, dst, _w), inserts = graphs
    with GraphSession(base, mutable=True, selective_threshold=THRESH) as sess, \
            sess.service(max_batch=4, max_wait_ms=1.0) as svc:
        for s in (0, 1, 2, 3):
            svc.submit("sssp", source=s).result()
        svc.submit("cc").result()
        svc.submit("pagerank").result()
        assert len(svc._memo) == 6
        report = svc.apply_mutations(inserts=inserts)
        assert report.epoch == 1
        assert report.memo_refreshed == 5  # 4 sssp sources + cc
        assert report.memo_dropped == 1    # pagerank: not incremental
        snap = svc.stats.snapshot()
        fut = svc.submit("sssp", source=2)  # must hit the refreshed memo
        got = fut.result().values
        assert svc.stats.snapshot()["memo_hits"] == snap["memo_hits"] + 1
        assert np.array_equal(got, sess.run("sssp", source=2).values)


def test_service_mutation_under_concurrent_traffic(graphs):
    base, _, (src, dst, _w), inserts = graphs
    with GraphSession(base, mutable=True, selective_threshold=THRESH) as sess, \
            sess.service(max_batch=4, max_wait_ms=0.5) as svc:
        errors, stop = [], threading.Event()

        def hammer():
            i = 0
            while not stop.is_set():
                try:
                    svc.submit("sssp", source=i % 8).result(timeout=60)
                except Exception as exc:  # noqa: BLE001 - recorded for assert
                    errors.append(exc)
                i += 1

        threads = [threading.Thread(target=hammer) for _ in range(3)]
        for t in threads:
            t.start()
        try:
            for i in range(0, len(inserts), 10):
                svc.apply_mutations(inserts=inserts[i:i + 10])
        finally:
            stop.set()
            for t in threads:
                t.join()
        assert not errors  # no request ever saw a torn or stale graph
        want = sess.run("sssp", source=0).values
        got = svc.submit("sssp", source=0).result().values
        assert np.array_equal(got, want)
    # every mutation landed: final state equals the fully merged graph
    assert sess.store.epoch() == 5


# ---------------------------------------------------------------------------
# property test: overlay edge set == brute-force dict model
# ---------------------------------------------------------------------------
_HN = 48  # tiny graph: the property test runs many examples


def _store_edge_dict(store):
    out = {}
    for p in range(store.num_shards):
        shard = store.read_shard(p)
        local, s, v = _ell_to_csr_triples(shard)
        for li, si, vi in zip(local + shard.start_vertex, s, v):
            out[(int(si), int(li))] = float(np.float32(vi))
    return out


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_apply_matches_dict_model(tmp_path_factory, data):
    tmp = tmp_path_factory.mktemp("hypo")
    src, dst, w = _random_edges(17, n=_HN, m=160)
    g = _preprocess(tmp, "h", src, dst, w, n=_HN, threshold=64, width=16)
    store = DeltaGraphStore(GraphStore(g))
    model = {(int(s), int(d)): float(np.float32(x))
             for s, d, x in zip(src, dst, w)}
    vertex = st.integers(0, _HN - 1)
    edge = st.tuples(vertex, vertex).filter(lambda e: e[0] != e[1])
    weight = st.floats(0.25, 4.0, width=32)
    for _ in range(data.draw(st.integers(1, 4))):
        ins = data.draw(st.lists(st.tuples(edge, weight), max_size=12))
        dels = data.draw(st.lists(edge, max_size=6))
        store.apply(inserts=[(s, d, x) for (s, d), x in ins],
                    deletes=dels)
        # replay with last-edit-wins order: inserts first, then deletes
        for (s, d), x in ins:
            model[(s, d)] = float(np.float32(x))
        for s, d in dels:
            model.pop((s, d), None)
        assert _store_edge_dict(store) == model
        assert store.num_edges == len(model)
        in_deg, out_deg = store.read_vertex_info()
        for v in range(_HN):
            assert out_deg[v] == sum(1 for k in model if k[0] == v)
            assert in_deg[v] == sum(1 for k in model if k[1] == v)
