"""Training substrate: optimizers, schedule, checkpointing, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import (OptConfig, lr_at, make_init_state, make_train_step)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.train_step import ef_compress_grads, init_ef, quantize_int8
from repro.models.nn import Param


def _setup(opt_name="adamw", grad_compression=False, peak_lr=3e-3):
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    opt = OptConfig(name=opt_name, peak_lr=peak_lr, warmup_steps=5, decay_steps=200)
    state = make_init_state(m, opt, grad_compression=grad_compression)(
        jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, opt, grad_compression=grad_compression))
    data = SyntheticLM(cfg.vocab_size, 32, 8)
    return cfg, state, step, data


def _run(state, step, data, n, cycle=4):
    losses = []
    for s in range(n):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s % cycle).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def test_overfit_small_batch_adamw():
    _, state, step, data = _setup()
    _, losses = _run(state, step, data, 40)
    assert losses[-1] < losses[0] - 1.5, (losses[0], losses[-1])


def test_overfit_adafactor():
    _, state, step, data = _setup(opt_name="adafactor", peak_lr=1e-2)
    _, losses = _run(state, step, data, 40)
    assert losses[-1] < losses[0] - 1.5


def test_grad_compression_still_learns():
    _, state, step, data = _setup(grad_compression=True)
    _, losses = _run(state, step, data, 40)
    assert losses[-1] < losses[0] - 1.5


def test_lr_schedule_shape():
    opt = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(opt, 0)) == 0.0
    assert abs(float(lr_at(opt, 10)) - 1.0) < 1e-6
    assert float(lr_at(opt, 5)) == 0.5
    assert float(lr_at(opt, 110)) <= 0.11
    assert float(lr_at(opt, 500)) >= 0.0999


def test_quantize_int8_error_feedback_converges():
    """EF ensures the *accumulated* compressed signal tracks the true one."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32) * 0.01
    grads = {"w": Param(g, (None, None))}
    ef = init_ef(grads)
    total = jnp.zeros_like(g)
    for _ in range(50):
        cg, ef = ef_compress_grads(grads, ef)
        total = total + cg["w"].value
    want = g * 50
    rel = float(jnp.abs(total - want).max() / jnp.abs(want).max())
    assert rel < 0.05, rel


def test_quantize_int8_range():
    q, s = quantize_int8(jnp.asarray([-3.0, 0.0, 3.0]))
    assert q.dtype == jnp.int8
    assert int(q[0]) == -127 and int(q[2]) == 127


def test_checkpoint_atomicity_and_gc(tmp_path):
    cfg, state, step, data = _setup()
    ck = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": np.arange(s)}, sync=True)
    files = sorted(p.name for p in tmp_path.glob("step_*.npz"))
    assert files == ["step_00000003.npz", "step_00000004.npz"]
    assert ck.latest_step() == 4
    assert not list(tmp_path.glob(".tmp_*"))


def test_checkpoint_resume_training_equivalence(tmp_path):
    cfg, state, step, data = _setup()
    state, _ = _run(state, step, data, 10)
    ck = CheckpointManager(tmp_path)
    ck.save(10, state, sync=True)
    restored, s0 = ck.restore(jax.eval_shape(lambda: state))
    assert s0 == 10
    sA, lA = _run(state, step, data, 5)
    sB, lB = _run(restored, step, data, 5)
    np.testing.assert_allclose(lA, lB, rtol=1e-5)


def test_checkpoint_async_save(tmp_path):
    ck = CheckpointManager(tmp_path)
    ck.save(7, {"x": np.ones(10)})
    ck.wait()
    assert ck.latest_step() == 7


def test_data_pipeline_determinism_and_prefetch():
    d1 = SyntheticLM(1000, 16, 4, host_id=3)
    d2 = SyntheticLM(1000, 16, 4, host_id=3)
    b1, b2 = d1.get_batch(42), d2.get_batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different hosts get different streams
    d3 = SyntheticLM(1000, 16, 4, host_id=4)
    assert not np.array_equal(d3.get_batch(42)["tokens"], b1["tokens"])
    pf = Prefetcher(d1, start_step=0, depth=2)
    try:
        first = pf.next()
        np.testing.assert_array_equal(first["tokens"], d2.get_batch(0)["tokens"])
    finally:
        pf.close()
