"""Per-arch smoke tests (required deliverable f): every assigned architecture
instantiates at REDUCED scale, runs one forward/train step on CPU, asserts
output shapes + no NaNs; plus prefill/decode consistency against the
teacher-forced forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model, layer_groups, padded_vocab
from repro.train import OptConfig, make_init_state, make_train_step


def make_batch(cfg, B=2, S=16, seed=0, with_targets=True):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if with_targets:
        batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.stub_frames, cfg.d_model)), jnp.float32)
    if cfg.modality_stub == "image_patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.img_patches, cfg.d_model)), jnp.float32)
        St = S + cfg.img_patches
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(St)[None, :, None], (B, St, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    state = make_init_state(m, OptConfig(warmup_steps=1, decay_steps=10))(
        jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, OptConfig(warmup_steps=1, decay_steps=10)))
    batch = make_batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params updated, shapes preserved
    before = jax.tree_util.tree_leaves(state.params)
    after = jax.tree_util.tree_leaves(new_state.params)
    assert all(a.shape == b.shape for a, b in zip(before, after))
    assert any(not np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
               for a, b in zip(before, after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S + 1, with_targets=False)
    extra = cfg.img_patches if cfg.modality_stub == "image_patches" else 0

    enc_out = m._encode(params, batch) if cfg.is_encdec else None
    x, positions = m._embed_inputs(params, batch)
    x, _, _ = m._run_groups(params, x, positions, enc_out=enc_out)
    ref = m._logits(params, x)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S]
    if extra:
        pre["positions"] = batch["positions"][:, : S + extra]
    logits0, caches, enc = m.prefill(params, pre, cache_len=S + 1 + extra)
    np.testing.assert_allclose(np.asarray(logits0[:, 0]),
                               np.asarray(ref[:, S - 1 + extra]), atol=2e-2)
    logits1, _ = m.decode_step(params, caches, batch["tokens"][:, S : S + 1],
                               jnp.asarray(S + extra, jnp.int32), enc_out=enc)
    np.testing.assert_allclose(np.asarray(logits1[:, 0]),
                               np.asarray(ref[:, S + extra]), atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_structure(arch):
    """FULL config structural checks (no allocation — abstract init only)."""
    cfg = get_config(arch)
    m = build_model(cfg)
    params = m.abstract_params()
    n = m.param_count(params)
    expected = {
        "gemma-2b": (2e9, 4e9), "starcoder2-7b": (6e9, 9e9),
        "minitron-4b": (3.5e9, 6e9), "stablelm-1.6b": (1.2e9, 2.2e9),
        "jamba-v0.1-52b": (40e9, 65e9), "seamless-m4t-large-v2": (1.3e9, 3e9),
        "mixtral-8x22b": (120e9, 160e9), "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "qwen2-vl-72b": (60e9, 85e9), "xlstm-1.3b": (1.0e9, 2.0e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"
    # layer-group structure covers exactly num_layers
    total = sum(len(unit) * rep for unit, rep in layer_groups(cfg))
    assert total == cfg.num_layers
    assert padded_vocab(cfg) % 2048 == 0


def test_vocab_padding_math():
    cfg = get_config("seamless-m4t-large-v2")
    assert padded_vocab(cfg) >= cfg.vocab_size
    assert padded_vocab(cfg) % 16 == 0  # 16-way vocab sharding divides


def test_sliding_window_ring_buffer_matches_full_cache():
    """SWA decode through the ring buffer == decode with a full cache when the
    window covers everything."""
    cfg = dataclasses.replace(get_config("mixtral-8x22b").reduced(),
                              sliding_window=64)
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 1, 24
    batch = make_batch(cfg, B=B, S=S + 4, with_targets=False)
    x, positions = m._embed_inputs(params, batch)
    xx, _, _ = m._run_groups(params, x, positions)
    ref = m._logits(params, xx)
    pre = {"tokens": batch["tokens"][:, :S]}
    logits, caches, _ = m.prefill(params, pre, cache_len=S + 4)
    for i in range(4):
        logits, caches = m.decode_step(params, caches,
                                       batch["tokens"][:, S + i : S + i + 1],
                                       jnp.asarray(S + i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref[:, S + i]), atol=2e-2)
