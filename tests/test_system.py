"""End-to-end behaviour tests for the GraphMP engine (the paper's system).

Validates the three paper claims at test scale, through the ``GraphSession``
public API:
  * VSW produces exactly the same fixpoints as straight numpy/networkx
    oracles for PR/SSSP/CC/BFS (Algorithm 2+3 correctness);
  * selective scheduling (Bloom-gated shard skipping) changes I/O, never
    results (§2.4.1's safety argument: no false negatives);
  * the compressed edge cache changes disk-byte counts, never results, and
    honours its budget (§2.4.2).
"""
import numpy as np
import pytest

from repro.core import apps
from repro.core.engine import EngineConfig, VSWEngine, latest_checkpoint
from repro.session import GraphSession
from tests.conftest import min_propagation_oracle, pagerank_oracle


def test_pagerank_matches_oracle(graph_store, small_graph):
    src, dst, _ = small_graph
    n = graph_store.num_vertices
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 26)
    res = sess.run("pagerank", max_iters=30)
    oracle = pagerank_oracle(src, dst, n, iters=30)
    np.testing.assert_allclose(res.values, oracle, atol=1e-6)
    assert abs(res.values.sum() - oracle.sum()) < 1e-3


def test_sssp_matches_networkx(graph_store, small_graph):
    import networkx as nx
    src, dst, _ = small_graph
    n = graph_store.num_vertices
    sess = GraphSession(graph_store, cache_mode=1)
    res = sess.run("sssp", source=0, max_iters=200)
    assert res.converged
    G = nx.DiGraph()
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    dist = nx.single_source_shortest_path_length(G, 0)
    ref = np.full(n, np.inf)
    for k, v in dist.items():
        ref[k] = v
    both = np.where(np.isinf(ref), -1, ref) == np.where(np.isinf(res.values), -1,
                                                        res.values)
    assert both.all()


def test_cc_matches_fixpoint(graph_store, small_graph):
    src, dst, _ = small_graph
    n = graph_store.num_vertices
    sess = GraphSession(graph_store, cache_mode=0)
    res = sess.run("cc", max_iters=300)
    assert res.converged
    oracle = min_propagation_oracle(src, dst, n, np.arange(n), iters=300)
    np.testing.assert_array_equal(res.values, oracle)


def test_selective_scheduling_is_lossless(tmp_path):
    """SS on vs off: identical results; SS must actually skip shards.

    Uses a path graph (0→1→…→n-1): the SSSP frontier is one vertex per
    iteration, so Bloom filters can prove most shards inactive — the regime
    the paper's §2.4.1 targets.  (On a tiny dense RMAT graph every shard has
    in-edges from nearly every vertex and nothing is skippable.)"""
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import write_edge_list
    n = 4096
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    write_edge_list(tmp_path / "el", [(src, dst)], num_vertices=n)
    store = preprocess_graph(str(tmp_path / "el"), str(tmp_path / "g"),
                             threshold_edge_num=256)
    on = GraphSession(store, selective_threshold=1e-3)
    off = GraphSession(store, selective_threshold=-1.0)
    r_on = on.run("sssp", source=0, max_iters=60)
    r_off = off.run("sssp", source=0, max_iters=60)
    np.testing.assert_array_equal(r_on.values, r_off.values)
    assert sum(h.shards_skipped for h in r_on.history) > 0
    assert sum(h.shards_skipped for h in r_off.history) == 0
    # skipped shards must not be counted as processed edges
    assert r_on.total_edges_processed < r_off.total_edges_processed
    # the frontier walks the path: distance k is exactly k where reached
    reached = np.isfinite(r_on.values)
    np.testing.assert_array_equal(r_on.values[reached],
                                  np.arange(n)[reached])


@pytest.mark.parametrize("mode", [0, 1, 2, 3, 4])
def test_cache_modes_are_lossless(graph_store, mode):
    sess = GraphSession(graph_store, cache_mode=mode,
                        cache_budget_bytes=1 << 24)
    res = sess.run("cc", max_iters=50)
    base = GraphSession(graph_store, cache_mode=0).run("cc", max_iters=50)
    np.testing.assert_array_equal(res.values, base.values)
    if mode > 0:
        assert sess.stats.hits > 0


def test_cache_reduces_disk_bytes(graph_store):
    miss = GraphSession(graph_store, cache_mode=0)
    hit = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 28)
    miss.run("pagerank", max_iters=5)
    hit.run("pagerank", max_iters=5)
    assert hit.stats.disk_bytes < miss.stats.disk_bytes


def test_checkpoint_resume_equivalence(graph_store, tmp_path):
    """Kill-and-resume yields the same fixpoint as an uninterrupted run."""
    r_full = GraphSession(graph_store).run("pagerank", max_iters=20)
    part = GraphSession(graph_store)
    part.run("pagerank", max_iters=10,
             checkpoint_dir=str(tmp_path), checkpoint_every=5)
    assert latest_checkpoint(str(tmp_path)) is not None
    resumed = GraphSession(graph_store)
    r2 = resumed.run("pagerank", max_iters=20,
                     checkpoint_dir=str(tmp_path), resume=True)
    np.testing.assert_allclose(r2.values, r_full.values, atol=1e-6)


def test_preprocess_once_run_many(graph_store):
    """The paper's reuse property: one session serves all applications."""
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 28)
    results = sess.run_many(
        ["pagerank", ("sssp", {"source": 0}), "cc", ("bfs", {"source": 0})],
        max_iters=10)
    assert len(results) == 4
    for res in results:
        assert np.isfinite(res.values[np.isfinite(res.values)]).all()


def test_legacy_engine_shim_still_works(graph_store):
    """The pre-session VSWEngine kwarg signature warns but still runs."""
    with pytest.warns(DeprecationWarning):
        eng = VSWEngine(graph_store, apps.cc(), cache_mode=1,
                        cache_budget_bytes=1 << 24)
    res = eng.run(max_iters=50)
    base = GraphSession(graph_store, cache_mode=0).run("cc", max_iters=50)
    np.testing.assert_array_equal(res.values, base.values)


def test_engine_from_explicit_config(graph_store):
    cfg = EngineConfig(cache_mode=2, cache_budget_bytes=1 << 24,
                       selective_threshold=1e-3)
    eng = VSWEngine(graph_store, apps.cc(), cfg)
    res = eng.run(max_iters=50)
    assert res.converged
    assert eng.config == cfg
