"""ShardPipeline mechanics + thread-safety of the cache and byte counters.

The pipeline's deterministic contract: shards are delivered in schedule
order at every depth, a failing fetch surfaces in the consumer, an early
consumer exit reaps the worker, and concurrent ``cache.get`` hammering
leaves every counter exactly right (the satellite regression: stats drifted
when BytesCounter/CacheStats updates raced).
"""
import threading

import numpy as np
import pytest

from repro.core.cache import CompressedShardCache
from repro.core.engine import EngineConfig
from repro.core.pipeline import ShardPipeline
from repro.core.shards import ELLShard
from repro.graph.source import BytesCounter

from _hypo import given, settings, st


def _fake_shard(p: int) -> ELLShard:
    cols = np.full((8, 4), -1, dtype=np.int32)
    return ELLShard(shard_id=p, start_vertex=0, end_vertex=8, nnz=0,
                    cols=cols, vals=np.zeros((8, 4), np.float32),
                    row_map=np.zeros(8, np.int32))


# ---------------------------------------------------------------------------
# ordering + staging
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 1, 2, 4, 16])
def test_stream_preserves_schedule_order(depth):
    schedule = [3, 0, 2, 2, 5, 1]
    fetched = []

    def fetch(p):
        fetched.append(p)
        return _fake_shard(p)

    pipe = ShardPipeline(fetch, depth=depth, stage=lambda s: s.shard_id * 10)
    out = list(pipe.stream(schedule))
    assert [p for p, _, _ in out] == schedule
    assert fetched == schedule  # fetch order == schedule order (determinism)
    assert [staged for _, _, staged in out] == [p * 10 for p in schedule]
    assert pipe.stats.shards == len(schedule)
    assert pipe.stats.fetch_seconds >= 0.0


@pytest.mark.parametrize("depth", [0, 2])
def test_stream_empty_schedule(depth):
    pipe = ShardPipeline(_fake_shard, depth=depth)
    assert list(pipe.stream([])) == []


@given(st.lists(st.integers(0, 9), max_size=30), st.integers(0, 6))
@settings(deadline=None, max_examples=25)
def test_stream_order_property(schedule, depth):
    pipe = ShardPipeline(_fake_shard, depth=depth)
    got = [(p, s.shard_id) for p, s, _ in pipe.stream(schedule)]
    assert got == [(p, p) for p in schedule]


def test_fetch_error_reaches_consumer():
    def fetch(p):
        if p == 2:
            raise OSError("shard 2 unreadable")
        return _fake_shard(p)

    for depth in (0, 1, 3):
        pipe = ShardPipeline(fetch, depth=depth)
        seen = []
        with pytest.raises(OSError, match="shard 2"):
            for p, _, _ in pipe.stream([0, 1, 2, 3]):
                seen.append(p)
        assert seen == [0, 1]  # everything before the failure was delivered


def test_consumer_early_exit_reaps_worker():
    fetched = []

    def fetch(p):
        fetched.append(p)
        return _fake_shard(p)

    pipe = ShardPipeline(fetch, depth=1,
                         nbytes=lambda s: s.decoded_nbytes())
    for p, _, _ in pipe.stream(list(range(100))):
        if p == 3:
            break
    # worker stopped promptly: it ran at most a couple past the break point
    assert len(fetched) <= 8
    assert threading.active_count() < 20  # no leaked prefetch threads
    # abandoned queued shards were de-charged: nothing is in flight anymore
    assert pipe.stats.staged_bytes == 0
    assert pipe.stats.staged_peak_bytes > 0


def test_negative_depth_rejected():
    with pytest.raises(ValueError, match="depth"):
        ShardPipeline(_fake_shard, depth=-1)
    with pytest.raises(ValueError):
        EngineConfig(prefetch_depth=-2)
    with pytest.raises(ValueError):
        EngineConfig(prefetch_depth=True)


def test_prefetch_env_override(monkeypatch):
    monkeypatch.setenv("GRAPHMP_PREFETCH", "3")
    assert EngineConfig.from_env().prefetch_depth == 3
    assert EngineConfig.from_env(prefetch_depth=1).prefetch_depth == 1


# ---------------------------------------------------------------------------
# stall accounting flows into IterationStats
# ---------------------------------------------------------------------------
def test_engine_reports_stall_and_fetch_seconds(graph_store):
    from repro.session import GraphSession
    sess = GraphSession(graph_store, cache_mode=1, prefetch_depth=1)
    res = sess.run("pagerank", max_iters=3)
    for h in res.history:
        assert h.stall_seconds >= 0.0
        assert h.fetch_seconds > 0.0  # fetch+stage always does real work


# ---------------------------------------------------------------------------
# thread-safety regression: 8 threads hammer cache.get
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [0, 1, 2, "adaptive"])
def test_cache_get_is_thread_safe(graph_store, mode):
    from repro.graph.storage import GraphStore
    store = GraphStore(graph_store.path)  # private io counters
    cache = CompressedShardCache(store, mode=mode, budget_bytes=1 << 28)
    P = store.num_shards
    per_thread = 40
    threads_n = 8
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for sid in rng.integers(0, P, size=per_thread):
                shard = cache.get(int(sid))
                assert shard.shard_id == int(sid)
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(threads_n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = threads_n * per_thread
    assert cache.stats.hits + cache.stats.misses == total
    if mode == 0:
        # uncached: every access is a miss charged at canonical nbytes
        assert cache.stats.misses == total
        assert cache.stats.disk_bytes == store.io.read
    else:
        # big budget, no evictions: exactly one miss per distinct shard
        # (adaptive promotions/demotions must not re-read or re-charge)
        assert cache.stats.evictions == 0
        assert cache.stats.misses == P
        assert cache.stats.disk_bytes == sum(
            store.shard_nbytes(p) for p in range(P))
        assert store.io.read == cache.stats.disk_bytes
    assert cache.cached_bytes <= cache.budget


def test_cache_eviction_under_concurrency_keeps_budget(graph_store):
    from repro.graph.storage import GraphStore
    store = GraphStore(graph_store.path)
    budget = max(store.shard_nbytes(0) * 2, 1 << 16)
    cache = CompressedShardCache(store, mode=1, budget_bytes=budget)
    barrier = threading.Barrier(8)

    def hammer(seed):
        rng = np.random.default_rng(seed)
        barrier.wait()
        for sid in rng.integers(0, store.num_shards, size=30):
            cache.get(int(sid))

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cache.cached_bytes <= cache.budget
    assert cache.stats.hits + cache.stats.misses == 8 * 30


def test_bytes_counter_concurrent_adds_are_exact():
    c = BytesCounter()

    def add():
        for _ in range(10_000):
            c.add_read(3)
            c.add_written(2)

    threads = [threading.Thread(target=add) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.read == 8 * 10_000 * 3
    assert c.written == 8 * 10_000 * 2
    c.reset()
    assert (c.read, c.written) == (0, 0)
    # legacy augmented-assignment call sites keep working single-threaded
    c.read += 7
    assert c.read == 7
