"""Serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def _engine(arch="stablelm-1.6b"):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(m, params)


def test_greedy_generation_deterministic():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 12)))}
    t1, _ = eng.generate(batch, num_tokens=8)
    t2, _ = eng.generate(batch, num_tokens=8)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (3, 8)
    assert (t1 >= 0).all()


def test_generation_continues_prefill():
    """Decoded tokens must equal argmax of teacher-forced logits step by step."""
    cfg, eng = _engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, (1, 10))
    batch = {"tokens": jnp.asarray(prompt)}
    toks, _ = eng.generate(batch, num_tokens=3)
    m, params = eng.model, eng.params
    seq = prompt.copy()
    for i in range(3):
        x, positions = m._embed_inputs(params, {"tokens": jnp.asarray(seq)})
        h, _, _ = m._run_groups(params, x, positions)
        logits = m._logits(params, h)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(toks[0, i]), f"step {i}"
        seq = np.concatenate([seq, [[nxt]]], axis=1)


def test_temperature_sampling_varies_with_seed():
    cfg, eng = _engine()
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)))}
    a, _ = eng.generate(batch, num_tokens=16, temperature=1.0, seed=1)
    b, _ = eng.generate(batch, num_tokens=16, temperature=1.0, seed=2)
    assert not np.array_equal(a, b)


def test_batched_requests_independent():
    """Each request in the batch decodes as if it were alone (padding-free
    uniform-length batch)."""
    cfg, eng = _engine()
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (2, 12))
    both, _ = eng.generate({"tokens": jnp.asarray(prompts)}, num_tokens=4)
    solo0, _ = eng.generate({"tokens": jnp.asarray(prompts[:1])}, num_tokens=4)
    np.testing.assert_array_equal(both[:1], solo0)
