"""Fault-tolerance integration: kill a real training process mid-run, resume
from its checkpoints, verify the loss trajectory continues (DESIGN.md §6)."""
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _run_train(ckpt_dir, steps, resume=False, kill_after=None):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "stablelm-1.6b", "--reduced",
           "--steps", str(steps), "--batch", "4", "--seq", "32",
           "--ckpt-dir", str(ckpt_dir), "--ckpt-every", "5",
           "--log-every", "5", "--lr", "3e-3"]
    if resume:
        cmd.append("--resume")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    if kill_after is None:
        return subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                              env=env)
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True, env=env)
    deadline = time.time() + 600
    # wait until at least one checkpoint is published, then SIGTERM
    while time.time() < deadline:
        if (Path(ckpt_dir) / "latest.json").exists():
            break
        time.sleep(0.5)
    time.sleep(kill_after)
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=300)
    return subprocess.CompletedProcess(cmd, proc.returncode, out, err)


def test_kill_and_resume_continues_training(tmp_path):
    ck = tmp_path / "ck"
    r1 = _run_train(ck, steps=40, kill_after=1.0)
    assert (ck / "latest.json").exists(), r1.stderr[-2000:]
    # resume to completion
    r2 = _run_train(ck, steps=40, resume=True)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
    assert "done: 40 steps" in r2.stdout
    if "already complete" not in r2.stdout:
        # loss at the end is finite and lower than a fresh model's ~ln(vocab)
        final = float(r2.stdout.strip().splitlines()[-1].split()[-1])
        assert final < 7.0


def test_uninterrupted_run_completes(tmp_path):
    r = _run_train(tmp_path / "ck2", steps=15)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 15 steps" in r.stdout
