"""Fault-tolerance integration: kill a real training process mid-run, resume
from its checkpoints, verify the loss trajectory continues (DESIGN.md §6) —
plus checkpoint/resume of batched multi-frontier graph runs."""
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_train(ckpt_dir, steps, resume=False, kill_after=None):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "stablelm-1.6b", "--reduced",
           "--steps", str(steps), "--batch", "4", "--seq", "32",
           "--ckpt-dir", str(ckpt_dir), "--ckpt-every", "5",
           "--log-every", "5", "--lr", "3e-3"]
    if resume:
        cmd.append("--resume")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    if kill_after is None:
        return subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                              env=env)
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True, env=env)
    deadline = time.time() + 600
    # wait until at least one checkpoint is published, then SIGTERM
    while time.time() < deadline:
        if (Path(ckpt_dir) / "latest.json").exists():
            break
        time.sleep(0.5)
    time.sleep(kill_after)
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=300)
    return subprocess.CompletedProcess(cmd, proc.returncode, out, err)


def test_kill_and_resume_continues_training(tmp_path):
    ck = tmp_path / "ck"
    r1 = _run_train(ck, steps=40, kill_after=1.0)
    assert (ck / "latest.json").exists(), r1.stderr[-2000:]
    # resume to completion
    r2 = _run_train(ck, steps=40, resume=True)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
    assert "done: 40 steps" in r2.stdout
    if "already complete" not in r2.stdout:
        # loss at the end is finite and lower than a fresh model's ~ln(vocab)
        final = float(r2.stdout.strip().splitlines()[-1].split()[-1])
        assert final < 7.0


def test_uninterrupted_run_completes(tmp_path):
    r = _run_train(tmp_path / "ck2", steps=15)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 15 steps" in r.stdout


# ---------------------------------------------------------------------------
# batched multi-frontier runs: checkpoint mid-run, resume, exact [n, K] match
# ---------------------------------------------------------------------------
def test_batched_checkpoint_resume_reproduces_uninterrupted(graph_store,
                                                            tmp_path):
    """An interrupted K-frontier run, resumed from its checkpoint, lands on
    exactly the uninterrupted run's final [n, K] values — and the checkpoint
    it resumes from stores the full per-column active frontier."""
    from repro.core.engine import latest_checkpoint
    from repro.session import GraphSession

    sources = (0, 7, 19, 42)
    K = len(sources)
    n = graph_store.num_vertices
    full = GraphSession(graph_store).run_batch("sssp", sources=sources,
                                               max_iters=40)

    # interrupt after 3 iterations; the final save persists iteration 3
    part_dir = tmp_path / "part"
    GraphSession(graph_store).run_batch("sssp", sources=sources, max_iters=3,
                                        checkpoint_dir=str(part_dir))
    ck = latest_checkpoint(str(part_dir))
    assert ck is not None
    values, active, it, col_iters, tag = ck
    assert it == 3
    assert values.shape == (n, K)
    assert active.shape == (n, K) and active.dtype == bool
    assert col_iters is not None and col_iters.shape == (K,)
    assert (col_iters <= 3).all() and col_iters.max() == 3
    assert tag == f"sssp_multi:{sources}"
    # the per-column frontier is the real one: a 3-hop SSSP frontier is
    # strictly per-column (columns started from different sources differ)
    assert active.any(), "mid-run frontier must be non-empty"
    assert any(not np.array_equal(active[:, 0], active[:, k])
               for k in range(1, K)), "frontier lost its per-column shape"

    # resume to completion and compare element-wise with the uninterrupted run
    resumed = GraphSession(graph_store).run_batch(
        "sssp", sources=sources, max_iters=40,
        checkpoint_dir=str(part_dir), resume=True)
    for k in range(K):
        np.testing.assert_array_equal(resumed[k].values, full[k].values)
        assert resumed[k].converged
        # per-column accounting spans the interruption: the resumed run
        # reports the same lifetime sweep count as the uninterrupted one,
        # while its history only bills the post-resume live iterations
        assert resumed[k].iterations == full[k].iterations
        assert len(resumed[k].history) == max(0, resumed[k].iterations - 3)


def test_batched_resume_rejects_checkpoint_from_different_run(graph_store,
                                                              tmp_path):
    """Resuming with a different K must fail loudly, not silently return the
    old run's frontiers labeled with the new sources."""
    from repro.session import GraphSession

    GraphSession(graph_store).run_batch("sssp", sources=(0, 1, 2),
                                        max_iters=2,
                                        checkpoint_dir=str(tmp_path))
    # different K: caught by the value-shape check
    with pytest.raises(ValueError, match="different run"):
        GraphSession(graph_store).run_batch("sssp", sources=(5, 9),
                                            max_iters=10, resume=True,
                                            checkpoint_dir=str(tmp_path))
    # same K, different landmark set: caught by the program tag
    with pytest.raises(ValueError, match="different run"):
        GraphSession(graph_store).run_batch("sssp", sources=(5, 9, 11),
                                            max_iters=10, resume=True,
                                            checkpoint_dir=str(tmp_path))


def test_batched_midrun_checkpoint_equals_uninterrupted_state(graph_store,
                                                              tmp_path):
    """The checkpoint a periodic saver writes at iteration i is bit-identical
    (values AND per-column frontier) to a run stopped at exactly i."""
    from repro.session import GraphSession

    sources = (1, 5)
    a_dir, b_dir = tmp_path / "a", tmp_path / "b"
    # A: stop at iteration 2 (final save publishes values + true frontier)
    GraphSession(graph_store).run_batch("sssp", sources=sources, max_iters=2,
                                        checkpoint_dir=str(a_dir))
    # B: run further but snapshot every 2 iterations
    GraphSession(graph_store).run_batch("sssp", sources=sources, max_iters=6,
                                        checkpoint_dir=str(b_dir),
                                        checkpoint_every=2)
    with np.load(a_dir / "ckpt_000002.npz") as za, \
            np.load(b_dir / "ckpt_000002.npz") as zb:
        np.testing.assert_array_equal(za["values"], zb["values"])
        np.testing.assert_array_equal(za["active"], zb["active"])
