"""Two-tier adaptive edge cache: placement, budget edge cases, churn.

The contract under test (core/cache.py):
  * one strict byte budget over BOTH tiers, never exceeded — not after any
    single get, not under 8 threads of promotion/demotion churn;
  * hot tier is earned by reuse (frequency), and the eviction path cascades
    hot→cold→out;
  * degenerate budgets still make progress: smaller than the largest shard,
    and budget=0 degrades to mode 0 (no application cache);
  * every placement decision is a deterministic function of the get
    sequence, so results stay bitwise identical to the static cache
    (cross-backend/depth property in tests/test_backends.py).
"""
import threading

import numpy as np
import pytest

from repro.core.cache import CompressedShardCache
from repro.core.engine import EngineConfig
from repro.session import GraphSession


@pytest.fixture(scope="module")
def tier_store(tmp_path_factory, small_graph):
    """A store with enough shards for eviction/promotion churn to happen."""
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import write_edge_list
    src, dst, n = small_graph
    base = tmp_path_factory.mktemp("tier_graph")
    write_edge_list(base / "el", [(src, dst)])
    return preprocess_graph(str(base / "el"), str(base / "store"),
                            threshold_edge_num=256, ell_max_width=64)


def _raw_nbytes(cache, store):
    return [cache._entry_nbytes(store.read_shard(p))
            for p in range(store.num_shards)]


# ---------------------------------------------------------------------------
# placement lifecycle: miss -> cold -> (frequency) -> hot
# ---------------------------------------------------------------------------
def test_promotion_lifecycle_and_decode_seconds_saved(tier_store):
    cache = CompressedShardCache(tier_store, mode="adaptive",
                                 budget_bytes=1 << 28)
    assert cache.adaptive and cache.mode >= 2  # admission default: compressed
    cache.get(0)                               # miss: admitted cold
    assert cache.shard_tier(0) == "cold"
    cache.get(0)                               # cold hit: 2nd touch promotes
    assert cache.shard_tier(0) == "hot"
    saved0 = cache.stats.decode_seconds_saved
    cache.get(0)                               # hot hit: zero decode
    assert (cache.stats.misses, cache.stats.cold_hits,
            cache.stats.hot_hits, cache.stats.promotions) == (1, 1, 1, 1)
    assert cache.stats.decode_seconds_saved > saved0
    assert cache.stats.hits == 2
    cache.audit()


def test_rarely_touched_shards_stay_cold(tier_store):
    cache = CompressedShardCache(tier_store, mode="adaptive",
                                 budget_bytes=1 << 28)
    for _ in range(4):
        cache.get(0)            # hub shard: touched every iteration
    cache.get(1)                # rarely-scheduled shard: one touch
    assert cache.shard_tier(0) == "hot"
    assert cache.shard_tier(1) == "cold"
    rep = cache.report()
    assert rep["hot_shards"] == 1 and rep["cold_shards"] == 1
    assert rep["measured_ratio"] > 1.0  # the cold blob really is compressed


def test_demotion_cascade_hot_to_cold_and_no_equal_heat_churn(tier_store):
    """A hotter shard displaces the hot LRU (which is demoted, compressed,
    back to cold) — but EQUAL heat must not displace (no promote/demote
    ping-pong between uniformly-swept shards)."""
    raw = _raw_nbytes(
        CompressedShardCache(tier_store, mode=1, budget_bytes=1), tier_store)
    # hot_fraction=0.5 -> the hot tier fits ONE of shards {0, 1}, not both
    budget = 2 * max(raw[0], raw[1])
    cache = CompressedShardCache(tier_store, mode="adaptive",
                                 budget_bytes=budget, hot_fraction=0.5)
    cache.get(0)
    cache.get(0)            # freq 2: promoted, hot tier now full
    assert cache.shard_tier(0) == "hot"
    cache.get(1)
    cache.get(1)            # freq 2 == freq of hot LRU: stays cold (no churn)
    assert cache.shard_tier(1) == "cold"
    assert cache.stats.demotions == 0
    cache.get(1)            # freq 3 > 2: displaces shard 0
    assert cache.shard_tier(1) == "hot"
    assert cache.shard_tier(0) in ("cold", "out")  # demoted (may then evict)
    assert cache.stats.demotions == 1
    assert cache.stats.promotions == 2
    cache.audit()


# ---------------------------------------------------------------------------
# budget edge cases
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["adaptive", 1, 2])
def test_budget_smaller_than_any_shard_still_makes_progress(tier_store, mode):
    """A budget no entry can fit under must behave like a cache that caches
    nothing: every get returns the right shard, bytes stay at <= budget."""
    cache = CompressedShardCache(tier_store, mode=mode, budget_bytes=64)
    for p in list(range(tier_store.num_shards)) * 2:
        shard = cache.get(p)
        assert shard.shard_id == p
        assert cache.cached_bytes <= cache.budget
    assert cache.stats.misses == 2 * tier_store.num_shards
    if cache.adaptive:
        cache.audit()


def test_budget_smaller_than_largest_shard_caches_what_fits(tier_store):
    """Budget below the largest single shard: the big shard is served
    uncached, smaller entries (cold blobs) still earn their keep."""
    raw = _raw_nbytes(
        CompressedShardCache(tier_store, mode=1, budget_bytes=1), tier_store)
    budget = max(raw) - 1
    for mode in ("adaptive", 1):
        cache = CompressedShardCache(tier_store, mode=mode,
                                     budget_bytes=budget)
        for p in range(tier_store.num_shards):
            cache.get(p)
            assert cache.cached_bytes <= cache.budget
        # a full sweep is served correctly and SOMETHING was cacheable
        # (cold blobs compress under the raw size; mode 1 keeps small shards)
        assert cache.cached_shards >= 1
        if cache.adaptive:
            cache.audit()


def test_budget_zero_degrades_to_mode_0(tier_store):
    for requested in ("auto", "adaptive", 1, 4):
        cache = CompressedShardCache(tier_store, mode=requested,
                                     budget_bytes=0)
        assert cache.mode == 0 and not cache.adaptive
        shard = cache.get(0)
        assert shard.shard_id == 0
        assert cache.cached_bytes == 0 and cache.cached_shards == 0
        assert cache.stats.hits == 0 and cache.stats.misses == 1
    with pytest.raises(ValueError, match="budget_bytes"):
        CompressedShardCache(tier_store, budget_bytes=-1)


def test_cache_ctor_validates_tier_knobs(tier_store):
    with pytest.raises(ValueError, match="hot_fraction"):
        CompressedShardCache(tier_store, budget_bytes=1, hot_fraction=0.0)
    with pytest.raises(ValueError, match="promote_after"):
        CompressedShardCache(tier_store, budget_bytes=1, promote_after=0)


# ---------------------------------------------------------------------------
# promotion/demotion churn under the 8-thread hammer, audited every op
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("budget_shards", [2, 4])
def test_adaptive_churn_hammer_byte_accounting_exact(tier_store, budget_shards):
    """8 threads hammer a tight adaptive cache; after EVERY operation the
    running byte counters are recounted from the actual tier contents
    (cache.audit()), so any promotion/demotion/eviction accounting race
    fails loudly, not statistically."""
    from repro.graph.storage import GraphStore
    store = GraphStore(tier_store.path)  # private io counters
    sizes = [store.shard_nbytes(p) for p in range(store.num_shards)]
    cache = CompressedShardCache(store, mode="adaptive",
                                 budget_bytes=budget_shards * max(sizes),
                                 promote_after=2)
    per_thread = 40
    errors = []
    barrier = threading.Barrier(8)

    def hammer(seed):
        rng = np.random.default_rng(seed)
        barrier.wait()
        try:
            for sid in rng.integers(0, store.num_shards, size=per_thread):
                shard = cache.get(int(sid))
                assert shard.shard_id == int(sid)
                cache.audit()  # byte accounting verified after every op
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert cache.stats.hits + cache.stats.misses == 8 * per_thread
    # every miss was charged at canonical nbytes, and reads match exactly
    assert cache.stats.disk_bytes == store.io.read
    assert cache.cached_bytes <= cache.budget
    cache.audit()


def test_adaptive_ample_budget_misses_once_per_shard(tier_store):
    """With an ample budget the adaptive cache has static-mode economics:
    exactly one miss (and one canonical-size disk charge) per shard."""
    from repro.graph.storage import GraphStore
    store = GraphStore(tier_store.path)
    cache = CompressedShardCache(store, mode="adaptive", budget_bytes=1 << 28)
    P = store.num_shards
    rng = np.random.default_rng(0)
    for sid in rng.permutation(np.repeat(np.arange(P), 5)):
        cache.get(int(sid))
    assert cache.stats.misses == P
    assert cache.stats.evictions == 0
    assert cache.stats.disk_bytes == sum(
        store.shard_nbytes(p) for p in range(P))
    cache.audit()


# ---------------------------------------------------------------------------
# session plumbing: knobs, env vars, cache_report
# ---------------------------------------------------------------------------
def test_session_cache_report_is_self_consistent(tier_store):
    sess = GraphSession(tier_store, cache_mode="adaptive",
                        cache_budget_bytes=1 << 28)
    sess.run("pagerank", max_iters=4)
    rep = sess.cache_report()
    assert rep["policy"] == "adaptive"
    assert rep["hot_bytes"] + rep["cold_bytes"] == rep["cached_bytes"]
    assert rep["cached_bytes"] <= rep["budget_bytes"]
    assert rep["hot_hits"] + rep["cold_hits"] == rep["hits"]
    assert rep["misses"] == tier_store.num_shards  # ample: one per shard
    # warm sweeps promoted the whole working set: decode cost is being
    # saved on every hot hit from iteration 3 on
    assert rep["hot_shards"] > 0
    assert rep["decode_seconds_saved"] > 0.0
    assert rep["promotions"] >= rep["hot_shards"]
    # per-iteration plumbing: the saved seconds show up in IterationStats
    saved = sum(h.decode_seconds_saved
                for h in sess.engine("pagerank").last_result.history)
    assert saved == pytest.approx(rep["decode_seconds_saved"], abs=1e-9)


def test_static_sessions_report_static_policy(tier_store):
    sess = GraphSession(tier_store, cache_mode=1, cache_budget_bytes=1 << 28)
    sess.run("pagerank", max_iters=2)
    rep = sess.cache_report()
    assert rep["policy"] == "static" and rep["mode"] == 1
    assert rep["promotions"] == rep["demotions"] == 0
    # static mode 1 entries are decompressed arrays: the hot tier, reported
    assert rep["hot_bytes"] == rep["cached_bytes"] > 0


def test_cache_budget_env_alias_and_tier_knobs(monkeypatch):
    monkeypatch.setenv("GRAPHMP_CACHE_BUDGET", str(1 << 21))
    monkeypatch.setenv("GRAPHMP_CACHE_HOT_FRACTION", "0.25")
    monkeypatch.setenv("GRAPHMP_CACHE_PROMOTE_AFTER", "3")
    cfg = EngineConfig.from_env()
    assert cfg.cache_budget_bytes == 1 << 21
    assert cfg.cache_hot_fraction == 0.25
    assert cfg.cache_promote_after == 3
    # the new name wins over the legacy alias when both are set
    monkeypatch.setenv("GRAPHMP_CACHE_BUDGET_BYTES", str(1 << 20))
    assert EngineConfig.from_env().cache_budget_bytes == 1 << 21
    # empty string (unset CI matrix legs) falls back to the default
    monkeypatch.setenv("GRAPHMP_CACHE_BUDGET", "")
    assert EngineConfig.from_env().cache_budget_bytes == 1 << 20  # legacy alias
    monkeypatch.setenv("GRAPHMP_CACHE_BUDGET_BYTES", "")
    assert (EngineConfig.from_env().cache_budget_bytes
            == EngineConfig().cache_budget_bytes)


def test_clear_drops_tiers_and_placement_state(tier_store):
    cache = CompressedShardCache(tier_store, mode="adaptive",
                                 budget_bytes=1 << 28)
    cache.get(0)
    cache.get(0)
    cache.clear()
    assert cache.cached_bytes == 0 and cache.cached_shards == 0
    assert cache.shard_tier(0) == "out"
    hits, misses = cache.stats.hits, cache.stats.misses
    cache.get(0)  # a fresh miss (placement state was reset too)
    assert cache.shard_tier(0) in ("cold", "hot")
    assert (cache.stats.hits, cache.stats.misses) == (hits, misses + 1)
    cache.audit()
