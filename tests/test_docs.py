"""Documentation stays navigable: every relative markdown link resolves.

Runs the same checker CI runs (tools/check_links.py) over README.md and
docs/, so a moved file or a renamed heading fails tier-1 locally, not just
on the push.
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_markdown_links_resolve():
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_links.py"),
         str(REPO / "README.md"), str(REPO / "docs")],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr or r.stdout
    assert "OK" in r.stdout


def test_docs_exist_and_are_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    for doc in ("docs/ARCHITECTURE.md", "docs/REPRODUCING.md"):
        assert (REPO / doc).is_file()
        assert doc in readme, f"README does not link {doc}"
