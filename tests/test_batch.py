"""Batched multi-source traversal: K frontiers through one VSW sweep.

Covers the ISSUE-2 acceptance criteria:
  * ``run_batch`` is element-wise identical to K sequential single-source
    runs (hypothesis property over random graphs / shard counts / K);
  * a K=16 batch on a warm session reads no more disk bytes than one
    single-source run (the amortization claim);
  * batched Pallas and jnp-oracle SpMV paths agree on [n, K] inputs for all
    four semirings.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from tests._hypo import given, settings, st

from repro.core.apps import get_app
from repro.core.engine import BatchRunResult
from repro.core.semiring import SEMIRINGS
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list
from repro.kernels.spmv import ref
from repro.kernels.spmv.ops import ell_spmv, ell_spmv_batch
from repro.session import GraphSession


# ---------------------------------------------------------------------------
# kernel-level: batched == per-column, Pallas == jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
def test_batched_spmv_paths_agree_all_semirings(semiring):
    rng = np.random.default_rng(42)
    n, R, W, K = 257, 64, 256, 7
    cols = rng.integers(-1, n, size=(R, W)).astype(np.int32)
    vals = rng.random((R, W)).astype(np.float32)
    x = (rng.random((n, K)) + 0.1).astype(np.float32)
    row_map = np.sort(rng.integers(0, R // 2, size=R)).astype(np.int32)
    args = (jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(row_map), R,
            semiring)
    pallas = ell_spmv_batch(jnp.asarray(x), *args, use_pallas=True)
    jnp_path = ell_spmv_batch(jnp.asarray(x), *args, use_pallas=False)
    oracle = ref.ell_spmv_batch_ref(jnp.asarray(x), *args)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(oracle),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(oracle),
                               rtol=1e-5)
    # and each column equals the unbatched kernel on that column
    for k in range(K):
        single = ell_spmv(jnp.asarray(x[:, k]), *args, use_pallas=False)
        np.testing.assert_allclose(np.asarray(oracle[:, k]),
                                   np.asarray(single), rtol=1e-5)


# ---------------------------------------------------------------------------
# engine-level acceptance on the shared fixture graph
# ---------------------------------------------------------------------------
def test_run_batch_k16_warm_session_io_and_values(graph_store):
    """K=16 SSSP landmarks: no more disk than ONE single-source run on the
    same warm session, and element-wise equal to 16 sequential runs."""
    total = graph_store.total_shard_bytes()
    sess = GraphSession(graph_store, cache_mode=1,
                        cache_budget_bytes=4 * total)
    sess.warm()
    n = graph_store.num_vertices
    sources = [(i * 37) % n for i in range(16)]

    d0 = sess.stats.disk_bytes
    single = sess.run("sssp", source=sources[0], max_iters=100)
    single_disk = sess.stats.disk_bytes - d0

    d1 = sess.stats.disk_bytes
    batch = sess.run_batch("sssp", sources=sources, max_iters=100)
    batch_disk = sess.stats.disk_bytes - d1
    assert batch_disk <= single_disk  # 16 queries, <= 1 query's disk I/O

    assert len(batch) == 16
    np.testing.assert_array_equal(batch[0].values, single.values)
    for k, s in enumerate(sources[1:], start=1):
        seq = sess.run("sssp", source=s, max_iters=100)
        np.testing.assert_array_equal(batch[k].values, seq.values)


def test_run_batch_personalized_pagerank_columns_independent(graph_store):
    """Each PPR column equals a K=1 personalized run with that seed."""
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 26)
    seeds = [3, 11, 29]
    batch = sess.run_batch("pagerank", sources=seeds, max_iters=25)
    for k, s in enumerate(seeds):
        # PPR's own vocabulary (seeds=) dispatches identically to sources=
        solo = sess.run_batch("personalized_pagerank", seeds=[s],
                              max_iters=25)
        np.testing.assert_allclose(batch[k].values, solo[0].values, atol=1e-6)
    # mass concentrates near the seed: the seed itself outranks the median
    for k, s in enumerate(seeds):
        assert batch[k].values[s] > np.median(batch[k].values)


def test_run_batch_honest_per_column_iterations(graph_store):
    """Column accounting: iterations vary per landmark, and the combined
    BatchRunResult stays available on the engine."""
    sess = GraphSession(graph_store)
    sources = (0, 1, 2, 3)
    batch = sess.run_batch("bfs", sources=sources, max_iters=100)
    combined = sess.last_batch_result
    assert isinstance(combined, BatchRunResult)
    assert sess.engine("bfs_multi", sources=sources).last_result is combined
    assert combined.values.shape == (graph_store.num_vertices, 4)
    for k, r in enumerate(batch):
        assert r.iterations == int(combined.column_iterations[k])
        assert r.iterations <= combined.iterations
        assert len(r.history) == r.iterations
        assert r.converged


def test_run_batch_argument_validation(graph_store):
    sess = GraphSession(graph_store)
    with pytest.raises(TypeError, match="needs sources"):
        sess.run_batch("sssp")
    with pytest.raises(TypeError, match="not a batched application"):
        sess.run_batch("cc", sources=[0])
    with pytest.raises(ValueError, match="at least one source"):
        get_app("sssp_multi", sources=())
    with pytest.raises(ValueError, match=">= 0"):
        sess.run_batch("sssp", sources=[0, -1])
    with pytest.raises(TypeError, match="not both"):
        sess.run_batch("ppr", sources=[1], seeds=[2])
    # a wrong kwarg on a genuinely batched app keeps the factory's own
    # message instead of being mislabeled "not a batched application"
    with pytest.raises(TypeError, match="damping"):
        sess.run_batch("sssp", sources=[0], damping=0.5)
    prog = get_app("sssp_multi", sources=(0, 1))
    with pytest.raises(TypeError, match="already fixes its frontiers"):
        sess.run_batch(prog, sources=[2])
    with pytest.raises(TypeError, match="only apply when dispatching by name"):
        sess.run_batch(prog, damping=0.5)  # kwargs must not be dropped


# ---------------------------------------------------------------------------
# property: run_batch == K sequential runs, over random graphs/shards/K
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 6), st.sampled_from([96, 512]))
@settings(max_examples=8, deadline=None)
def test_property_batch_equals_sequential(tmp_path_factory, seed, K,
                                          threshold):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(48, 200))
    m = int(rng.integers(2 * n, 6 * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    base = tmp_path_factory.mktemp(f"prop_{seed}_{K}_{threshold}")
    write_edge_list(base / "el", [(src, dst)], num_vertices=n)
    store = preprocess_graph(str(base / "el"), str(base / "store"),
                             threshold_edge_num=threshold, ell_max_width=128)
    sources = rng.integers(0, n, size=K).tolist()
    sess = GraphSession(store, cache_mode=1, cache_budget_bytes=1 << 24)
    batch = sess.run_batch("sssp", sources=sources, max_iters=n + 1)
    assert len(batch) == K
    for k, s in enumerate(sources):
        seq = sess.run("sssp", source=int(s), max_iters=n + 1)
        np.testing.assert_array_equal(batch[k].values, seq.values)
