"""Bloom filter properties — the safety of selective scheduling rests on
"no false negatives" (a skipped shard is truly unable to produce updates)."""
import numpy as np

from tests._hypo import given, settings, st

from repro.core.bloom import BloomFilter


@given(st.lists(st.integers(0, 1 << 40), max_size=300),
       st.lists(st.integers(0, 1 << 40), max_size=300))
@settings(max_examples=60, deadline=None)
def test_no_false_negatives(members, probes):
    bf = BloomFilter.build(np.asarray(members, dtype=np.int64))
    if members:
        assert bf.might_contain(np.asarray(members)).all()
    probe = np.asarray(probes, dtype=np.int64)
    hits = bf.might_contain(probe) if probes else np.zeros(0, bool)
    for p, h in zip(probes, hits):
        if p in set(members):
            assert h


def test_false_positive_rate_reasonable():
    rng = np.random.default_rng(0)
    members = rng.integers(0, 1 << 50, 5000)
    bits = BloomFilter.sized_for(5000, fp_rate=0.01)
    bf = BloomFilter.build(members, num_bits=bits)
    probes = rng.integers(1 << 50, 1 << 51, 20000)  # disjoint range
    fp = bf.might_contain(probes).mean()
    assert fp < 0.05, fp


def test_empty_filter_rejects_everything():
    bf = BloomFilter.build(np.zeros(0, dtype=np.int64))
    assert not bf.might_contain_any(np.arange(1000))


def test_might_contain_any_chunking():
    bf = BloomFilter.build(np.asarray([123456789]))
    big = np.arange(1 << 21)  # exercises the chunked path
    assert not bf.might_contain_any(big + (1 << 30)) or True  # no crash
    assert bf.might_contain_any(np.asarray([123456789]))
