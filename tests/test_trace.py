"""LoadTrace: format round-trip, synthesis determinism, and the replay
determinism acceptance bar — replaying the same trace twice (even under
DIFFERENT batching policies) yields bitwise-identical request results.
"""
import json

import pytest

from repro.obs import LoadTrace, TraceEvent, TraceRecorder
from repro.serve import bench
from repro.serve.bench import ServiceConfig, replay_trace
from repro.session import GraphSession


def _tiny_trace(n, events=24, seed=3):
    return LoadTrace.synthesize(
        duration_s=events / 40.0, qps=40.0, mix={"bfs": 2.0, "sssp": 1.0},
        num_vertices=n, seed=seed, max_iters=50)


# ---------------------------------------------------------------------------
# format
# ---------------------------------------------------------------------------
def test_save_load_round_trip(tmp_path):
    trace = _tiny_trace(64)
    trace.meta["store"] = {"scale": 6}
    path = trace.save(tmp_path / "t.jsonl")
    loaded = LoadTrace.load(path)
    assert loaded.meta == trace.meta
    assert len(loaded) == len(trace)
    for a, b in zip(trace, loaded):
        assert (a.app, a.params) == (b.app, b.params)
        assert b.t == pytest.approx(a.t, abs=1e-6)  # t rounds to 6 dp
    # header first, then one event object per line
    lines = path.read_text().splitlines()
    assert json.loads(lines[0])["trace"] == 1
    assert len(lines) == len(trace) + 1


def test_events_sorted_and_introspection():
    trace = LoadTrace([TraceEvent(0.5, "bfs", {"source": 1}),
                       TraceEvent(0.1, "sssp", {"source": 2})])
    assert [e.t for e in trace] == [0.1, 0.5]
    assert trace.duration == 0.5
    assert trace.apps() == {"bfs": 1, "sssp": 1}
    assert trace.mean_qps() == pytest.approx(2 / 0.5)
    assert trace[0].app == "sssp"


def test_load_rejects_malformed(tmp_path):
    cases = {
        "empty.jsonl": "",
        "headeronly.jsonl": '{"trace": 1, "meta": {}}\n',
        "badver.jsonl": '{"trace": 99}\n',
        "notjson.jsonl": "nope\n",
        "negativet.jsonl": '{"t": -1.0, "app": "bfs", "params": {}}\n',
        "noapp.jsonl": '{"t": 0.0, "params": {}}\n',
        "listparams.jsonl": '{"t": 0.0, "app": "bfs", "params": []}\n',
    }
    for name, content in cases.items():
        p = tmp_path / name
        p.write_text(content)
        with pytest.raises(ValueError):
            LoadTrace.load(p)


# ---------------------------------------------------------------------------
# synthesis
# ---------------------------------------------------------------------------
def test_synthesize_deterministic_and_mixed():
    a = _tiny_trace(128, seed=9)
    b = _tiny_trace(128, seed=9)
    assert a.events == b.events  # bit-for-bit, same seed
    c = _tiny_trace(128, seed=10)
    assert a.events != c.events
    assert set(a.apps()) <= {"bfs", "sssp"}
    assert all(e.params["max_iters"] == 50 for e in a)
    assert all(0 <= e.params["source"] < 128 for e in a)


def test_synthesize_burst_raises_rate():
    base = LoadTrace.synthesize(duration_s=30.0, qps=10.0, mix={"bfs": 1.0},
                                num_vertices=64, seed=1)
    burst = LoadTrace.synthesize(duration_s=30.0, qps=10.0, mix={"bfs": 1.0},
                                 num_vertices=64, seed=1,
                                 burst=(10.0, 20.0, 4.0))
    def inside(tr):
        return sum(1 for e in tr if 10.0 <= e.t < 20.0)
    assert inside(burst) > 2 * inside(base)
    assert burst.meta["burst"] == [10.0, 20.0, 4.0]


def test_synthesize_validation():
    with pytest.raises(ValueError):
        LoadTrace.synthesize(duration_s=0, qps=1, mix={"bfs": 1},
                             num_vertices=8)
    with pytest.raises(ValueError):
        LoadTrace.synthesize(duration_s=1, qps=1, mix={}, num_vertices=8)
    with pytest.raises(ValueError):
        LoadTrace.synthesize(duration_s=1, qps=1, mix={"bfs": -1},
                             num_vertices=8)


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------
def test_recorder_explicit_and_wall_clock(tmp_path):
    rec = TraceRecorder(meta={"mode": "open"})
    rec.record("bfs", {"source": 1}, t=0.25)   # intended-offset mode
    rec.record("sssp", {"source": 2}, t=0.10)
    assert len(rec) == 2
    trace = rec.trace()
    assert [e.t for e in trace] == [0.10, 0.25]  # sorted on materialize
    path = rec.save(tmp_path / "rec.jsonl")
    assert LoadTrace.load(path).meta == {"mode": "open"}

    fake = [5.0]
    wall = TraceRecorder(clock=lambda: fake[0])
    wall.record("bfs", {})          # first record pins t0 -> t = 0
    fake[0] = 5.5
    wall.record("bfs", {})
    assert [e.t for e in wall.trace()] == [0.0, 0.5]


# ---------------------------------------------------------------------------
# replay determinism: the acceptance bar
# ---------------------------------------------------------------------------
def test_replay_twice_is_bitwise_identical(graph_store):
    """Same trace, two DIFFERENT batching policies: every request resolves
    to the same bytes (exact min-propagation apps), digests match, and the
    replay completes everything it admitted."""
    trace = _tiny_trace(graph_store.num_vertices)
    digests = []
    for cfg in (ServiceConfig(max_batch=2, max_wait_ms=0.5, memoize=False),
                ServiceConfig(max_batch=8, max_wait_ms=25.0, memoize=False)):
        with GraphSession(graph_store) as session:
            r = replay_trace(session, trace, cfg)
        assert r["completed"] == len(trace)
        assert r["failed"] == 0 and r["rejected"] == 0
        digests.append(r["result_digest"])
    assert digests[0] == digests[1]


def test_open_mode_cli_records_then_replays(graph_store, tmp_path, capsys):
    """Satellite: open-loop Poisson mode end to end through the CLI —
    ``--record-trace`` writes the generated schedule, and ``--mode replay``
    of that file reproduces the run's result digest."""
    rec = tmp_path / "open.jsonl"
    rc = bench.main(["--mode", "open", "--graph", str(graph_store.path),
                     "--qps", "30", "--duration", "0.5", "--seed", "5",
                     "--max-wait-ms", "2.0", "--record-trace", str(rec)])
    assert rc == 0
    out = capsys.readouterr().out
    digest = [ln for ln in out.splitlines()
              if ln.startswith("# result_digest=")][0]
    trace = LoadTrace.load(rec)  # the recorded schedule is a valid trace
    assert len(trace) > 0 and set(trace.apps()) <= {"bfs", "sssp"}
    rc = bench.main(["--mode", "replay", "--graph", str(graph_store.path),
                     "--replay-trace", str(rec), "--max-wait-ms", "25.0"])
    assert rc == 0
    out2 = capsys.readouterr().out
    assert digest in out2  # different policy, same results, same digest
