"""Multi-device semantics, run in subprocesses with XLA_FLAGS-forced device
counts (the main test process must keep seeing 1 CPU device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_vsw_matches_single_device():
    out = run_with_devices("""
        import numpy as np, jax
        from repro.graph.generate import rmat_edges, materialize
        from repro.core.distributed import partition_for_mesh, DistributedVSW
        from repro.core import apps

        src, dst = materialize(rmat_edges(scale=9, edge_factor=8, seed=3))
        n = 1 << 9
        mesh8 = jax.make_mesh((8,), ('data',),
                              axis_types=(jax.sharding.AxisType.Auto,))
        g8 = partition_for_mesh(src, dst, n, 8)
        vals8, it8 = DistributedVSW(g8, apps.cc(), mesh8).run(100)
        # oracle fixpoint
        ref = np.arange(g8.num_vertices, dtype=np.float64)
        for _ in range(200):
            new = ref.copy(); np.minimum.at(new, dst, ref[src])
            if (new == ref).all(): break
            ref = new
        assert (vals8 == ref).all(), 'cc mismatch on 8 devices'
        print('OK', it8)
    """)
    assert "OK" in out


def test_distributed_vsw_pagerank_8dev():
    out = run_with_devices("""
        import numpy as np, jax
        from repro.graph.generate import rmat_edges, materialize
        from repro.core.distributed import partition_for_mesh, DistributedVSW
        from repro.core import apps

        src, dst = materialize(rmat_edges(scale=8, edge_factor=8, seed=5))
        n = 1 << 8
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = partition_for_mesh(src, dst, n, 8)
        eng = DistributedVSW(g, apps.pagerank(), mesh)
        vals, _ = eng.run(30)
        out_deg = np.bincount(src, minlength=g.num_vertices)
        pr = np.full(g.num_vertices, 1.0/g.num_vertices)
        for _ in range(30):
            c = pr / np.maximum(out_deg, 1)
            s = np.zeros_like(pr); np.add.at(s, dst, c[src])
            pr = 0.15/g.num_vertices + 0.85*s
        assert np.abs(vals - pr).max() < 1e-5, np.abs(vals - pr).max()
        print('OK')
    """)
    assert "OK" in out


def test_distributed_vsw_non_divisible_n():
    """Regression: n not divisible by the device count.  partition_for_mesh
    pads the intervals; the padding rows must not absorb PageRank mass,
    join the CC label space, or be counted as changed vertices."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.graph.generate import rmat_edges, materialize
        from repro.core.distributed import partition_for_mesh, DistributedVSW
        from repro.core import apps

        src, dst = materialize(rmat_edges(scale=9, edge_factor=8, seed=3))
        n = 500  # 500 % 8 != 0
        keep = (src < n) & (dst < n)
        src, dst = src[keep], dst[keep]
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = partition_for_mesh(src, dst, n, 8)
        assert g.num_vertices == n, g.num_vertices
        assert g.padded_num_vertices == 504, g.padded_num_vertices

        vals, _ = DistributedVSW(g, apps.cc(), mesh).run(100)
        assert vals.shape == (n,), vals.shape
        ref = np.arange(n, dtype=np.float64)
        for _ in range(200):
            new = ref.copy(); np.minimum.at(new, dst, ref[src])
            if (new == ref).all(): break
            ref = new
        assert (vals == ref).all(), 'cc: padding leaked into labels'

        pr_vals, _ = DistributedVSW(g, apps.pagerank(), mesh).run(30)
        out_deg = np.bincount(src, minlength=n)
        pr = np.full(n, 1.0 / n)
        for _ in range(30):
            c = pr / np.maximum(out_deg, 1)
            s = np.zeros_like(pr); np.add.at(s, dst, c[src])
            pr = 0.15 / n + 0.85 * s
        err = np.abs(pr_vals - pr).max()
        assert err < 1e-5, f'pagerank: padding absorbed mass ({err})'
        print('OK')
    """)
    assert "OK" in out


def test_distributed_vsw_honors_config():
    """EngineConfig fields the prototype supports must be honored (not
    silently dropped), and the replicated-Bloom selective schedule must
    keep SSSP exact while devices get skipped."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.graph.generate import rmat_edges, materialize
        from repro.core.distributed import partition_for_mesh, DistributedVSW
        from repro.core import apps
        from repro.core.engine import EngineConfig

        src, dst = materialize(rmat_edges(scale=9, edge_factor=8, seed=11))
        n = 500
        keep = (src < n) & (dst < n)
        src, dst = src[keep], dst[keep]
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = partition_for_mesh(src, dst, n, 8)
        assert len(g.blooms) == 8

        cfg = EngineConfig(use_pallas=False, selective_threshold=0.5)
        eng = DistributedVSW(g, apps.sssp(source=3), mesh, config=cfg)
        assert eng.use_pallas is False
        assert eng.selective_threshold == 0.5
        # threshold 0.5 forces Bloom probing from the 1-vertex frontier on
        flags = eng._schedule_flags(np.array([3]), 1.0 / n)
        assert flags.dtype == bool and flags.shape == (8,)
        dist, _ = eng.run(100)

        init = np.full(n, np.inf); init[3] = 0.0
        ref = init.copy()
        for _ in range(200):
            new = ref.copy(); np.minimum.at(new, dst, ref[src] + 1.0)
            if (new == ref).all(): break
            ref = new
        assert np.array_equal(dist, ref.astype(np.float32)), 'sssp mismatch'
        print('OK')
    """)
    assert "OK" in out


def test_spmv_2d_partition():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.distributed import spmv_2d
        from repro.kernels.spmv import ref

        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(0)
        D, S, R, W, nloc = 2, 2, 16, 128, 64
        n = S * nloc
        # cols are LOCAL source indices into each device's x block
        cols = rng.integers(-1, nloc, size=(D, S, R, W)).astype(np.int32)
        vals = rng.random((D, S, R, W)).astype(np.float32)
        row_map = np.sort(rng.integers(0, R, size=(D, S, R)), -1).astype(np.int32)
        x = rng.random(n).astype(np.float32)
        out = spmv_2d(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                      jnp.asarray(row_map), 'plus_times', mesh)
        # oracle: per dst-block, sum over src blocks of local spmv
        want = np.zeros((D, R), np.float32)
        for d in range(D):
            for s in range(S):
                xb = x[s*nloc:(s+1)*nloc]
                seg = ref.ell_spmv_ref(jnp.asarray(xb), jnp.asarray(cols[d, s]),
                                       jnp.asarray(vals[d, s]),
                                       jnp.asarray(row_map[d, s]), R, 'plus_times')
                want[d] += np.asarray(seg)
        got = np.asarray(out).reshape(D, R)
        np.testing.assert_allclose(got, want, rtol=1e-5)
        print('OK')
    """)
    assert "OK" in out


def test_spmv_2d_min_semiring():
    """min_plus over the 2-D partition: the cross-src-block combine is a
    pmin (all_gather + fold), not a psum — must match the elementwise min
    of per-block single-device SpMVs EXACTLY (min never rounds)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import spmv_2d
        from repro.kernels.spmv import ref

        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(1)
        D, S, R, W, nloc = 2, 2, 16, 128, 48  # nloc deliberately unaligned
        n = S * nloc
        cols = rng.integers(-1, nloc, size=(D, S, R, W)).astype(np.int32)
        vals = rng.random((D, S, R, W)).astype(np.float32)
        row_map = np.sort(rng.integers(0, R, size=(D, S, R)), -1).astype(np.int32)
        x = rng.random(n).astype(np.float32)
        out = spmv_2d(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                      jnp.asarray(row_map), 'min_plus', mesh)
        want = np.full((D, R), np.inf, np.float32)
        for d in range(D):
            for s in range(S):
                xb = x[s*nloc:(s+1)*nloc]
                seg = ref.ell_spmv_ref(jnp.asarray(xb), jnp.asarray(cols[d, s]),
                                       jnp.asarray(vals[d, s]),
                                       jnp.asarray(row_map[d, s]), R, 'min_plus')
                want[d] = np.minimum(want[d], np.asarray(seg))
        got = np.asarray(out).reshape(D, R)
        assert np.array_equal(got, want), np.abs(got - want).max()
        print('OK')
    """)
    assert "OK" in out


def test_model_train_step_dp_tp_matches_single_device():
    """One train step on a (2 data × 2 model) mesh == single-device step."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist.context import make_rules, ShardCtx
        from repro.models.model import build_model
        from repro.train import OptConfig, make_init_state, make_train_step
        from repro.launch.dryrun import state_shardings
        from repro.launch.shapes import batch_shardings

        cfg = get_config('mixtral-8x22b').reduced()
        opt = OptConfig(warmup_steps=1, decay_steps=10)
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
                 'targets': jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}

        # single device
        m1 = build_model(cfg)
        s1 = make_init_state(m1, opt)(jax.random.PRNGKey(0))
        st1, met1 = jax.jit(make_train_step(m1, opt))(s1, batch)

        # 2x2 mesh
        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        ctx = make_rules(mesh, cfg)
        m2 = build_model(cfg, ctx)
        s2 = make_init_state(m2, opt)(jax.random.PRNGKey(0))
        sh = state_shardings(jax.eval_shape(lambda: s2), ctx)
        step2 = jax.jit(make_train_step(m2, opt), in_shardings=(sh, None))
        st2, met2 = step2(s2, batch)
        d = abs(float(met1['loss']) - float(met2['loss']))
        assert d < 2e-2, d
        print('OK', float(met1['loss']), float(met2['loss']))
    """)
    assert "OK" in out


def test_ep_modes_agree():
    """a2a EP, replicated EP, and the local path give the same MoE loss."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist.context import make_rules
        from repro.models.model import build_model

        cfg = get_config('kimi-k2-1t-a32b').reduced()
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
                 'targets': jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}
        m0 = build_model(cfg)
        params = m0.init(jax.random.PRNGKey(0))
        base, _ = jax.jit(m0.loss_fn)(params, batch)
        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        for mode in ('a2a', 'replicated'):
            ctx = make_rules(mesh, cfg, ep_mode=mode)
            m = build_model(cfg, ctx)
            loss, _ = jax.jit(m.loss_fn)(params, batch)
            d = abs(float(loss) - float(base))
            assert d < 2e-2, (mode, float(loss), float(base))
        print('OK', float(base))
    """)
    assert "OK" in out


def test_elastic_checkpoint_resharding():
    """Save on a 4-device mesh, restore on 8 devices (different sharding)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import CheckpointManager

        mesh4 = jax.make_mesh((4,), ('data',),
                              axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh4, P('data')))
        with tempfile.TemporaryDirectory() as td:
            ck = CheckpointManager(td)
            ck.save(1, {'x': x}, sync=True)
            mesh8 = jax.make_mesh((8,), ('data',),
                                  axis_types=(jax.sharding.AxisType.Auto,))
            sh8 = {'x': NamedSharding(mesh8, P('data'))}
            restored, step = ck.restore({'x': jax.eval_shape(lambda: x)},
                                        shardings=sh8)
            assert restored['x'].sharding.num_devices == 8
            np.testing.assert_array_equal(np.asarray(restored['x']),
                                          np.asarray(x))
        print('OK')
    """)
    assert "OK" in out


def test_serve_2d_expert_layout_matches():
    """Serve-time 2-D MoE layout (EP over data + ff-TP over model) == local."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist.context import make_rules
        from repro.models.model import build_model

        # f32 so the comparison is exact (bf16 adds reduction-order ulps)
        cfg = dataclasses.replace(get_config('kimi-k2-1t-a32b').reduced(),
                                  dtype='float32')
        rng = np.random.default_rng(0)
        B, S = 4, 16
        toks = rng.integers(0, cfg.vocab_size, (B, S))
        m0 = build_model(cfg, remat=False)
        params = m0.init(jax.random.PRNGKey(0))
        x, positions = m0._embed_inputs(params, {'tokens': jnp.asarray(toks)})
        h, _, _ = m0._run_groups(params, x, positions)
        ref = m0._logits(params, h)

        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        ctx = make_rules(mesh, cfg, serve_fsdp=False)
        assert ctx.rules['experts'] == 'data', ctx.rules['experts']
        m2 = build_model(cfg, ctx, remat=False)
        x2, pos2 = m2._embed_inputs(params, {'tokens': jnp.asarray(toks)})
        h2, _, _ = m2._run_groups(params, x2, pos2)
        got = m2._logits(params, h2)
        d = float(jnp.abs(got - ref).max())
        assert d < 1e-4, d
        print('OK', d)
    """)
    assert "OK" in out
