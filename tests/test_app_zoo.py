"""App-zoo differential harness + oracles for the PR-9 applications.

Two layers:

* **Oracles** — label propagation, k-core, triangle counting and random-walk
  sampling pinned against NetworkX / straight-line NumPy references (the
  style of test_oracles.py), including the walk distributional invariants:
  a fixed seed reproduces bitwise, and empirical visit frequencies match
  the oracle transition-matrix expectation.

* **Differential matrix** — EVERY registered application (enumerated via
  ``list_apps``, so a new app is covered the day it registers) runs through
  backend {npz, packed, memory} x cache mode {0, adaptive} x prefetch
  {0, 2}, and a GRAPHMP_DEVICES=2 subprocess leg, asserting bitwise-equal
  values and identical Table-3 disk-byte accounting.
"""
import json

import numpy as np
import pytest

from tests._hypo import given, prop_settings, st
from tests._zoo_runner import BATCH_ARGS, SOLO_ARGS, digest, run_zoo

from repro.core.apps import list_apps
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list
from repro.session import GraphSession

try:
    import networkx as nx
except ImportError:  # pragma: no cover - exercised on minimal installs
    nx = None

needs_networkx = pytest.mark.skipif(nx is None,
                                    reason="networkx not installed")


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------
def _symmetric_graph(seed, n, m):
    """Connected symmetric simple graph: random edges + the undirected ring
    (no dead ends, so walks never halt), deduplicated, no self-loops."""
    rng = np.random.default_rng(seed)
    src = np.concatenate([rng.integers(0, n, size=m), np.arange(n)])
    dst = np.concatenate([rng.integers(0, n, size=m), (np.arange(n) + 1) % n])
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    keep = src != dst
    pairs = np.unique(np.stack([src[keep], dst[keep]], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _build_store(base, src, dst, n):
    write_edge_list(base / "el", [(src, dst)], num_vertices=n)
    return preprocess_graph(str(base / "el"), str(base / "store"),
                            threshold_edge_num=512, ell_max_width=128)


N = 192


@pytest.fixture(scope="module")
def zoo_graph(tmp_path_factory):
    src, dst = _symmetric_graph(42, N, 3 * N)
    base = tmp_path_factory.mktemp("zoo")
    store = _build_store(base, src, dst, N)
    assert store.num_shards > 1  # the sweep must cross shard boundaries
    return src, dst, str(base / "store")


# ---------------------------------------------------------------------------
# NumPy oracles (independent of the engine stack)
# ---------------------------------------------------------------------------
def oracle_label_propagation(src, dst, n):
    """Fixpoint of directed max-label propagation."""
    label = np.arange(n, dtype=np.float64)
    while True:
        new = label.copy()
        np.maximum.at(new, dst, label[src])
        if (new == label).all():
            return label
        label = new


def oracle_kcore(src, dst, n, k):
    """Iterated peeling of vertices with < k live in-neighbors."""
    alive = np.ones(n, dtype=bool)
    while True:
        deg = np.bincount(dst[alive[src]], minlength=n)
        new = alive & (deg >= k)
        if (new == alive).all():
            return alive.astype(np.float64)
        alive = new


def oracle_triangles(src, dst, n):
    """diag(A^3)/2 on the (symmetric, simple) adjacency matrix."""
    A = np.zeros((n, n), dtype=np.int64)
    A[src, dst] = 1
    return np.diag(A @ A @ A) / 2.0


def _check_zoo_vs_numpy(seed, tmp_base):
    """Engine vs NumPy oracles on one random symmetric graph — shared by
    the deterministic tests below and the hypothesis property sweep."""
    n = 48
    src, dst = _symmetric_graph(seed, n, 2 * n)
    store = _build_store(tmp_base, src, dst, n)
    with GraphSession(store) as sess:
        lp = sess.run("label_propagation", max_iters=4 * n)
        assert lp.converged
        np.testing.assert_array_equal(
            lp.values, oracle_label_propagation(src, dst, n))
        for k in (1, 2, 3):
            kc = sess.run("kcore", k=k, max_iters=4 * n)
            assert kc.converged
            np.testing.assert_array_equal(
                kc.values, oracle_kcore(src, dst, n, k))
        tri = sess.run("triangles")
        np.testing.assert_array_equal(
            tri.values, oracle_triangles(src, dst, n))


def test_zoo_vs_numpy_oracles(tmp_path):
    _check_zoo_vs_numpy(123, tmp_path)


@given(seed=st.integers(0, 2**20))
@prop_settings(max_examples=5)
def test_zoo_vs_numpy_oracles_property(seed, tmp_path_factory):
    _check_zoo_vs_numpy(seed, tmp_path_factory.mktemp(f"prop_{seed}"))


# ---------------------------------------------------------------------------
# NetworkX oracles (shares nothing with this repo)
# ---------------------------------------------------------------------------
@needs_networkx
def test_label_propagation_vs_networkx(zoo_graph):
    src, dst, path = zoo_graph
    g = nx.Graph(list(zip(src.tolist(), dst.tolist())))
    g.add_nodes_from(range(N))
    with GraphSession(path) as sess:
        res = sess.run("label_propagation", max_iters=4 * N)
        assert res.converged
        want = np.empty(N)
        for comp in nx.connected_components(g):
            want[list(comp)] = max(comp)
        np.testing.assert_array_equal(res.values, want)
        # seeded broadcast: each lp_multi column marks its seed's component
        cols = sess.run_batch("lp", sources=[0, 5, 9], max_iters=4 * N)
        for col, s in zip(cols, (0, 5, 9)):
            reach = nx.node_connected_component(g, s)
            w = np.full(N, -1.0)
            w[list(reach)] = float(s)
            np.testing.assert_array_equal(col.values, w)


@needs_networkx
def test_kcore_vs_networkx(zoo_graph):
    src, dst, path = zoo_graph
    g = nx.Graph(list(zip(src.tolist(), dst.tolist())))
    g.add_nodes_from(range(N))
    with GraphSession(path) as sess:
        for k in (2, 3, 4):
            res = sess.run("kcore", k=k, max_iters=4 * N)
            assert res.converged
            want = np.zeros(N)
            want[list(nx.k_core(g, k=k).nodes)] = 1.0
            np.testing.assert_array_equal(res.values, want)
        # one batched sweep answers all thresholds, bitwise equal to solo
        cols = sess.run_batch("kcore", sources=[2, 3, 4], max_iters=4 * N)
        for col, k in zip(cols, (2, 3, 4)):
            solo = sess.run("kcore", k=k, max_iters=4 * N)
            np.testing.assert_array_equal(col.values, solo.values)


@needs_networkx
def test_triangles_vs_networkx(zoo_graph):
    src, dst, path = zoo_graph
    g = nx.Graph(list(zip(src.tolist(), dst.tolist())))
    g.add_nodes_from(range(N))
    with GraphSession(path) as sess:
        res = sess.run("triangles")
        tri = nx.triangles(g)
        np.testing.assert_array_equal(
            res.values, [float(tri[v]) for v in range(N)])
        # the probe columns sum to the same counts: t(u) = sum(col_u) / 2
        cols = sess.run_batch("triangle_count", sources=[3, 17, 40])
        for col, u in zip(cols, (3, 17, 40)):
            assert np.asarray(col.values).sum() / 2 == res.values[u]


# ---------------------------------------------------------------------------
# random walks: determinism + distributional invariants
# ---------------------------------------------------------------------------
def test_random_walks_deterministic_and_batch_invariant(zoo_graph):
    _, _, path = zoo_graph
    with GraphSession(path) as sess:
        a = sess.run_batch("random_walk", sources=[1, 5, 9], length=12,
                           seed=7)
        b = sess.run_batch("random_walk", sources=[1, 5, 9], length=12,
                           seed=7)
        for x, y in zip(a, b):  # fixed seed => bitwise reproducible
            np.testing.assert_array_equal(x.values, y.values)
        # column k is a pure function of (seed, source): solo == batched
        solo = sess.run_batch("random_walk", sources=[5], length=12, seed=7)
        np.testing.assert_array_equal(a[1].values, solo[0].values)
        # a different seed decorrelates
        c = sess.run_batch("random_walk", sources=[1, 5, 9], length=12,
                           seed=8)
        assert any(not np.array_equal(x.values, y.values)
                   for x, y in zip(a, c))
        # no dead ends on this graph: every walk takes every step, and
        # visit counts include the starting position
        for col in a:
            assert np.asarray(col.values).sum() == 13
            assert col.iterations == 12


def test_random_walks_match_transition_matrix(tmp_path):
    """Mean visit counts over many seeds converge to the oracle expectation
    sum_{t<=L} e_s P^t, where P is the uniform in-neighbor transition."""
    n, L, S, source = 16, 6, 400, 3
    src, dst = _symmetric_graph(11, n, n)
    store = _build_store(tmp_path, src, dst, n)
    P = np.zeros((n, n))
    for v in range(n):
        nbrs = src[dst == v]  # walks step along the pull layout's in-edges
        P[v, nbrs] = 1.0 / len(nbrs)
    expect = np.zeros(n)
    state = np.zeros(n)
    state[source] = 1.0
    for _ in range(L + 1):
        expect += state
        state = state @ P
    with GraphSession(store) as sess:
        total = np.zeros(n)
        for seed in range(S):
            col = sess.run_batch("random_walk", sources=[source], length=L,
                                 seed=seed)[0]
            total += np.asarray(col.values)
    tv = 0.5 * np.abs(total / total.sum() - expect / expect.sum()).sum()
    assert tv < 0.08, f"total-variation distance {tv:.3f}"


# ---------------------------------------------------------------------------
# the differential matrix: every registered app, every configuration
# (invocation tables + runner live in tests/_zoo_runner.py, shared with the
#  GRAPHMP_DEVICES=2 subprocess leg)
# ---------------------------------------------------------------------------
def test_zoo_covers_every_app():
    """The invocation tables span the live registry — a new @register_app
    without a matrix entry fails here, keeping the zoo differential."""
    for info in list_apps():
        if info.kind == "alias":  # covered through their batched family
            assert info.family is not None
        elif info.kind == "batched":
            assert info.name in BATCH_ARGS, f"add {info.name} to BATCH_ARGS"
        else:  # vertex programs and drivers (batched drivers batch-dispatch)
            assert info.name in SOLO_ARGS or info.name in BATCH_ARGS, \
                f"add {info.name} to SOLO_ARGS or BATCH_ARGS"


_REFERENCE = {}  # cache_mode -> zoo results at (npz, prefetch=0)


def _reference(path, cache_mode):
    if cache_mode not in _REFERENCE:
        _REFERENCE[cache_mode] = run_zoo(path, backend="npz",
                                         cache_mode=cache_mode,
                                         prefetch_depth=0)
    return _REFERENCE[cache_mode]


MATRIX = [pytest.param(b, m, p, id=f"{b}-mode{m}-pf{p}")
          for b in ("npz", "packed", "memory")
          for m in (0, "adaptive")
          for p in (0, 2)
          if not (b == "npz" and m == 0 and p == 0)]  # the reference itself


@pytest.mark.parametrize("backend,cache_mode,prefetch", MATRIX)
def test_differential_matrix(zoo_graph, backend, cache_mode, prefetch):
    """Every app: bitwise-equal values and identical disk-byte accounting
    against the npz/prefetch-0 reference at the same cache mode."""
    _, _, path = zoo_graph
    ref = _reference(path, cache_mode)
    got = run_zoo(path, backend=backend, cache_mode=cache_mode,
                  prefetch_depth=prefetch)
    assert got.keys() == ref.keys()
    for name in ref:
        np.testing.assert_array_equal(
            got[name][0], ref[name][0],
            err_msg=f"{name}: values diverged under {backend}/"
                    f"{cache_mode}/pf{prefetch}")
        assert got[name][1] == ref[name][1], (
            f"{name}: disk bytes {got[name][1]} != reference {ref[name][1]}")


def test_values_invariant_across_cache_modes(zoo_graph):
    """Cache modes change I/O accounting, never values: the mode-0 and
    adaptive references agree bitwise app by app."""
    _, _, path = zoo_graph
    a, b = _reference(path, 0), _reference(path, "adaptive")
    for name in a:
        np.testing.assert_array_equal(a[name][0], b[name][0], err_msg=name)


def _runner_pythonpath():
    """src + this test directory (the subprocess imports the shared
    _zoo_runner module instead of duplicating the invocation tables)."""
    import os
    from pathlib import Path
    repo = Path(__file__).resolve().parent.parent
    return os.pathsep.join([str(repo / "src"), str(repo / "tests")])


def test_differential_matrix_two_devices(zoo_graph):
    """The GRAPHMP_DEVICES=2 leg: the whole zoo, bitwise + byte-identical
    to the single-device run of the same configuration (packed backend,
    adaptive cache, prefetch 2 — the serving default shape)."""
    from tests.test_sharded_session import run_with_devices
    _, _, path = zoo_graph
    solo = run_zoo(path, backend="packed", cache_mode="adaptive",
                   prefetch_depth=2)
    code = f"""
    import json
    import _zoo_runner as zoo
    results = zoo.run_zoo({path!r}, backend="packed",
                          cache_mode="adaptive", prefetch_depth=2)
    print(json.dumps(zoo.digest(results)))
    """
    out = run_with_devices(code, n_devices=2, extra_env={
        "GRAPHMP_DEVICES": "2",
        "PYTHONPATH": _runner_pythonpath()})
    got = json.loads(out.strip().splitlines()[-1])
    assert got == digest(solo)


# ---------------------------------------------------------------------------
# registry introspection (satellite: no hard-coded app lists downstream)
# ---------------------------------------------------------------------------
def test_list_apps_classifies_the_zoo():
    kinds = {i.name: i.kind for i in list_apps()}
    assert kinds["label_propagation"] == "vertex"
    assert kinds["kcore"] == "vertex"
    assert kinds["lp_multi"] == "batched"
    assert kinds["kcore_multi"] == "batched"
    assert kinds["triangles_multi"] == "batched"
    assert kinds["triangles"] == "driver"
    assert kinds["random_walks"] == "driver"
    for alias in ("ppr", "lp", "triangle_count", "random_walk"):
        assert kinds[alias] == "alias"
    fams = {i.name: i.family for i in list_apps()}
    assert fams["kcore"] == "plus_src/kcore_multi"
    assert fams["lp"] == "max_src/lp_multi"
    incr = {i.name: i.incremental for i in list_apps()}
    assert incr["label_propagation"] and not incr["kcore"]


def test_service_serves_the_whole_registry(zoo_graph):
    _, _, path = zoo_graph
    with GraphSession(path) as sess, sess.service() as svc:
        served = set(svc._served_apps())
        assert {i.name for i in list_apps()} <= served


def test_driver_dispatch_guards(zoo_graph):
    _, _, path = zoo_graph
    with GraphSession(path) as sess:
        with pytest.raises(TypeError, match="host-driven"):
            sess.run("random_walks", sources=(1,), checkpoint_dir="/tmp/x",
                     checkpoint_every=2)
        with pytest.raises(TypeError, match="host-driven"):
            next(sess.iter_run("triangles"))
        with pytest.raises(TypeError, match="host-driven"):
            sess.engine("triangles")
        with pytest.raises(TypeError, match="not a batched application"):
            sess.run_batch("triangles", sources=[1])
        with pytest.raises(ValueError, match="thresholds"):
            sess.run_batch("kcore", sources=[-1])
