"""AdaptiveServeController: deterministic control-law tests + close races.

``tick()`` is clock-free (it consumes reservoir/occupancy DELTAS), so a
fake service with hand-fed latencies drives every law branch with no
sleeping and no real traffic.  The close-race tests at the bottom use a
real service: controller and service must shut down cleanly in EITHER
order (the ISSUE's close-race satellite).
"""
import dataclasses
import time

import pytest

from repro.obs.controller import AdaptiveServeController, ControllerConfig
from repro.serve.graph_service import (GraphService, ServiceClosed,
                                       ServiceConfig, ServiceStats)
from repro.session import GraphSession


class FakeService:
    """stats + config + reconfigure — all the controller touches."""

    def __init__(self, **cfg):
        self.config = ServiceConfig(**cfg)
        self.stats = ServiceStats()
        self.queue_depth = 0
        self.reconfigures: list[dict] = []
        self.closed = False

    def reconfigure(self, **changes):
        if self.closed:
            raise ServiceClosed("service is closing")
        self.reconfigures.append(changes)
        self.config = dataclasses.replace(self.config, **changes)
        return self.config

    def feed(self, latency_s: float, n: int = 16, occupancy: int = 1):
        """n completed requests at latency_s, in batches of `occupancy`."""
        for _ in range(n):
            self.stats.record_latency(latency_s)
        for _ in range(max(n // occupancy, 1)):
            self.stats.record_batch(occupancy)


def make(svc=None, **overrides) -> tuple:
    svc = svc if svc is not None else FakeService(max_batch=8,
                                                 max_wait_ms=5.0)
    config = overrides.pop("config", None)
    if config is None:
        overrides.setdefault("slo_p99_ms", 50.0)
    ctl = AdaptiveServeController(svc, config, **overrides)
    return svc, ctl


# ---------------------------------------------------------------------------
# the law, branch by branch
# ---------------------------------------------------------------------------
def test_raise_wait_on_low_occupancy_under_slo():
    svc, ctl = make()
    svc.feed(0.005, n=32, occupancy=1)  # 5 ms << 50 ms SLO, singleton sweeps
    d = ctl.tick()
    assert d.action == "raise_wait"
    assert svc.config.max_wait_ms > 5.0
    assert svc.reconfigures == [dict(max_batch=8,
                                     max_wait_ms=svc.config.max_wait_ms)]


def test_shrink_wait_on_breach_with_low_occupancy_terminates():
    svc, ctl = make()
    for i in range(60):
        svc.feed(0.2, n=16, occupancy=1)  # 200 ms >> SLO, window suspect
        d = ctl.tick()
        if svc.config.max_wait_ms <= ctl.config.min_wait_ms:
            break
        assert d.action == "shrink_wait", d
    # the progress floor walks the window all the way down, then holds
    assert svc.config.max_wait_ms == ctl.config.min_wait_ms
    svc.feed(0.2, n=16, occupancy=1)
    d = ctl.tick()
    assert d.action == "hold" and "limits" in d.reason


def test_raise_wait_on_breach_with_coalescing_occupancy():
    """A breach with full-ish sweeps is queueing, not straggler-waiting:
    the right move is MORE coalescing (wider window), never less."""
    svc, ctl = make()
    svc.feed(0.2, n=32, occupancy=4)  # breach, mean occupancy 4 >= 2.0
    d = ctl.tick()
    assert d.action == "raise_wait" and "coalescing" in d.reason
    assert svc.config.max_wait_ms > 5.0


def test_raise_batch_on_breach_with_deep_queue_and_clamp():
    svc, ctl = make(max_batch_limit=16)
    for _ in range(10):
        svc.queue_depth = 10 * svc.config.max_batch
        svc.feed(0.2, n=16, occupancy=4)
        ctl.tick()
    assert svc.config.max_batch == 16  # stepped up, hard-clamped at limit
    assert any(len(ctl.decisions) and d.action == "raise_batch"
               for d in ctl.decisions)


def test_hysteresis_band_holds():
    svc, ctl = make(hysteresis=0.15)
    svc.feed(0.055, n=32, occupancy=1)  # 55 ms: above SLO, inside the band
    d = ctl.tick()
    assert d.action == "hold" and ctl.adjustments == 0


def test_predictive_guard_blocks_risky_raise():
    # p99 ~40 ms, low band 42.5 ms: headroom is 2.5 ms, but the smallest
    # raise would add 5 ms of potential wait -> the guard holds
    svc, ctl = make(svc=FakeService(max_batch=8, max_wait_ms=10.0))
    svc.feed(0.040, n=32, occupancy=1)
    d = ctl.tick()
    assert d.action == "hold" and "risk" in d.reason


def test_wait_raise_clamped_at_limit():
    svc, ctl = make(max_wait_ms_limit=12.0)
    for _ in range(20):
        svc.feed(0.001, n=16, occupancy=1)
        ctl.tick()
    assert svc.config.max_wait_ms <= 12.0


def test_thin_window_holds_and_counts_toward_convergence():
    svc, ctl = make(settle_ticks=3)
    svc.feed(0.2, n=4)  # 4 < min_samples=8: never trusted
    for _ in range(3):
        d = ctl.tick()
        assert d.action == "hold" and "thin" in d.reason
    assert ctl.converged and ctl.adjustments == 0


def test_no_oscillation_on_steady_in_band_traffic():
    """Steady traffic with p99 inside the band: zero knob moves, converged
    latches, and stays latched."""
    svc, ctl = make(settle_ticks=5)
    for _ in range(12):
        svc.feed(0.048, n=32, occupancy=2)
        assert ctl.tick().action == "hold"
    assert ctl.converged and ctl.adjustments == 0
    svc.feed(0.005, n=32, occupancy=1)  # regime change: headroom appears
    assert ctl.tick().action == "raise_wait"
    assert not ctl.converged  # adjustment resets settling


def test_converged_after_breach_recovery():
    svc, ctl = make(settle_ticks=2)
    svc.feed(0.2, n=16, occupancy=1)
    assert ctl.tick().action == "shrink_wait"
    for _ in range(2):
        svc.feed(0.048, n=16, occupancy=1)
        ctl.tick()
    assert ctl.converged and ctl.adjustments == 1


def test_decisions_history_and_publish_to_hub():
    from repro.obs import MetricsHub

    hub = MetricsHub()
    svc = FakeService(max_batch=8, max_wait_ms=5.0)
    ctl = AdaptiveServeController(svc, hub=hub, slo_p99_ms=50.0, history=4)
    for _ in range(6):
        svc.feed(0.2, n=16, occupancy=1)
        ctl.tick()
    assert len(ctl.decisions) == 4  # bounded
    snap = hub.sample()
    assert snap["gauges"]["controller.max_wait_ms"] == svc.config.max_wait_ms
    assert snap["counters"]["controller.adjustments"] >= 1
    assert ctl.last_decision is ctl.decisions[-1]


def test_tick_propagates_service_closed():
    svc, ctl = make()
    svc.closed = True
    svc.feed(0.2, n=16, occupancy=1)
    with pytest.raises(ServiceClosed):
        ctl.tick()


def test_config_validation_and_overrides():
    with pytest.raises(ValueError):
        ControllerConfig(slo_p99_ms=0)
    with pytest.raises(ValueError):
        ControllerConfig(min_batch=0)
    with pytest.raises(ValueError):
        ControllerConfig(min_wait_ms=5, max_wait_ms_limit=1)
    with pytest.raises(ValueError):
        ControllerConfig(hysteresis=1.0)
    with pytest.raises(ValueError):
        ControllerConfig(step=1.0)
    base = ControllerConfig(slo_p99_ms=99.0)
    _, ctl = make(svc=None, config=base, step=2.0)
    assert ctl.config.slo_p99_ms == 99.0 and ctl.config.step == 2.0


# ---------------------------------------------------------------------------
# close races against a REAL service (either shutdown order is clean)
# ---------------------------------------------------------------------------
def _real_service(graph_store):
    sess = GraphSession(graph_store)
    svc = GraphService(sess, ServiceConfig(max_batch=4, max_wait_ms=2.0))
    return sess, svc


def test_close_service_then_stop_controller(graph_store):
    sess, svc = _real_service(graph_store)
    try:
        ctl = AdaptiveServeController(svc, slo_p99_ms=50.0, interval_s=0.01)
        ctl.start()
        svc.submit("bfs", source=0, max_iters=50).result(timeout=120)
        svc.close(drain=True)   # service goes first
        deadline = time.monotonic() + 5.0
        while ctl._thread is not None and ctl._thread.is_alive():
            if time.monotonic() > deadline:
                raise AssertionError("controller loop did not exit")
            time.sleep(0.01)
        ctl.stop()              # already-dead loop: still clean
        assert ctl.error is None
    finally:
        sess.close()


def test_stop_controller_then_close_service(graph_store):
    sess, svc = _real_service(graph_store)
    try:
        with AdaptiveServeController(svc, slo_p99_ms=50.0,
                                     interval_s=0.01) as ctl:
            svc.submit("bfs", source=1, max_iters=50).result(timeout=120)
        # controller stopped by the context exit; service still live
        assert ctl.error is None
        assert svc.submit("bfs", source=2,
                          max_iters=50).result(timeout=120) is not None
        svc.close(drain=True)
    finally:
        sess.close()
