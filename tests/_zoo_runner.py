"""Shared app-zoo runner: one invocation table for every registered app.

Used by tests/test_app_zoo.py in-process AND by its GRAPHMP_DEVICES=2
subprocess leg (which imports this module instead of duplicating the
tables), so the differential matrix compares exactly the same calls.
"""
import hashlib

import numpy as np

from repro.core.apps import list_apps
from repro.session import GraphSession

PR_ITERS = 20
# per-app invocation arguments; test_zoo_covers_every_app pins these tables
# to the live registry, so registering an app without extending them fails
SOLO_ARGS = {
    "pagerank": {"max_iters": PR_ITERS},
    "sssp": {"source": 5},
    "bfs": {"source": 7},
    "cc": {},
    "label_propagation": {},
    "kcore": {"k": 2},
    "triangles": {"chunk": 64},
}
BATCH_ARGS = {
    "sssp_multi": {"sources": (1, 5, 9)},
    "bfs_multi": {"sources": (2, 6)},
    "personalized_pagerank": {"seeds": (3, 11), "max_iters": PR_ITERS},
    "lp_multi": {"sources": (0, 5, 9)},
    "kcore_multi": {"ks": (2, 3)},
    "triangles_multi": {"vertices": (1, 2, 3)},
    "random_walks": {"sources": (1, 5, 9), "length": 12, "seed": 3},
}


def run_zoo(path, **session_kwargs):
    """name -> (values, total disk bytes) for every registered app."""
    out = {}
    with GraphSession(path, **session_kwargs) as sess:
        for info in list_apps():
            if info.kind == "alias":
                continue
            if info.name in BATCH_ARGS:  # batched programs AND batched drivers
                kw = dict(BATCH_ARGS[info.name])
                sess.run_batch(info.name, max_iters=kw.pop("max_iters", 400),
                               **kw)
                res = sess.last_batch_result
            else:
                kw = dict(SOLO_ARGS[info.name])
                res = sess.run(info.name, max_iters=kw.pop("max_iters", 400),
                               **kw)
            out[info.name] = (np.asarray(res.values),
                              sum(h.disk_bytes for h in res.history))
    return out


def digest(results):
    """JSON-able fingerprint: sha256 of the value bytes + disk total."""
    return {name: [hashlib.sha256(np.ascontiguousarray(vals).tobytes())
                   .hexdigest(), int(disk)]
            for name, (vals, disk) in sorted(results.items())}
