"""Validates the roofline delta method (EXPERIMENTS.md §Roofline-method):

1. XLA's cost model counts scan bodies once (the reason the method exists);
2. delta-extrapolated FLOPs from (r=1, r=2) unrolled programs match a
   directly fully-unrolled r=R program;
3. the collective-bytes HLO parser agrees with hand-computed byte counts
   on a known psum/all_gather program.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.dryrun import collective_bytes

REPO = Path(__file__).resolve().parent.parent


def test_scan_bodies_counted_once():
    w = jnp.zeros((256, 256))

    def single(x):
        return x @ w

    def scanned(x):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)
        return out

    x = jnp.zeros((256, 256))
    f1 = jax.jit(single).lower(x).compile().cost_analysis()["flops"]
    f10 = jax.jit(scanned).lower(x).compile().cost_analysis()["flops"]
    assert abs(f10 / f1 - 1.0) < 0.01  # the deficiency the delta method fixes


def test_delta_extrapolation_matches_direct_unroll():
    w = jnp.zeros((128, 128))
    x = jnp.zeros((128, 128))

    def stack(r, unroll):
        def fn(x):
            out, _ = jax.lax.scan(lambda c, _: (c @ w + c, None), x, None,
                                  length=r, unroll=r if unroll else 1)
            return out
        return fn

    def flops(r, unroll=True):
        return jax.jit(stack(r, unroll)).lower(x).compile().cost_analysis()["flops"]

    R = 7
    f1, f2 = flops(1), flops(2)
    extrapolated = f1 + (R - 1) * (f2 - f1)
    direct = flops(R)
    assert abs(extrapolated - direct) / direct < 0.01


def test_collective_parser_known_program():
    env_code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys; sys.path.insert(0, %r)
        from repro.launch.dryrun import collective_bytes

        mesh = jax.make_mesh((8,), ('d',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def f(x):
            y = jax.lax.psum(x, 'd')            # all-reduce of [1024] f32
            z = jax.lax.all_gather(y, 'd')      # all-gather -> [8,1024] f32
            return z
        fn = jax.shard_map(f, mesh=mesh, in_specs=P('d'), out_specs=P(None, 'd'),
                           check_vma=False)
        x = jnp.zeros((8 * 1024,), jnp.float32)
        txt = jax.jit(fn).lower(x).compile().as_text()
        got = collective_bytes(txt)
        ar = got.get('all-reduce', 0)
        ag = got.get('all-gather', 0)
        assert ar >= 1024 * 4, got          # psum result bytes
        assert ag >= 8 * 1024 * 4, got      # gathered result bytes
        print('OK', got)
    """) % str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", env_code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_collective_parser_units():
    hlo = """
  %x = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %done = bf16[64,128]{1,0} all-gather-done(bf16[64,128] %h)
    """
    got = collective_bytes(hlo)
    assert got["all-gather"] == 64 * 128 * 2
    assert got["all-reduce"] == 256 * 4
    # -done lines must not double count
    assert len(got) == 2
