"""Dry-run machinery validated at test scale: lower+compile reduced archs on
a small forced-device mesh in a subprocess, exercising the exact lower_cell /
delta / collective-parse path the production sweep uses."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.parametrize("arch,shape", [
    ("stablelm-1.6b", "train_4k"),
    ("mixtral-8x22b", "train_4k"),
    ("jamba-v0.1-52b", "decode_32k"),
    ("xlstm-1.3b", "decode_32k"),
])
def test_lower_cell_reduced(arch, shape):
    out = run_with_devices(f"""
        import dataclasses, jax
        from repro.configs import get_config
        from repro.launch.dryrun import lower_cell, analyze_compiled
        from repro.launch.shapes import SHAPES, ShapeSpec

        cfg = get_config('{arch}').reduced()
        shape = SHAPES['{shape}']
        # scale the shape down with the config
        shape = dataclasses.replace(shape, seq_len=64, global_batch=8)
        mesh = jax.make_mesh((2, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        model, lowered = lower_cell(cfg, shape, mesh, unroll=False,
                                    opt_name='adamw')
        rec = analyze_compiled(lowered.compile())
        assert rec['flops'] > 0
        print('OK', rec['flops'], sum(rec['collectives'].values()))
    """)
    assert "OK" in out


def test_multi_pod_mesh_lowering_small():
    """(pod, data, model) mesh lowering — the 'pod' axis shards the batch."""
    out = run_with_devices("""
        import dataclasses, jax
        from repro.configs import get_config
        from repro.launch.dryrun import lower_cell
        from repro.launch.shapes import SHAPES

        cfg = get_config('stablelm-1.6b').reduced()
        shape = dataclasses.replace(SHAPES['train_4k'], seq_len=32, global_batch=8)
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        model, lowered = lower_cell(cfg, shape, mesh, unroll=False,
                                    opt_name='adamw')
        lowered.compile()
        print('OK')
    """)
    assert "OK" in out
