import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 placeholders.


@pytest.fixture(scope="session")
def small_graph():
    """A deterministic RMAT graph shared across engine tests."""
    from repro.graph.generate import rmat_edges, materialize
    src, dst = materialize(rmat_edges(scale=9, edge_factor=8, seed=7))
    return src, dst, 1 << 9


@pytest.fixture(scope="session")
def graph_store(tmp_path_factory, small_graph):
    from repro.graph.storage import write_edge_list
    from repro.graph.preprocess import preprocess_graph
    src, dst, n = small_graph
    base = tmp_path_factory.mktemp("graph")
    write_edge_list(base / "el", [(src, dst)])
    return preprocess_graph(str(base / "el"), str(base / "store"),
                            threshold_edge_num=2048, ell_max_width=256)


def pagerank_oracle(src, dst, n, iters=30, damping=0.85):
    out_deg = np.bincount(src, minlength=n)
    pr = np.full(n, 1.0 / n, np.float64)
    for _ in range(iters):
        contrib = pr / np.maximum(out_deg, 1)
        s = np.zeros_like(pr)
        np.add.at(s, dst, contrib[src])
        pr = (1 - damping) / n + damping * s
    return pr


def min_propagation_oracle(src, dst, n, init, edge_add=0.0, iters=200):
    """Fixpoint of v <- min(v, min_{(u,v)} (u + edge_add)) — SSSP/CC oracle."""
    val = init.astype(np.float64).copy()
    for _ in range(iters):
        new = val.copy()
        np.minimum.at(new, dst, val[src] + edge_add)
        if (new == val).all():
            break
        val = new
    return val
