"""GraphPulse telemetry primitives: Reservoir error bound, MetricsHub
wiring, snapshot schema, and the JSONL emitter.

The load-bearing regression here is the Reservoir's documented quantile
error: every percentile the serving layer now reports (ServiceStats,
controller windows, emitted snapshots) comes from log-binned reservoirs,
so the ``sqrt(growth) - 1`` relative-error bound against exact
nearest-rank percentiles is the contract the rest of the system leans on.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Gauge, MetricsHub, Reservoir,
                               main, validate_file, validate_snapshot)
from repro.serve.graph_service import percentile


# ---------------------------------------------------------------------------
# Reservoir: the documented error bound, pinned
# ---------------------------------------------------------------------------
def test_reservoir_quantile_error_vs_exact_nearest_rank():
    """Relative quantile error <= sqrt(growth) - 1 on heavy-tailed data,
    at every quantile the system reports — the documented contract."""
    rng = np.random.RandomState(7)
    samples = np.exp(rng.normal(-3.5, 1.2, size=5000))  # latency-ish, sec
    res = Reservoir()
    for s in samples:
        res.observe(float(s))
    bound = math.sqrt(res.growth) - 1.0
    ordered = sorted(samples.tolist())
    for q in (10, 50, 90, 95, 99, 99.9):
        exact = percentile(ordered, q)
        approx = res.quantile(q)
        rel = abs(approx - exact) / exact
        assert rel <= bound, f"p{q}: {approx} vs exact {exact}, rel {rel}"


def test_reservoir_exact_moments_and_edges():
    res = Reservoir(min_value=1e-3, max_value=10.0, growth=1.05)
    vals = [0.0, 5e-4, 0.002, 0.5, 2.0, 50.0]  # under-, in-, over-range
    for v in vals:
        res.observe(v)
    assert res.count == len(vals)
    assert res.sum == pytest.approx(sum(vals))
    assert res.min == 0.0 and res.max == 50.0
    assert res.mean == pytest.approx(sum(vals) / len(vals))
    # under-range values report min_value (absolute error <= min_value)
    assert res.quantile(1) == res.min_value
    # over-range values clamp to max_value, never invent a larger number
    assert res.quantile(100) == res.max_value


def test_reservoir_windowed_quantile_from_counts_delta():
    """Subtracting two counts() snapshots yields the percentile of ONLY
    the observations in between — the controller's rolling window."""
    res = Reservoir()
    for _ in range(100):
        res.observe(0.001)
    before = res.counts()
    for _ in range(50):
        res.observe(1.0)
    delta = res.counts() - before
    assert int(delta.sum()) == 50
    # the window contains only ~1.0s observations; lifetime p50 is 1 ms
    assert res.quantile(50, counts=delta) == pytest.approx(1.0, rel=0.02)
    assert res.quantile(50) == pytest.approx(0.001, rel=0.02)


def test_reservoir_empty_and_validation():
    res = Reservoir()
    assert res.quantile(99) == 0.0
    assert res.count == 0 and res.mean == 0.0
    with pytest.raises(ValueError):
        res.quantile(0)
    with pytest.raises(ValueError):
        res.quantile(101)
    with pytest.raises(ValueError):
        Reservoir(min_value=0.0)
    with pytest.raises(ValueError):
        Reservoir(min_value=2.0, max_value=1.0)
    with pytest.raises(ValueError):
        Reservoir(growth=1.0)


def test_reservoir_thread_safety_exact_count():
    res = Reservoir()

    def worker(k):
        for i in range(1000):
            res.observe(1e-3 * (k + 1) + 1e-6 * i)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert res.count == 8000


# ---------------------------------------------------------------------------
# Counter / Gauge
# ---------------------------------------------------------------------------
def test_counter_monotone_and_gauge_last_wins():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(4)
    g.set(1.5)
    assert g.value == 1.5


# ---------------------------------------------------------------------------
# MetricsHub: registry, pollers, snapshots, timeseries
# ---------------------------------------------------------------------------
def test_hub_registry_get_or_create_and_adoption():
    hub = MetricsHub()
    assert hub.counter("a") is hub.counter("a")
    assert hub.gauge("b") is hub.gauge("b")
    assert hub.histogram("h") is hub.histogram("h")
    shared = Reservoir()
    shared.observe(0.25)
    assert hub.adopt_histogram("h", shared) is shared
    assert hub.histogram("h") is shared  # adoption replaced the original
    snap = hub.sample()
    assert snap["histograms"]["h"]["count"] == 1


def test_hub_poller_flattening_and_dead_poller():
    hub = MetricsHub()
    hub.register_poller("cache", lambda: {
        "hits": 10, "nested": {"ratio": 0.5, "deep": [1, 2]},
        "mode": "zlib",        # string leaf: a label, skipped
        "enabled": True,       # bool -> 1.0
        "bad": float("nan"),   # non-finite: skipped
    })
    hub.register_poller("dead", lambda: 1 / 0)
    snap = hub.sample()
    g = snap["gauges"]
    assert g["cache.hits"] == 10.0
    assert g["cache.nested.ratio"] == 0.5
    assert g["cache.nested.deep.0"] == 1.0 and g["cache.nested.deep.1"] == 2.0
    assert g["cache.enabled"] == 1.0
    assert "cache.mode" not in g and "cache.bad" not in g
    assert not any(k.startswith("dead") for k in g)  # dead poller ignored
    validate_snapshot(snap)
    hub.unregister_poller("cache")
    assert "cache.hits" not in hub.sample()["gauges"]


def test_hub_sample_schema_and_timeseries():
    fake_now = [100.0]
    hub = MetricsHub(retain=4, clock=lambda: fake_now[0])
    hub.counter("reqs").inc(3)
    hub.gauge("depth").set(7)
    hub.histogram("lat").observe(0.5)
    for i in range(6):  # more samples than the ring retains
        fake_now[0] = 100.0 + i
        validate_snapshot(hub.sample())
    assert len(hub.snapshots) == 4  # bounded ring
    ts = hub.timeseries("depth")
    assert ts == [(2.0, 7.0), (3.0, 7.0), (4.0, 7.0), (5.0, 7.0)]
    (t, h), *_ = hub.timeseries("lat")
    assert h["count"] == 1 and h["p50"] == pytest.approx(0.5, rel=0.02)
    assert hub.timeseries("nope") == []


# ---------------------------------------------------------------------------
# the emitter + schema validation on disk
# ---------------------------------------------------------------------------
def test_hub_emits_validating_jsonl(tmp_path):
    path = tmp_path / "metrics.jsonl"
    hub = MetricsHub(path, emit_interval=0.05)
    hub.counter("serve.requests").inc(5)
    hub.histogram("serve.latency_s").observe(0.01)
    hub.emit()      # explicit emit
    hub.close()     # close emits one final snapshot
    hub.close()     # idempotent
    hub.emit()      # after close: silently dropped
    n = validate_file(path)
    assert n >= 2
    first = json.loads(path.read_text().splitlines()[0])
    assert first["counters"]["serve.requests"] == 5.0


def test_hub_env_knobs(tmp_path, monkeypatch):
    path = tmp_path / "env_metrics.jsonl"
    monkeypatch.setenv("GRAPHMP_METRICS", str(path))
    monkeypatch.setenv("GRAPHMP_METRICS_INTERVAL", "0.05")
    hub = MetricsHub()  # picks both up from the environment
    assert hub.emit_path == path and hub.emit_interval == 0.05
    hub.gauge("x").set(1)
    hub.close()
    assert validate_file(path) >= 1
    monkeypatch.setenv("GRAPHMP_METRICS", "")
    assert MetricsHub().emit_path is None  # empty disables


def test_validate_snapshot_rejects_malformed():
    good = MetricsHub().sample()
    validate_snapshot(good)
    for mutate in (
        lambda s: s.update(v=2),
        lambda s: s.update(t=-1.0),
        lambda s: s.update(t=float("nan")),
        lambda s: s.pop("gauges"),
        lambda s: s["counters"].update(bad=-1.0),
        lambda s: s["gauges"].update(bad=float("inf")),
        lambda s: s["histograms"].update(bad={"count": 1}),  # missing fields
        lambda s: s["histograms"].update(bad={
            **{f: 0.0 for f in ("sum", "min", "max", "mean",
                                "p50", "p90", "p95", "p99")},
            "count": 1.5}),  # non-int count
    ):
        snap = json.loads(json.dumps(good))
        mutate(snap)
        with pytest.raises(ValueError):
            validate_snapshot(snap)
    with pytest.raises(ValueError):
        validate_snapshot([])


def test_validate_file_and_cli(tmp_path, capsys):
    good = tmp_path / "good.jsonl"
    hub = MetricsHub(good, emit_interval=10.0)
    hub.counter("c").inc()
    hub.close()
    empty = tmp_path / "empty.jsonl"
    empty.write_text("\n")
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"v": 99}\n')
    with pytest.raises(ValueError, match="no snapshots"):
        validate_file(empty)
    with pytest.raises(ValueError, match="bad.jsonl:1"):
        validate_file(bad)
    assert main([str(good)]) == 0
    assert "ok" in capsys.readouterr().out
    assert main([str(good), str(bad)]) == 1
    assert "FAIL" in capsys.readouterr().out
