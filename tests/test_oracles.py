"""Oracle harness: every engine configuration vs independent NumPy references.

The rest of the suite mostly cross-checks apps against each other or against
a single configuration; this module is the independent ground truth.  The
oracles below are straight-line NumPy (no jax, no shards, no semiring
machinery) implementing the textbook definitions, and every (cache mode 0-4)
× (use_pallas False/"auto") engine configuration must reproduce them on a
random graph — exactly for the min-propagation apps, to float tolerance for
PageRank.
"""
import numpy as np
import pytest

from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list
from repro.session import GraphSession

# ---------------------------------------------------------------------------
# pure-NumPy reference implementations (independent of the engine stack)
# ---------------------------------------------------------------------------


def oracle_pagerank(src, dst, n, iters, damping=0.85):
    out_deg = np.bincount(src, minlength=n)
    pr = np.full(n, 1.0 / n, dtype=np.float64)
    for _ in range(iters):
        s = np.zeros(n, dtype=np.float64)
        np.add.at(s, dst, (pr / np.maximum(out_deg, 1))[src])
        pr = (1.0 - damping) / n + damping * s
    return pr


def oracle_sssp(src, dst, n, source, weight=1.0):
    """Bellman-Ford relaxation to fixpoint (unit weights on these graphs)."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    for _ in range(n):
        relaxed = dist.copy()
        np.minimum.at(relaxed, dst, dist[src] + weight)
        if (relaxed == dist).all():
            break
        dist = relaxed
    return dist


def oracle_bfs(src, dst, n, source):
    """Level-by-level frontier expansion over the directed edges."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    frontier = np.zeros(n, dtype=bool)
    frontier[source] = True
    level = 0
    while frontier.any():
        level += 1
        hop = np.zeros(n, dtype=bool)
        hop[dst[frontier[src]]] = True
        hop &= np.isinf(dist)
        dist[hop] = level
        frontier = hop
    return dist


def oracle_cc(src, dst, n):
    """Fixpoint of directed min-label propagation (the engine's CC
    semantics: labels flow along edge direction only)."""
    label = np.arange(n, dtype=np.float64)
    while True:
        new = label.copy()
        np.minimum.at(new, dst, label[src])
        if (new == label).all():
            return label
        label = new


# ---------------------------------------------------------------------------
# one random graph, every engine configuration
# ---------------------------------------------------------------------------
N = 384
PR_ITERS = 15


@pytest.fixture(scope="module")
def oracle_graph(tmp_path_factory):
    rng = np.random.default_rng(1234)
    m = 6 * N
    src = rng.integers(0, N, size=m)
    dst = rng.integers(0, N, size=m)
    base = tmp_path_factory.mktemp("oracle_graph")
    write_edge_list(base / "el", [(src, dst)], num_vertices=N)
    store = preprocess_graph(str(base / "el"), str(base / "store"),
                             threshold_edge_num=512, ell_max_width=128)
    assert store.num_shards > 1  # the sweep must cross shard boundaries
    return src, dst, store


CONFIGS = [pytest.param(mode, up, id=f"mode{mode}-{'pallas' if up == 'auto' else 'jnp'}")
           for mode in (0, 1, 2, 3, 4) for up in (False, "auto")]


def _session(store, mode, use_pallas):
    return GraphSession(store, cache_mode=mode, cache_budget_bytes=1 << 24,
                        use_pallas=use_pallas)


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_pagerank_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("pagerank", max_iters=PR_ITERS)
    np.testing.assert_allclose(
        res.values, oracle_pagerank(src, dst, N, PR_ITERS), atol=1e-6)


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_sssp_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("sssp", source=5, max_iters=200)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_sssp(src, dst, N, 5))


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_bfs_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("bfs", source=7, max_iters=200)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_bfs(src, dst, N, 7))


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_cc_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("cc", max_iters=300)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_cc(src, dst, N))


def test_bfs_and_sssp_oracles_agree():
    """Unit-weight SSSP and BFS levels are the same function — a sanity
    check on the references themselves."""
    rng = np.random.default_rng(7)
    src = rng.integers(0, 64, size=256)
    dst = rng.integers(0, 64, size=256)
    np.testing.assert_array_equal(oracle_sssp(src, dst, 64, 0),
                                  oracle_bfs(src, dst, 64, 0))
