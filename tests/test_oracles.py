"""Oracle harness: every engine configuration vs independent NumPy references.

The rest of the suite mostly cross-checks apps against each other or against
a single configuration; this module is the independent ground truth.  The
oracles below are straight-line NumPy (no jax, no shards, no semiring
machinery) implementing the textbook definitions, and every (cache mode 0-4)
× (use_pallas False/"auto") engine configuration must reproduce them on a
random graph — exactly for the min-propagation apps, to float tolerance for
PageRank.
"""
import numpy as np
import pytest

from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list
from repro.session import GraphSession

try:  # external oracle (optional): cross-validation against NetworkX
    import networkx as nx
except ImportError:  # pragma: no cover - exercised on minimal installs
    nx = None

needs_networkx = pytest.mark.skipif(nx is None,
                                    reason="networkx not installed")

# ---------------------------------------------------------------------------
# pure-NumPy reference implementations (independent of the engine stack)
# ---------------------------------------------------------------------------


def oracle_pagerank(src, dst, n, iters, damping=0.85):
    out_deg = np.bincount(src, minlength=n)
    pr = np.full(n, 1.0 / n, dtype=np.float64)
    for _ in range(iters):
        s = np.zeros(n, dtype=np.float64)
        np.add.at(s, dst, (pr / np.maximum(out_deg, 1))[src])
        pr = (1.0 - damping) / n + damping * s
    return pr


def oracle_sssp(src, dst, n, source, weight=1.0):
    """Bellman-Ford relaxation to fixpoint (unit weights on these graphs)."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    for _ in range(n):
        relaxed = dist.copy()
        np.minimum.at(relaxed, dst, dist[src] + weight)
        if (relaxed == dist).all():
            break
        dist = relaxed
    return dist


def oracle_bfs(src, dst, n, source):
    """Level-by-level frontier expansion over the directed edges."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    frontier = np.zeros(n, dtype=bool)
    frontier[source] = True
    level = 0
    while frontier.any():
        level += 1
        hop = np.zeros(n, dtype=bool)
        hop[dst[frontier[src]]] = True
        hop &= np.isinf(dist)
        dist[hop] = level
        frontier = hop
    return dist


def oracle_cc(src, dst, n):
    """Fixpoint of directed min-label propagation (the engine's CC
    semantics: labels flow along edge direction only)."""
    label = np.arange(n, dtype=np.float64)
    while True:
        new = label.copy()
        np.minimum.at(new, dst, label[src])
        if (new == label).all():
            return label
        label = new


# ---------------------------------------------------------------------------
# one random graph, every engine configuration
# ---------------------------------------------------------------------------
N = 384
PR_ITERS = 15


@pytest.fixture(scope="module")
def oracle_graph(tmp_path_factory):
    rng = np.random.default_rng(1234)
    m = 6 * N
    src = rng.integers(0, N, size=m)
    dst = rng.integers(0, N, size=m)
    base = tmp_path_factory.mktemp("oracle_graph")
    write_edge_list(base / "el", [(src, dst)], num_vertices=N)
    store = preprocess_graph(str(base / "el"), str(base / "store"),
                             threshold_edge_num=512, ell_max_width=128)
    assert store.num_shards > 1  # the sweep must cross shard boundaries
    return src, dst, store


CONFIGS = [pytest.param(mode, up, id=f"mode{mode}-{'pallas' if up == 'auto' else 'jnp'}")
           for mode in (0, 1, 2, 3, 4) for up in (False, "auto")]


def _session(store, mode, use_pallas):
    return GraphSession(store, cache_mode=mode, cache_budget_bytes=1 << 24,
                        use_pallas=use_pallas)


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_pagerank_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("pagerank", max_iters=PR_ITERS)
    np.testing.assert_allclose(
        res.values, oracle_pagerank(src, dst, N, PR_ITERS), atol=1e-6)


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_sssp_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("sssp", source=5, max_iters=200)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_sssp(src, dst, N, 5))


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_bfs_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("bfs", source=7, max_iters=200)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_bfs(src, dst, N, 7))


@pytest.mark.parametrize("mode,use_pallas", CONFIGS)
def test_cc_vs_oracle(oracle_graph, mode, use_pallas):
    src, dst, store = oracle_graph
    res = _session(store, mode, use_pallas).run("cc", max_iters=300)
    assert res.converged
    np.testing.assert_array_equal(res.values, oracle_cc(src, dst, N))


def test_bfs_and_sssp_oracles_agree():
    """Unit-weight SSSP and BFS levels are the same function — a sanity
    check on the references themselves."""
    rng = np.random.default_rng(7)
    src = rng.integers(0, 64, size=256)
    dst = rng.integers(0, 64, size=256)
    np.testing.assert_array_equal(oracle_sssp(src, dst, 64, 0),
                                  oracle_bfs(src, dst, 64, 0))


# ---------------------------------------------------------------------------
# external oracle: NetworkX (closes the in-repo-only-reference gap).  The
# NumPy oracles above and the engine share this repo; NetworkX shares
# nothing with it, so agreement here rules out a common-mode bug.
# ---------------------------------------------------------------------------
def _random_digraph(seed, n, m, symmetric=False, ensure_out=True):
    """Deduplicated random edges; ``ensure_out`` adds the ring edge
    i -> (i+1) % n so no vertex dangles.  Dedup matters: nx.DiGraph
    collapses parallel edges while the engine (and np.add.at) counts them;
    no-dangling matters for PageRank: nx redistributes dangling mass, the
    paper's update lets it leak.  (The ring also connects everything, so
    component tests must pass ensure_out=False.)"""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    if ensure_out:
        src = np.concatenate([src, np.arange(n)])
        dst = np.concatenate([dst, (np.arange(n) + 1) % n])
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    pairs = np.unique(np.stack([src, dst], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _store_for(tmp_path_factory, tag, src, dst, n):
    base = tmp_path_factory.mktemp(tag)
    write_edge_list(base / "el", [(src, dst)], num_vertices=n)
    return preprocess_graph(str(base / "el"), str(base / "store"),
                            threshold_edge_num=512, ell_max_width=128)


NX_SEEDS = (0, 1)


@needs_networkx
@pytest.mark.parametrize("seed", NX_SEEDS)
def test_pagerank_vs_networkx(tmp_path_factory, seed):
    n = 160
    src, dst = _random_digraph(seed, n, 5 * n)
    store = _store_for(tmp_path_factory, f"nx_pr_{seed}", src, dst, n)
    res = GraphSession(store).run("pagerank", max_iters=300)
    assert res.converged
    g = nx.DiGraph(list(zip(src.tolist(), dst.tolist())))
    want = nx.pagerank(g, alpha=0.85, tol=1e-12, max_iter=1000)
    np.testing.assert_allclose(res.values,
                               [want[v] for v in range(n)], atol=1e-5)


@needs_networkx
@pytest.mark.parametrize("seed", NX_SEEDS)
def test_sssp_bfs_vs_networkx(tmp_path_factory, seed):
    n = 200
    src, dst = _random_digraph(seed + 10, n, 3 * n)
    store = _store_for(tmp_path_factory, f"nx_sp_{seed}", src, dst, n)
    g = nx.DiGraph(list(zip(src.tolist(), dst.tolist())))
    sess = GraphSession(store)
    for app, source in (("sssp", 3), ("bfs", 17)):
        res = sess.run(app, source=source, max_iters=n + 1)
        assert res.converged
        lengths = nx.single_source_shortest_path_length(g, source)
        want = np.full(n, np.inf)
        for v, d in lengths.items():
            want[v] = d  # unreachable vertices stay +inf, as in the engine
        np.testing.assert_array_equal(res.values, want)


@needs_networkx
@pytest.mark.parametrize("seed", NX_SEEDS)
def test_cc_vs_networkx(tmp_path_factory, seed):
    """On a SYMMETRIC graph the engine's directed min-label propagation is
    exactly min-vertex-id per (weakly = strongly) connected component."""
    n = 220
    src, dst = _random_digraph(seed + 20, n, n, symmetric=True,
                               ensure_out=False)
    store = _store_for(tmp_path_factory, f"nx_cc_{seed}", src, dst, n)
    res = GraphSession(store).run("cc", max_iters=2 * n)
    assert res.converged
    g = nx.Graph(list(zip(src.tolist(), dst.tolist())))
    g.add_nodes_from(range(n))
    want = np.empty(n)
    for comp in nx.connected_components(g):
        want[list(comp)] = min(comp)
    np.testing.assert_array_equal(res.values, want)
