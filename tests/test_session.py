"""GraphSession behaviour: one entry point, one shared compressed cache.

The economic claim under test is the paper's §2.2/§2.4.2 shape: preprocess
once, then serve many applications from the same shards with the cache
absorbing the disk I/O — a second application on a warm session must read
(almost) nothing from disk, where the old one-private-cache-per-engine API
re-read the whole graph per application.
"""
import numpy as np
import pytest

from repro.core import apps
from repro.core.apps import VertexProgram, available_apps, get_app, register_app
from repro.core.engine import EngineConfig, IterationStats, RunResult
from repro.session import GraphSession


# ---------------------------------------------------------------------------
# (a) shared cache economics
# ---------------------------------------------------------------------------
def test_warm_cache_serves_later_apps_without_disk(graph_store):
    """PR then SSSP then CC through ONE session: at most one full-graph read
    total — after the first app, per-app disk growth stays under 5% of the
    on-disk graph."""
    total = graph_store.total_shard_bytes()
    sess = GraphSession(graph_store, cache_mode=1,
                        cache_budget_bytes=4 * total)  # budget >= graph
    sess.run("pagerank", max_iters=10)
    d1 = sess.stats.disk_bytes
    assert d1 <= 1.05 * total  # one full read (plus rounding), no more
    sess.run("sssp", source=0, max_iters=50)
    d2 = sess.stats.disk_bytes
    sess.run("cc", max_iters=50)
    d3 = sess.stats.disk_bytes
    assert d2 - d1 < 0.05 * total, "sssp re-read the graph"
    assert d3 - d2 < 0.05 * total, "cc re-read the graph"


def test_fresh_engines_pay_per_app_but_session_does_not(graph_store):
    """The regression the session API exists to prevent: per-engine private
    caches re-read the graph for every application."""
    total = graph_store.total_shard_bytes()
    per_engine = 0
    for name in ("pagerank", "cc"):
        s = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=4 * total)
        s.run(name, max_iters=10)
        per_engine += s.stats.disk_bytes
    shared = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=4 * total)
    shared.run("pagerank", max_iters=10)
    shared.run("cc", max_iters=10)
    assert per_engine >= 1.9 * shared.stats.disk_bytes


def test_session_results_match_across_shared_cache(graph_store):
    """Cache sharing is invisible to results."""
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 28)
    pr_warm = sess.run("pagerank", max_iters=15)
    pr_cold = GraphSession(graph_store, cache_mode=0).run("pagerank",
                                                          max_iters=15)
    np.testing.assert_allclose(pr_warm.values, pr_cold.values, atol=1e-7)


def test_rerun_reuses_engine_and_jit_caches(graph_store):
    sess = GraphSession(graph_store)
    e1 = sess.engine("pagerank")
    sess.run("pagerank", max_iters=3)
    assert sess.engine("pagerank") is e1
    # different factory kwargs -> different engine, same shared cache
    e2 = sess.engine("pagerank", damping=0.5)
    assert e2 is not e1
    assert e2.cache is e1.cache is sess.cache


def test_engine_cache_is_lru_bounded(graph_store):
    """A long-lived session answering many distinct landmark sets must not
    retain one jitted engine per set forever.  Same-signature programs now
    solve this outright (ONE engine serves every sssp source); programs
    with genuinely different compiled steps stay LRU-bounded."""
    sess = GraphSession(graph_store, max_engines=2)
    shared = sess.engine("sssp", source=0)
    # every source shares one engine via jit_signature ("sssp",)...
    assert sess.engine("sssp", source=1) is shared
    # ...with the default program rebound to the latest request
    assert shared.program.sources == (1,)
    assert len(sess._engines) == 1
    # distinct signatures (pagerank damping is baked into the jitted post)
    # fill distinct slots, and the oldest is evicted at the bound
    keep = sess.engine("pagerank")
    evicted = sess.engine("pagerank", damping=0.5)  # evicts `shared` (LRU)
    assert len(sess._engines) == 2
    assert sess.engine("pagerank") is keep          # survivor kept identity
    sess.engine("sssp", source=0)                   # evicts damping=0.5
    assert sess.engine("pagerank", damping=0.5) is not evicted  # rebuilt
    with pytest.raises(ValueError, match="max_engines"):
        GraphSession(graph_store, max_engines=0)


# ---------------------------------------------------------------------------
# (b) registry round-trip
# ---------------------------------------------------------------------------
def test_register_app_round_trip(graph_store):
    # explicit name deliberately differs from the function name: the
    # registry must honour the decorator argument, not __name__
    @register_app("frontier_walk")
    def _my_custom_factory():
        base = apps.sssp(0)
        import dataclasses
        return dataclasses.replace(base, name="frontier_walk")

    assert "frontier_walk" in available_apps()
    assert "_my_custom_factory" not in available_apps()
    assert isinstance(get_app("frontier_walk"), VertexProgram)
    sess = GraphSession(graph_store)
    res = sess.run("frontier_walk", max_iters=5)
    assert isinstance(res, RunResult)
    # repeat dispatch must reuse the cached engine without tripping the
    # jit-compatibility check (fresh factory instance each call; regression:
    # custom apps with the inherited signature — or none at all — reran fine
    # once and raised on the second run)
    res2 = sess.run("frontier_walk", max_iters=5)
    np.testing.assert_array_equal(res.values, res2.values)

    @register_app("sigless_walk")
    def _sigless_factory():
        import dataclasses
        return dataclasses.replace(apps.cc(), name="sigless_walk",
                                   jit_signature=None)

    for _ in range(2):  # name-keyed engines (no signature) rerun fine too
        sess.run("sigless_walk", max_iters=3)
    # tripwire: overriding a device callable while KEEPING the inherited
    # jit_signature must raise, not silently run the old compiled post
    import dataclasses
    bad = dataclasses.replace(
        apps.sssp(0), name="bad_walk",
        post=lambda partial, old, n: partial + old)
    with pytest.raises(ValueError, match="must also replace jit_signature"):
        sess.run(bad, max_iters=3)
    # cleanup: keep the registry stable for other tests
    del apps._REGISTRY["frontier_walk"]
    del apps._REGISTRY["sigless_walk"]


def test_builtin_apps_registered():
    assert {"pagerank", "sssp", "cc", "bfs"} <= set(available_apps())
    # deprecated alias stays live
    assert apps.APPS["pagerank"] is apps.pagerank


def test_unknown_app_name_raises(graph_store):
    with pytest.raises(KeyError, match="unknown graph application"):
        GraphSession(graph_store).run("nope")


def test_factory_kwargs_dispatch(graph_store):
    res = GraphSession(graph_store).run("sssp", source=3, max_iters=50)
    assert res.values[3] == 0.0


# ---------------------------------------------------------------------------
# (c) EngineConfig validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bad", [
    dict(cache_mode=7),
    dict(cache_mode=-1),
    dict(cache_mode="fast"),
    dict(cache_mode=True),
    dict(cache_budget_bytes=-4096),
    dict(cache_budget_bytes=1.5),
    dict(cache_hot_fraction=0.0),
    dict(cache_hot_fraction=1.5),
    dict(cache_promote_after=0),
    dict(selective_threshold=float("nan")),
    dict(use_pallas="maybe"),
])
def test_engine_config_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        EngineConfig(**bad)


def test_engine_config_budget_zero_means_no_cache(graph_store):
    """budget=0 is valid and degrades to mode 0 (no application cache)."""
    sess = GraphSession(graph_store, cache_budget_bytes=0)
    assert sess.cache.mode == 0 and not sess.cache.adaptive
    sess.run("pagerank", max_iters=2)
    assert sess.cache.cached_shards == 0
    assert sess.stats.hits == 0


def test_engine_config_replace_and_env(monkeypatch):
    cfg = EngineConfig()
    assert cfg.replace(cache_mode=2).cache_mode == 2
    assert cfg.cache_mode == "auto"  # frozen: replace does not mutate
    monkeypatch.setenv("GRAPHMP_CACHE_MODE", "3")
    monkeypatch.setenv("GRAPHMP_CACHE_BUDGET_BYTES", str(1 << 20))
    # the primary name would shadow the legacy alias under test (e.g. on the
    # CI tight-budget leg, which exports GRAPHMP_CACHE_BUDGET suite-wide)
    monkeypatch.delenv("GRAPHMP_CACHE_BUDGET", raising=False)
    env_cfg = EngineConfig.from_env()
    assert env_cfg.cache_mode == 3
    assert env_cfg.cache_budget_bytes == 1 << 20
    # explicit overrides beat the environment
    assert EngineConfig.from_env(cache_mode=1).cache_mode == 1


def test_session_kwarg_overrides(graph_store):
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=1 << 22)
    assert sess.config.cache_mode == 1
    assert sess.cache.budget == 1 << 22


# ---------------------------------------------------------------------------
# (d) checkpoint / resume through the session
# ---------------------------------------------------------------------------
def test_checkpoint_resume_through_session(graph_store, tmp_path):
    full = GraphSession(graph_store).run("pagerank", max_iters=20)
    interrupted = GraphSession(graph_store)
    interrupted.run("pagerank", max_iters=10,
                    checkpoint_dir=str(tmp_path), checkpoint_every=5)
    resumed = GraphSession(graph_store).run(
        "pagerank", max_iters=20, checkpoint_dir=str(tmp_path), resume=True)
    np.testing.assert_allclose(resumed.values, full.values, atol=1e-6)


# ---------------------------------------------------------------------------
# streaming + throughput accounting
# ---------------------------------------------------------------------------
def test_iter_run_streams_iteration_stats(graph_store):
    sess = GraphSession(graph_store)
    gen = sess.iter_run("pagerank", max_iters=7)
    seen = []
    while True:
        try:
            seen.append(next(gen))
        except StopIteration as stop:
            result = stop.value
            break
    assert len(seen) == 7
    assert all(isinstance(s, IterationStats) for s in seen)
    assert [s.iteration for s in seen] == list(range(7))
    assert isinstance(result, RunResult)
    assert result.iterations == 7
    assert sess.engine("pagerank").last_result is result


def test_edges_per_second_weights_by_shard_nnz(graph_store):
    """Skipping light shards must not inflate throughput: processed edges are
    summed per shard nnz, and a full run processes exactly E per iteration."""
    sess = GraphSession(graph_store)
    res = sess.run("pagerank", max_iters=4)
    E = graph_store.num_edges
    assert res.total_edges_processed == 4 * E
    assert res.edges_per_second() == pytest.approx(
        4 * E / res.total_seconds, rel=1e-6)


def test_run_many_order_and_types(graph_store):
    sess = GraphSession(graph_store)
    results = sess.run_many(
        ["cc", ("sssp", {"source": 0}), apps.bfs(0)], max_iters=5)
    assert [type(r) for r in results] == [RunResult] * 3


# ---------------------------------------------------------------------------
# (e) cache invariants under arbitrary access sequences
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [0, 1, 2, 3, 4])
def test_cache_budget_invariant_under_random_gets(graph_store, mode):
    """cached_bytes <= budget must hold after EVERY get, in every mode, even
    with a budget too small to hold the whole graph."""
    from repro.core.cache import CompressedShardCache
    budget = max(graph_store.shard_nbytes(0) * 2, 1 << 16)
    cache = CompressedShardCache(graph_store, mode=mode, budget_bytes=budget)
    rng = np.random.default_rng(mode)
    for sid in rng.integers(0, graph_store.num_shards, size=60):
        shard = cache.get(int(sid))
        assert shard.shard_id == int(sid)
        assert cache.cached_bytes <= cache.budget
    assert cache.stats.hits + cache.stats.misses == 60


def test_cache_stats_count_correctly(graph_store):
    """hits/misses/evictions against a hand-walked access sequence."""
    from repro.core.cache import CompressedShardCache
    cache = CompressedShardCache(graph_store, mode=1, budget_bytes=1 << 28)
    cache.get(0)            # miss
    cache.get(0)            # hit
    cache.get(1)            # miss
    cache.get(0)            # hit
    assert (cache.stats.hits, cache.stats.misses) == (2, 2)
    assert cache.stats.hit_ratio == pytest.approx(0.5)
    assert cache.stats.evictions == 0
    # budget that fits exactly one cached shard forces one eviction per swap
    e0 = cache._entry_nbytes(cache.get(0))
    e1 = cache._entry_nbytes(cache.get(1))
    tight = CompressedShardCache(graph_store, mode=1,
                                 budget_bytes=max(e0, e1))
    tight.get(0)
    assert tight.cached_shards == 1
    tight.get(1)  # fits, but only after evicting shard 0
    assert tight.cached_bytes <= tight.budget
    tight.get(0)
    assert tight.stats.hits == 0  # every access was a fresh read
    assert tight.stats.evictions == 2


def test_cache_clear_rereads_from_disk_and_keeps_stats(graph_store):
    from repro.core.cache import CompressedShardCache
    cache = CompressedShardCache(graph_store, mode=1, budget_bytes=1 << 28)
    cache.get(0)
    cache.get(0)
    hits, misses = cache.stats.hits, cache.stats.misses
    disk = cache.stats.disk_bytes
    cache.clear()
    assert cache.cached_bytes == 0 and cache.cached_shards == 0
    # stats survive the clear (lifetime counters, not per-epoch)
    assert (cache.stats.hits, cache.stats.misses) == (hits, misses)
    cache.get(0)  # must be a disk re-read, not a stale hit
    assert cache.stats.misses == misses + 1
    assert cache.stats.disk_bytes > disk


# ---------------------------------------------------------------------------
# (f) per-iteration cache_hit_ratio (regression: was the lifetime ratio)
# ---------------------------------------------------------------------------
def test_iteration_hit_ratio_is_per_iteration_not_lifetime(graph_store):
    """A warm-cache second run must report hit ratio 1.0 for EVERY iteration;
    the old code reported the cache's lifetime ratio, which the cold first
    run drags permanently below 1."""
    total = graph_store.total_shard_bytes()
    sess = GraphSession(graph_store, cache_mode=1, cache_budget_bytes=4 * total)
    first = sess.run("cc", max_iters=5)
    # iteration 0 of the cold run reads everything from disk
    assert first.history[0].cache_hit_ratio == 0.0
    assert all(h.cache_hit_ratio == 1.0 for h in first.history[1:])
    second = sess.run("pagerank", max_iters=5)
    assert sess.stats.hit_ratio < 1.0  # lifetime ratio includes cold misses
    assert all(h.cache_hit_ratio == 1.0 for h in second.history)
