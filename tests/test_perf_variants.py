"""§Perf levers must not change semantics: each hillclimb knob is validated
for numerical sanity before its roofline effect is claimed."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import OptConfig, make_init_state, make_train_step
from repro.train.data import SyntheticLM


def _losses(model, steps=30, lr=3e-3):
    opt = OptConfig(peak_lr=lr, warmup_steps=5, decay_steps=200)
    state = make_init_state(model, opt)(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(model.cfg.vocab_size, 32, 8)
    out = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s % 4).items()}
        state, m = step(state, batch)
        out.append(float(m["loss"]))
    return out


def test_ssm_bf16_scan_close_to_f32():
    cfg = get_config("jamba-v0.1-52b").reduced()
    m32 = build_model(cfg, ssm_dtype="float32", remat=False)
    m16 = build_model(cfg, ssm_dtype="bfloat16", remat=False)
    params = m32.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)))}
    l32, _ = jax.jit(m32.loss_fn)(params, batch)
    l16, _ = jax.jit(m16.loss_fn)(params, batch)
    assert abs(float(l32) - float(l16)) < 5e-2, (float(l32), float(l16))


def test_ssm_bf16_scan_still_learns():
    cfg = get_config("jamba-v0.1-52b").reduced()
    losses = _losses(build_model(cfg, ssm_dtype="bfloat16"))
    assert losses[-1] < losses[0] - 1.0


def test_remat_dots_policy_identical_loss():
    cfg = get_config("stablelm-1.6b").reduced()
    m_a = build_model(cfg, remat_policy="nothing")
    m_b = build_model(cfg, remat_policy="dots")
    params = m_a.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))}
    ga = jax.jit(jax.grad(lambda p, b: m_a.loss_fn(p, b)[0]))(params, batch)
    gb = jax.jit(jax.grad(lambda p, b: m_b.loss_fn(p, b)[0]))(params, batch)
    fa = jax.tree_util.tree_leaves(ga)
    fb = jax.tree_util.tree_leaves(gb)
    for a, b in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
