"""Quantized edge values (int8/fp16): helpers, dequant-in-kernel, codecs,
engine end-to-end, and the GRAPHMP_DEVICES=2 fused-kernel leg.

Tolerance contract (docs/ARCHITECTURE.md "Kernels"):
  * vs the fp32 oracle on the TRUE values — bounded error: per-edge
    |v - v_hat| <= scale/2 for int8 (affine, range widened to include 0)
    and <= 2^-11 |v| for fp16; min/max semirings propagate the per-edge
    bound unamplified.
  * across dispatch paths (pallas fused / pallas fold / jnp fallback) —
    BITWISE on exact (min/max) semirings: every path applies the identical
    (q - zero) * scale arithmetic, so the referee property survives
    quantization.
  * vs the fp32 oracle on the DEQUANTIZED values — bitwise when the
    semiring's combine ignores the edge value (max_src/min_src); within
    1 ulp for min_plus, where backends may contract dequant-multiply +
    semiring-add into a single-rounded FMA (identically on every path).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.shards import (ELLShard, dequantize_edge_vals,
                               quantize_edge_vals, quantize_shard)
from repro.kernels.spmv import ref, spmv
from repro.kernels.spmv.ops import ell_spmv, ell_spmv_batch

REPO = Path(__file__).resolve().parent.parent
EXACT_SEMIS = ["min_plus", "max_src"]
QDTYPES = ["int8", "float16"]


# ---------------------------------------------------------------------------
# quantizer helpers
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    vals = (rng.random((64, 128), np.float32) * 20 - 5).astype(np.float32)
    q, scale, zero = quantize_edge_vals(vals, "int8")
    assert q.dtype == np.int8
    err = np.abs(dequantize_edge_vals(q, scale, zero) - vals)
    assert float(err.max()) <= scale / 2 + 1e-7


def test_int8_constant_and_zero_exact():
    const = np.full((8, 16), 3.25, np.float32)
    q, scale, zero = quantize_edge_vals(const, "int8")
    assert np.array_equal(dequantize_edge_vals(q, scale, zero), const)
    # 0 is always exactly representable (padded slots store 0)
    with_zero = np.array([[0.0, 7.5]], np.float32)
    q, scale, zero = quantize_edge_vals(with_zero, "int8")
    assert dequantize_edge_vals(q, scale, zero)[0, 0] == 0.0
    # ...including when vmin < 0 makes the raw zero point fractional: the
    # quantizer rounds it to an integer so dequant(q(0)) == 0.0 exactly
    mixed = np.array([[-3.7, 0.0, 11.1]], np.float32)
    q, scale, zero = quantize_edge_vals(mixed, "int8")
    assert zero == np.rint(zero)
    assert dequantize_edge_vals(q, scale, zero)[0, 1] == 0.0


def test_float16_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    vals = rng.random((32, 64), np.float32).astype(np.float32)
    q, scale, zero = quantize_edge_vals(vals, "float16")
    assert (q.dtype, scale, zero) == (np.float16, 1.0, 0.0)
    err = np.abs(dequantize_edge_vals(q, scale, zero) - vals)
    assert float(err.max()) <= 2.0 ** -11 * float(np.abs(vals).max()) + 1e-7


def test_quantize_shard_fields_and_accounting():
    rng = np.random.default_rng(2)
    cols = rng.integers(-1, 100, (16, 128)).astype(np.int32)
    vals = rng.random((16, 128), np.float32)
    s = ELLShard(0, 0, 10, cols, vals, np.arange(16, dtype=np.int32),
                 int((cols >= 0).sum()))
    q = quantize_shard(s, "int8")
    assert q.quantized and q.vals.dtype == np.int8
    # decoded-byte accounting shrinks with the stored dtype (cache budgets
    # and pipeline staged-bytes see the compressed footprint)
    assert q.decoded_nbytes() < s.decoded_nbytes()
    np.testing.assert_allclose(q.vals_f32(), vals, atol=q.val_scale / 2 + 1e-7)
    # re-quantizing to float32 restores a plain shard
    back = quantize_shard(q, "float32")
    assert not back.quantized and back.val_scale == 1.0


# ---------------------------------------------------------------------------
# dequant-in-kernel vs oracles
# ---------------------------------------------------------------------------
def _problem(rng, n=700, R=64, W=256, K=4):
    cols = rng.integers(-1, n, size=(R, W)).astype(np.int32)
    vals = (rng.random((R, W), np.float32) * 4 - 1).astype(np.float32)
    x = rng.random((n, K)).astype(np.float32)
    row_map = np.sort(rng.integers(0, R // 2, size=R)).astype(np.int32)
    return cols, vals, x, row_map


@pytest.mark.parametrize("semiring", EXACT_SEMIS)
@pytest.mark.parametrize("dtype", QDTYPES)
def test_quantized_paths_bitwise_identical(semiring, dtype):
    """All three dispatch paths (forced-Pallas fused, forced-jnp, auto)
    produce bit-identical results on quantized values — the referee
    property the engine's correctness story leans on."""
    rng = np.random.default_rng(3)
    cols, vals, x, row_map = _problem(rng)
    R = cols.shape[0]
    q, scale, zero = quantize_edge_vals(vals, dtype)
    qp = jnp.asarray([scale, zero], jnp.float32)
    outs1 = [np.asarray(ell_spmv(
        jnp.asarray(x[:, 0]), jnp.asarray(cols), jnp.asarray(q),
        jnp.asarray(row_map), R, semiring, use_pallas=up, qparams=qp))
        for up in (True, False, "auto")]
    assert np.array_equal(outs1[0], outs1[1])
    assert np.array_equal(outs1[0], outs1[2])
    outsK = [np.asarray(ell_spmv_batch(
        jnp.asarray(x), jnp.asarray(cols), jnp.asarray(q),
        jnp.asarray(row_map), R, semiring, use_pallas=up, qparams=qp))
        for up in (True, False, "auto")]
    assert np.array_equal(outsK[0], outsK[1])
    assert np.array_equal(outsK[0], outsK[2])


@pytest.mark.parametrize("semiring", EXACT_SEMIS)
@pytest.mark.parametrize("dtype", QDTYPES)
@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "jnp"])
def test_quantized_vs_dequantized_oracle(semiring, dtype, use_pallas):
    """vs the fp32 oracle on pre-dequantized values: bitwise for max_src
    (combine ignores the edge value); within 1 ulp for min_plus, where the
    backend single-rounds dequant * scale + src into an FMA."""
    rng = np.random.default_rng(3)
    cols, vals, x, row_map = _problem(rng)
    R = cols.shape[0]
    q, scale, zero = quantize_edge_vals(vals, dtype)
    qp = jnp.asarray([scale, zero], jnp.float32)
    vdq = jnp.asarray(dequantize_edge_vals(q, scale, zero))
    out1 = np.asarray(ell_spmv(
        jnp.asarray(x[:, 0]), jnp.asarray(cols), jnp.asarray(q),
        jnp.asarray(row_map), R, semiring, use_pallas=use_pallas, qparams=qp))
    want1 = np.asarray(ref.ell_spmv_ref(
        jnp.asarray(x[:, 0]), jnp.asarray(cols), vdq, jnp.asarray(row_map),
        R, semiring))
    outK = np.asarray(ell_spmv_batch(
        jnp.asarray(x), jnp.asarray(cols), jnp.asarray(q),
        jnp.asarray(row_map), R, semiring, use_pallas=use_pallas, qparams=qp))
    wantK = np.asarray(ref.ell_spmv_batch_ref(
        jnp.asarray(x), jnp.asarray(cols), vdq, jnp.asarray(row_map), R,
        semiring))
    if semiring == "max_src":
        assert np.array_equal(out1, want1)
        assert np.array_equal(outK, wantK)
    else:  # min_plus: 1-ulp FMA contraction slack
        np.testing.assert_allclose(out1, want1, rtol=3e-7)
        np.testing.assert_allclose(outK, wantK, rtol=3e-7)


@pytest.mark.parametrize("dtype", QDTYPES)
def test_quantized_tolerance_vs_fp32_oracle(dtype):
    """min_plus: the result error vs TRUE fp32 values is bounded by the
    per-edge quantization error (min propagates, never amplifies)."""
    rng = np.random.default_rng(4)
    cols, vals, x, row_map = _problem(rng)
    R = cols.shape[0]
    q, scale, zero = quantize_edge_vals(vals, dtype)
    qp = jnp.asarray([scale, zero], jnp.float32)
    out = np.asarray(ell_spmv(jnp.asarray(x[:, 0]), jnp.asarray(cols),
                              jnp.asarray(q), jnp.asarray(row_map), R,
                              "min_plus", use_pallas=True, qparams=qp))
    want = np.asarray(ref.ell_spmv_ref(jnp.asarray(x[:, 0]), jnp.asarray(cols),
                                       jnp.asarray(vals), jnp.asarray(row_map),
                                       R, "min_plus"))
    bound = (scale / 2 if dtype == "int8"
             else 2.0 ** -11 * float(np.abs(vals).max()))
    finite = np.isfinite(want)
    assert float(np.abs(out[finite] - want[finite]).max()) <= bound + 1e-6


@pytest.mark.parametrize("dtype", QDTYPES)
def test_fused_kernel_dequantizes(dtype):
    """The fused in-kernel-gather path dequantizes identically too."""
    rng = np.random.default_rng(5)
    cols, vals, x, _ = _problem(rng)
    q, scale, zero = quantize_edge_vals(vals, dtype)
    qp = jnp.asarray([scale, zero], jnp.float32)
    vdq = jnp.asarray(dequantize_edge_vals(q, scale, zero))
    out = spmv.ell_spmv_fused_pallas(jnp.asarray(x), jnp.asarray(cols),
                                     jnp.asarray(q), "min_plus",
                                     interpret=True, qparams=qp)
    xg = jnp.asarray(x)[np.where(cols >= 0, cols, 0)]
    unfused = spmv.ell_fold_batch_pallas(xg, jnp.asarray(q), jnp.asarray(cols),
                                         "min_plus", interpret=True,
                                         qparams=qp)
    assert np.array_equal(np.asarray(out), np.asarray(unfused))
    want = ref.ell_fold_batch_ref(xg, vdq, jnp.asarray(cols), "min_plus")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-7)


def test_bfloat16_vals_not_dequantized():
    """bf16 edge values are a compute dtype, not a quantized storage dtype —
    they must pass through the semiring untouched (no qparams arithmetic)."""
    rng = np.random.default_rng(6)
    cols, vals, x, row_map = _problem(rng)
    R = cols.shape[0]
    vb = jnp.asarray(vals).astype(jnp.bfloat16)
    xb = jnp.asarray(x[:, 0]).astype(jnp.bfloat16)
    out = ell_spmv(xb, jnp.asarray(cols), vb, jnp.asarray(row_map), R,
                   "min_plus", use_pallas=True)
    want = ref.ell_spmv_ref(xb, jnp.asarray(cols), vb, jnp.asarray(row_map),
                            R, "min_plus")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2)


# ---------------------------------------------------------------------------
# storage round-trips (all three ShardSource backends)
# ---------------------------------------------------------------------------
def _weighted_store(tmp_path, val_dtype, name="store"):
    from repro.graph.generate import materialize, rmat_edges
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import write_edge_list

    src, dst = materialize(rmat_edges(scale=8, edge_factor=8, seed=13))
    el = tmp_path / f"el_{name}"
    if not (el / "meta.json").exists():
        write_edge_list(el, [(src, dst)], weighted=True)
    return preprocess_graph(str(el), str(tmp_path / name),
                            threshold_edge_num=1024, ell_max_width=256,
                            val_dtype=val_dtype)


@pytest.mark.parametrize("dtype", QDTYPES)
def test_quantized_blob_roundtrip_three_backends(tmp_path, dtype):
    from repro.graph.memory import MemoryGraphStore
    from repro.graph.packed import PackedGraphStore, pack_graph
    from repro.graph.source import unpack_shard_npz

    store = _weighted_store(tmp_path, dtype)
    assert store.properties["val_dtype"] == dtype
    packed = PackedGraphStore(pack_graph(store))
    mem = MemoryGraphStore.from_source(store)
    for p in range(store.num_shards):
        base = store.read_shard(p)
        assert base.vals.dtype == np.dtype(dtype)
        for other in (packed.read_shard(p), mem.read_shard(p),
                      unpack_shard_npz(p, store.read_shard_bytes(p)),
                      unpack_shard_npz(p, packed.read_shard_bytes(p)),
                      unpack_shard_npz(p, mem.read_shard_bytes(p))):
            assert other.vals.dtype == base.vals.dtype
            assert np.array_equal(other.vals, base.vals)
            assert (other.val_scale, other.val_zero) == \
                (base.val_scale, base.val_zero)
            assert np.array_equal(other.cols, base.cols)


def test_unweighted_store_ignores_edge_dtype(tmp_path, monkeypatch):
    """Unweighted graphs keep unit float32 vals (the npz codec elides them);
    GRAPHMP_EDGE_DTYPE only applies to weighted inputs."""
    from repro.graph.generate import materialize, rmat_edges
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import write_edge_list

    monkeypatch.setenv("GRAPHMP_EDGE_DTYPE", "int8")
    src, dst = materialize(rmat_edges(scale=7, edge_factor=4, seed=3))
    write_edge_list(tmp_path / "el", [(src, dst)])
    store = preprocess_graph(str(tmp_path / "el"), str(tmp_path / "store"),
                             threshold_edge_num=1024)
    assert store.properties["val_dtype"] == "float32"
    assert store.read_shard(0).vals.dtype == np.float32


def test_env_knob_and_validation(tmp_path, monkeypatch):
    from repro.graph.preprocess import resolve_val_dtype

    monkeypatch.delenv("GRAPHMP_EDGE_DTYPE", raising=False)
    assert resolve_val_dtype(None) == "float32"
    monkeypatch.setenv("GRAPHMP_EDGE_DTYPE", "float16")
    assert resolve_val_dtype(None) == "float16"
    assert resolve_val_dtype("int8") == "int8"  # explicit arg wins
    with pytest.raises(ValueError):
        resolve_val_dtype("int4")


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", QDTYPES)
def test_session_quantized_pallas_vs_jnp_bitwise(tmp_path, dtype):
    """SSSP over a quantized store: forced-Pallas (fused, dequant-in-kernel)
    and forced-jnp (host dequant formula) agree bitwise — the referee
    property the CI kernels job leans on."""
    from repro.core.engine import EngineConfig
    from repro.session import GraphSession

    store = _weighted_store(tmp_path, dtype)
    outs = {}
    for up in (True, False):
        sess = GraphSession(store, config=EngineConfig(use_pallas=up))
        res = sess.run("sssp", source=0)
        outs[up] = np.asarray(res.values)
    assert np.array_equal(outs[True], outs[False])


def test_session_quantized_close_to_fp32(tmp_path):
    """int8 SSSP distances track the fp32 store within hops * scale/2."""
    from repro.session import GraphSession

    f32 = _weighted_store(tmp_path, "float32", name="s32")
    q8 = _weighted_store(tmp_path, "int8", name="s8")
    r32 = GraphSession(f32).run("sssp", source=0)
    r8 = GraphSession(q8).run("sssp", source=0)
    a, b = np.asarray(r32.values), np.asarray(r8.values)
    finite = np.isfinite(a) & np.isfinite(b)
    assert (np.isfinite(a) == np.isfinite(b)).all()
    scale = max(s.val_scale for s in (q8.read_shard(p)
                                      for p in range(q8.num_shards)))
    hops = max(r32.iterations, r8.iterations)
    assert float(np.abs(a[finite] - b[finite]).max()) <= hops * scale / 2 + 1e-5


def test_delta_mutation_keeps_quantized_dtype(tmp_path):
    """Edge mutations on a quantized store re-quantize the merged shard at
    the store's recorded val_dtype and runs still work."""
    from repro.graph.delta import DeltaGraphStore
    from repro.session import GraphSession

    store = _weighted_store(tmp_path, "int8")
    delta = DeltaGraphStore(store)
    n = store.num_vertices
    delta.apply(inserts=[(0, n - 1, 0.5), (1, n - 1, 0.25)])
    merged_dirty = [delta.read_shard(p) for p in range(delta.num_shards)
                    if delta.shard_epoch(p) > 0]
    assert merged_dirty, "mutation should dirty at least one shard"
    assert all(s.vals.dtype == np.int8 for s in merged_dirty)
    res = GraphSession(delta).run("sssp", source=0)
    assert np.isfinite(np.asarray(res.values)).any()


# ---------------------------------------------------------------------------
# GRAPHMP_DEVICES=2 leg: fused kernel under the sharded engine
# ---------------------------------------------------------------------------
def test_sharded_engine_fused_bitwise_two_devices(tmp_path):
    """ShardedVSWEngine with GRAPHMP_USE_PALLAS=1 (fused kernels) over a
    quantized store is bitwise-identical to the single-device engine."""
    code = textwrap.dedent("""
        import numpy as np
        from repro.graph.generate import rmat_edges, materialize
        from repro.graph.storage import write_edge_list
        from repro.graph.preprocess import preprocess_graph
        from repro.core.engine import EngineConfig
        from repro.session import GraphSession
        import tempfile

        src, dst = materialize(rmat_edges(scale=8, edge_factor=8, seed=13))
        base = tempfile.mkdtemp()
        write_edge_list(base + "/el", [(src, dst)], weighted=True)
        store = preprocess_graph(base + "/el", base + "/store",
                                 threshold_edge_num=1024, ell_max_width=256,
                                 val_dtype="int8")
        vals = {}
        for d in (1, 2):
            cfg = EngineConfig(use_pallas=True, num_devices=d)
            res = GraphSession(store, config=cfg).run("sssp", source=0)
            vals[d] = np.asarray(res.values)
        assert np.array_equal(vals[1], vals[2]), "D=2 diverged from D=1"
        print("OK", np.isfinite(vals[1]).sum())
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = str(REPO / "src")
    env["GRAPHMP_USE_PALLAS"] = "1"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "OK" in r.stdout
