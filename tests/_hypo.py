"""Optional-hypothesis shim: property tests SKIP (not error) when the
container lacks hypothesis.  Import ``given``/``settings``/``st`` from here
instead of from hypothesis directly.

Every ``@given`` test — present or absent hypothesis — also carries the
``hypothesis`` pytest marker (registered in pyproject.toml), so CI can
shard property tests from the deterministic suite with ``-m hypothesis`` /
``-m "not hypothesis"``.  ``prop_settings`` is the shared settings profile:
no deadline (the first example pays the jit compiles) and a CI-sized
example budget.
"""
import pytest

try:
    from hypothesis import given as _hypothesis_given  # noqa: F401
    from hypothesis import settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.hypothesis(
                _hypothesis_given(*args, **kwargs)(fn))
        return deco

    def prop_settings(max_examples: int = 25, **kw):
        return settings(deadline=None, max_examples=max_examples, **kw)

except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.hypothesis(
                pytest.mark.skip(reason="hypothesis not installed")(fn))
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def prop_settings(max_examples: int = 25, **kw):
        return settings()

    class _Strategies:
        """Inert placeholder: any attribute access or call chains to
        another placeholder, so strategy expressions at decoration time
        (st.lists(st.integers(0, 5)).map(f)) evaluate harmlessly."""

        def __getattr__(self, name):
            return _Strategies()

        def __call__(self, *args, **kwargs):
            return _Strategies()

    st = _Strategies()
