"""Optional-hypothesis shim: property tests SKIP (not error) when the
container lacks hypothesis.  Import ``given``/``settings``/``st`` from here
instead of from hypothesis directly."""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Inert placeholder: any attribute access or call chains to
        another placeholder, so strategy expressions at decoration time
        (st.lists(st.integers(0, 5)).map(f)) evaluate harmlessly."""

        def __getattr__(self, name):
            return _Strategies()

        def __call__(self, *args, **kwargs):
            return _Strategies()

    st = _Strategies()
