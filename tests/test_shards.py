"""Algorithm 1 + CSR/ELL layout properties (hypothesis)."""
import numpy as np

from tests._hypo import given, settings, st

from repro.core.shards import (LANE, SUBLANE, build_csr_shards, compute_intervals,
                               csr_to_ell, iter_edges)


@given(st.lists(st.integers(0, 50), min_size=1, max_size=200),
       st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_intervals_partition_and_respect_threshold(degs, threshold):
    deg = np.asarray(degs, dtype=np.int64)
    starts = compute_intervals(deg, threshold)
    # partition: consecutive, covering, disjoint
    assert starts[0] == 0 and starts[-1] == len(deg)
    assert (np.diff(starts) >= 1).all()
    # threshold respected except for unavoidable singleton heavy vertices
    csum = np.concatenate([[0], np.cumsum(deg)])
    for a, b in zip(starts[:-1], starts[1:]):
        edges = csum[b] - csum[a]
        assert edges <= threshold or b - a == 1


@given(st.integers(1, 6), st.integers(0, 400), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_csr_ell_roundtrip_preserves_edges(seed, n_edges, logn):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, n_edges)
    dst = rng.integers(0, n, n_edges)
    val = rng.random(n_edges).astype(np.float32)
    shards = build_csr_shards(src, dst, n, threshold_edge_num=64, val=val)
    # every edge appears in exactly one shard; destination owned by shard
    seen = []
    for sh in shards:
        for s, d, v in iter_edges(sh):
            assert sh.start_vertex <= d < sh.end_vertex
            seen.append((s, d, np.float32(v)))
        ell = csr_to_ell(sh, max_width=LANE)
        # ELL geometry
        R, W = ell.shape
        assert R % SUBLANE == 0 and W % LANE == 0
        # edge multiset preserved CSR -> ELL (per destination row)
        got = []
        for r in range(R):
            m = ell.cols[r] >= 0
            for c, v in zip(ell.cols[r][m], ell.vals[r][m]):
                got.append((int(c), sh.start_vertex + int(ell.row_map[r]),
                            np.float32(v)))
        assert sorted(got) == sorted(
            (s, d, v) for (s, d, v) in seen
            if sh.start_vertex <= d < sh.end_vertex)
        seen = [e for e in seen if not (sh.start_vertex <= e[1] < sh.end_vertex)]
    assert not seen or len(shards) == 0


def test_heavy_vertex_row_wrapping():
    """A vertex whose in-degree exceeds the ELL width wraps onto many rows."""
    n = 16
    src = np.arange(1000) % n
    dst = np.zeros(1000, dtype=np.int64)  # all edges into vertex 0
    shards = build_csr_shards(src, dst, n, threshold_edge_num=1 << 20)
    ell = csr_to_ell(shards[0], max_width=128)
    rows_for_v0 = (ell.row_map == 0).sum() if ell.nnz else 0
    assert (ell.cols >= 0).sum() == 1000
    assert rows_for_v0 >= 1000 // 128
