"""ShardSource backends are interchangeable: identical results, identical
disk-byte accounting.

The property under test is the redesign's contract: prefetch_depth ∈
{0, 1, 4} × backend ∈ {npz, packed, memory} × cache mode is invisible to
``RunResult.values`` (bitwise) AND to the reported disk bytes — the pipeline
fetches in schedule order through one worker, and every backend charges
reads at the shard's canonical nbytes, so Table-3 accounting cannot drift
with the storage layer or the overlap depth.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.graph.memory import MemoryGraphStore
from repro.graph.packed import PackedGraphStore, is_packed_file, pack_graph
from repro.graph.source import MissingGraphError, ShardSource
from repro.graph.storage import GraphStore
from repro.session import GraphSession

BACKENDS = ("npz", "packed", "memory")
DEPTHS = (0, 1, 4)
# 0 and 2 cover the no-cache and compressed static paths (mode 2 uses zstd
# on CI, stdlib zlib where zstandard is absent — both deterministic)
MODES = (0, 2)
APPS = {
    "pagerank": dict(kwargs={}, max_iters=5),
    "sssp": dict(kwargs={"source": 0}, max_iters=100),
}


@pytest.fixture(scope="module")
def packed_store(graph_store):
    return pack_graph(graph_store)  # writes <store>/packed.gmpk


def _run(graph_store, backend, depth, mode, app):
    spec = APPS[app]
    sess = GraphSession(str(graph_store.path), backend=backend,
                        cache_mode=mode, prefetch_depth=depth)
    res = sess.run(app, max_iters=spec["max_iters"], **spec["kwargs"])
    return res, sess


@pytest.fixture(scope="module")
def reference(graph_store, packed_store):
    """(app, mode) -> (values, disk_bytes) on the npz backend, depth 0."""
    out = {}
    for app in APPS:
        for mode in MODES:
            res, sess = _run(graph_store, "npz", 0, mode, app)
            out[(app, mode)] = (res.values, sess.stats.disk_bytes)
    return out


@pytest.mark.parametrize("app", sorted(APPS))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_and_depth_invisible_to_results_and_accounting(
        graph_store, packed_store, reference, backend, depth, mode, app):
    if backend == "npz" and depth == 0:
        pytest.skip("this combination IS the reference")
    res, sess = _run(graph_store, backend, depth, mode, app)
    ref_values, ref_disk = reference[(app, mode)]
    np.testing.assert_array_equal(res.values, ref_values)
    assert sess.stats.disk_bytes == ref_disk
    assert sess.config.prefetch_depth == depth


# ---------------------------------------------------------------------------
# the same contract for every TIER configuration of the adaptive cache:
# budget ∈ {tiny, one_shard, ample} × depth ∈ {0, 2} × backend — results
# bitwise-identical to the static cache, disk-byte accounting invariant to
# backend and prefetch depth, and the budget never exceeded
# ---------------------------------------------------------------------------
TIER_BUDGETS = ("tiny", "one_shard", "ample")


def _tier_budget(store, kind: str) -> int:
    largest = max(store.shard_nbytes(p) for p in range(store.num_shards))
    if kind == "tiny":
        return max(largest // 2, 1 << 10)  # below the largest single shard
    if kind == "one_shard":
        return largest
    return 4 * store.total_shard_bytes()   # ample: everything can go hot


def _run_adaptive(graph_store, backend, depth, budget):
    sess = GraphSession(str(graph_store.path), backend=backend,
                        cache_mode="adaptive", cache_budget_bytes=budget,
                        prefetch_depth=depth)
    res = sess.run("pagerank", max_iters=5)
    return res, sess


@pytest.fixture(scope="module")
def adaptive_reference(graph_store, packed_store):
    """budget kind -> disk_bytes of the npz depth-0 adaptive run."""
    out = {}
    for kind in TIER_BUDGETS:
        _, sess = _run_adaptive(graph_store, "npz", 0,
                                _tier_budget(graph_store, kind))
        out[kind] = sess.stats.disk_bytes
    return out


@pytest.mark.parametrize("budget_kind", TIER_BUDGETS)
@pytest.mark.parametrize("depth", (0, 2))
@pytest.mark.parametrize("backend", BACKENDS)
def test_adaptive_tiers_invisible_to_results_and_accounting(
        graph_store, packed_store, reference, adaptive_reference,
        backend, depth, budget_kind):
    budget = _tier_budget(graph_store, budget_kind)
    res, sess = _run_adaptive(graph_store, backend, depth, budget)
    # bitwise-identical to the static cache (mode-0 reference values)
    np.testing.assert_array_equal(res.values, reference[("pagerank", 0)][0])
    # disk-byte accounting invariant to backend and overlap depth
    assert sess.stats.disk_bytes == adaptive_reference[budget_kind]
    # the strict budget held (and the tier split stayed consistent)
    assert sess.cache.audit() <= budget
    if budget_kind == "ample":
        # ample budget: static economics — exactly one miss per shard
        assert sess.stats.misses == graph_store.num_shards


# ---------------------------------------------------------------------------
# backend selection plumbing
# ---------------------------------------------------------------------------
def test_every_backend_satisfies_the_protocol(graph_store, packed_store):
    sources = [GraphStore(graph_store.path), PackedGraphStore(packed_store),
               MemoryGraphStore.from_source(graph_store)]
    for s in sources:
        assert isinstance(s, ShardSource)
        assert s.num_shards == graph_store.num_shards
        assert s.total_shard_bytes() == graph_store.total_shard_bytes()


def test_packed_file_path_is_sniffed(graph_store, packed_store):
    assert is_packed_file(packed_store)
    sess = GraphSession(str(packed_store))
    assert isinstance(sess.store, PackedGraphStore)


def test_unknown_backend_rejected(graph_store):
    with pytest.raises(ValueError, match="unknown backend"):
        GraphSession(str(graph_store.path), backend="tape")


def test_backend_kwarg_conflicts_with_store_object(graph_store):
    with pytest.raises(TypeError, match="backend="):
        GraphSession(graph_store, backend="npz")


def test_store_object_shim_still_works(graph_store):
    # the pre-backend GraphSession(store=GraphStore(...)) construction path
    sess = GraphSession(store=graph_store)
    assert sess.store is graph_store


# ---------------------------------------------------------------------------
# packed format round trip + zero-copy
# ---------------------------------------------------------------------------
def test_packed_round_trip(graph_store, packed_store):
    packed = PackedGraphStore(packed_store)
    assert packed.properties["num_edges"] == graph_store.num_edges
    np.testing.assert_array_equal(packed.intervals, graph_store.intervals)
    for a, b in zip(packed.read_vertex_info(), graph_store.read_vertex_info()):
        np.testing.assert_array_equal(a, b)
    for p in range(graph_store.num_shards):
        got, want = packed.read_shard(p), graph_store.read_shard(p)
        np.testing.assert_array_equal(got.cols, want.cols)
        np.testing.assert_array_equal(got.vals, want.vals)
        np.testing.assert_array_equal(got.row_map, want.row_map)
        assert (got.start_vertex, got.end_vertex, got.nnz) == \
               (want.start_vertex, want.end_vertex, want.nnz)
        assert packed.shard_nbytes(p) == graph_store.shard_nbytes(p)
        np.testing.assert_array_equal(packed.read_bloom(p).bits,
                                      graph_store.read_bloom(p).bits)


def test_packed_shards_are_zero_copy_views(packed_store):
    packed = PackedGraphStore(packed_store)
    shard = packed.read_shard(0)
    for arr in (shard.cols, shard.vals, shard.row_map):
        assert not arr.flags.owndata     # a view into the shared mmap...
        assert not arr.flags.writeable   # ...and read-only


def test_packed_rejects_non_packed_files(tmp_path, packed_store):
    bogus = tmp_path / "bogus.gmpk"
    bogus.write_bytes(b"not a packed graph at all")
    with pytest.raises(MissingGraphError, match="bad magic"):
        PackedGraphStore(bogus)
    with pytest.raises(MissingGraphError, match="packed graph file"):
        PackedGraphStore(tmp_path / "absent.gmpk")
    # intact magic but amputated tail header -> still the clear error class
    truncated = tmp_path / "truncated.gmpk"
    truncated.write_bytes(packed_store.read_bytes()[:1024])
    with pytest.raises(MissingGraphError, match="corrupt or truncated"):
        PackedGraphStore(truncated)


def test_session_close_releases_packed_mmap(graph_store, packed_store):
    # an idle session closes its mmap deterministically: vertex info and
    # blooms are copies, so nothing long-lived pins the mapping
    idle = GraphSession(str(packed_store), cache_mode=0)
    idle.close()
    assert idle.store._mm.closed
    # after a run, jax may still alias shard buffers zero-copy (the packed
    # backend's whole point) — close() must stay silent, not raise BufferError
    ran = GraphSession(str(packed_store), cache_mode=0)
    ran.run("pagerank", max_iters=2)
    ran.close()


def test_pack_cli(graph_store, tmp_path):
    out = tmp_path / "cli.gmpk"
    r = subprocess.run(
        [sys.executable, "-m", "repro.graph.pack", str(graph_store.path),
         str(out)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "packed" in r.stdout and out.is_file()
    assert PackedGraphStore(out).num_shards == graph_store.num_shards


# ---------------------------------------------------------------------------
# canonical blobs + memory backend
# ---------------------------------------------------------------------------
def test_read_shard_bytes_is_canonical_across_backends(graph_store, packed_store):
    from repro.graph.source import unpack_shard_npz
    packed = PackedGraphStore(packed_store)
    mem = MemoryGraphStore.from_source(graph_store)
    for p in range(graph_store.num_shards):
        want = graph_store.read_shard(p)
        for src in (graph_store, packed, mem):
            got = unpack_shard_npz(p, src.read_shard_bytes(p))
            np.testing.assert_array_equal(got.cols, want.cols)
            np.testing.assert_array_equal(got.vals, want.vals)


def test_memory_from_packed_owns_its_arrays(packed_store):
    # RAM-resident means RAM-resident: shards loaded out of the packed
    # backend must be copies, not views that keep the file mmap'd
    mem = MemoryGraphStore.from_source(PackedGraphStore(packed_store))
    shard = mem.read_shard(0)
    for arr in (shard.cols, shard.vals, shard.row_map):
        assert arr.flags.owndata and arr.flags.writeable


def test_memory_backend_accounts_reads(graph_store):
    mem = MemoryGraphStore.from_source(graph_store)
    before = mem.io.read
    mem.read_shard(0)
    assert mem.io.read - before == mem.shard_nbytes(0) == \
        graph_store.shard_nbytes(0)


# ---------------------------------------------------------------------------
# missing/partial graph directories fail with a clear error (not a raw ENOENT)
# ---------------------------------------------------------------------------
def test_missing_graph_dir_raises_clear_error(tmp_path):
    with pytest.raises(MissingGraphError, match="preprocess_graph"):
        GraphSession(str(tmp_path / "never_preprocessed"))


def test_corrupt_property_json_raises_clear_error(tmp_path):
    d = tmp_path / "halfwritten"
    d.mkdir()
    (d / "property.json").write_text("{ not json")
    with pytest.raises(MissingGraphError, match="re-run"):
        GraphStore(d).properties


def test_incomplete_property_json_raises_clear_error(tmp_path):
    d = tmp_path / "partial"
    d.mkdir()
    (d / "property.json").write_text('{"num_vertices": 4}')
    with pytest.raises(MissingGraphError, match="num_shards"):
        GraphStore(d).properties
