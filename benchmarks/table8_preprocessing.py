"""Paper Table 8: preprocessing cost.  Times the 3-step GraphMP pipeline
(degree scan -> bucket -> CSR/ELL+Bloom) and reports measured I/O bytes
against the paper's 5·D·|E| prediction; PSW/ESG partitioning measured for
comparison (ESG cheapest, as in the paper)."""
from __future__ import annotations

import shutil
import time

from benchmarks.common import BENCH_DIR, get_graph, row
from repro.baselines.esg import ESGEngine
from repro.baselines.psw import PSWEngine
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import write_edge_list


def run() -> list[str]:
    out = []
    src, dst, n = get_graph()
    D = 16  # our binary edge record (2 x int64)
    el = BENCH_DIR / "el_t8"
    if not (el / "meta.json").exists():
        write_edge_list(el, [(src, dst)])
    dest = BENCH_DIR / "store_t8"
    shutil.rmtree(dest, ignore_errors=True)
    t0 = time.perf_counter()
    store = preprocess_graph(str(el), str(dest), threshold_edge_num=1 << 16)
    t_g = time.perf_counter() - t0
    io = store.io.read + store.io.written
    pred = 5 * D * len(src)
    out.append(row("table8_preprocess_graphmp", t_g * 1e6,
                   f"s={t_g:.2f};io_MB={io/1e6:.0f};pred_5DE_MB={pred/1e6:.0f};"
                   f"edges_per_s={len(src)/t_g/1e6:.1f}M"))
    t0 = time.perf_counter()
    PSWEngine(str(BENCH_DIR / "psw_t8"), src, dst, n)
    out.append(row("table8_preprocess_psw", (time.perf_counter() - t0) * 1e6,
                   f"s={time.perf_counter()-t0:.2f}"))
    t0 = time.perf_counter()
    ESGEngine(str(BENCH_DIR / "esg_t8"), src, dst, n)
    out.append(row("table8_preprocess_esg", (time.perf_counter() - t0) * 1e6,
                   f"s={time.perf_counter()-t0:.2f}"))
    for d in ("psw_t8", "esg_t8", "store_t8"):
        shutil.rmtree(BENCH_DIR / d, ignore_errors=True)
    return out
