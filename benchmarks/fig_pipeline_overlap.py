"""Async shard pipeline: edges/sec and stall time vs prefetch depth.

The claim under measurement (ISSUE 3 tentpole): streaming shards through the
double-buffered ``ShardPipeline`` hides fetch + decompress + host->device
staging behind the SpMV (paper §2.3's overlap), so edges/sec rises and the
compute loop's stall time falls as ``prefetch_depth`` grows — while disk
bytes stay EXACTLY constant (the single ordered prefetch worker preserves
the cache access sequence).  Measured for depth ∈ {0, 1, 2, 4} on the npz
and packed backends, cold cache (every shard misses: the full fetch cost is
on the table) and warm cache (only staging is left to hide).
"""
from __future__ import annotations

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.session import GraphSession

DEPTHS = (0, 1, 2, 4)
BACKENDS = ("npz", "packed")
MAX_ITERS = 8
REPS = 2


def _measure(path: str, backend: str, depth: int, warm: bool):
    # cold = cache mode 0: EVERY iteration pays the full backend fetch (the
    # overlap target); warm = mode 1 with the whole graph resident, so only
    # host->device staging is left to hide
    with GraphSession(path, backend=backend, cache_mode=1 if warm else 0,
                      prefetch_depth=depth) as sess:
        sess.run("pagerank", max_iters=1)  # warm the jit caches (not measured)
        if warm:
            sess.warm()
        # best of REPS: on small CI boxes a stray scheduler hiccup in one rep
        # otherwise swamps the overlap effect under measurement
        best = None
        disk = None
        for _ in range(REPS):
            disk0 = sess.stats.disk_bytes
            res = sess.run("pagerank", max_iters=MAX_ITERS)
            d = sess.stats.disk_bytes - disk0
            assert disk is None or d == disk  # accounting is deterministic
            disk = d
            cur = (res.edges_per_second(), d,
                   sum(h.stall_seconds for h in res.history),
                   sum(h.fetch_seconds for h in res.history),
                   res.total_seconds)
            if best is None or cur[0] > best[0]:
                best = cur
        return best


def run() -> list[str]:
    out = []
    store = get_store()
    path = str(store.path)
    for backend in BACKENDS:
        for warm in (False, True):
            label = "warm" if warm else "cold"
            disk_seen = set()
            for depth in DEPTHS:
                eps, disk, stall, fetch, secs = _measure(path, backend,
                                                         depth, warm)
                disk_seen.add(disk)
                out.append(row(
                    f"fig_pipeline_{backend}_{label}_depth{depth}",
                    secs * 1e6,
                    f"edges_per_s={eps:.3g};stall_s={stall:.3f};"
                    f"fetch_s={fetch:.3f};disk_MB={disk/1e6:.1f}"))
            # accounting must not drift with overlap depth
            out.append(row(
                f"fig_pipeline_{backend}_{label}_disk_invariant", 0.0,
                f"identical={'yes' if len(disk_seen) == 1 else 'NO'}"))
    return out
