# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig7_selective, fig8_cache_modes, fig10_inmemory,
                            fig_app_zoo, fig_autotune, fig_batch_frontiers,
                            fig_cache_tiers, fig_delta_incremental,
                            fig_multidevice, fig_pipeline_overlap,
                            fig_serve_throughput, grad_compression,
                            kernel_spmv, roofline_report, table2_compression,
                            table3_io_model, table5_apps, table8_preprocessing)
    modules = [
        ("table2_compression", table2_compression),
        ("table3_io_model", table3_io_model),
        ("table5_apps (tables 5-7)", table5_apps),
        ("fig_app_zoo", fig_app_zoo),
        ("table8_preprocessing", table8_preprocessing),
        ("fig7_selective", fig7_selective),
        ("fig8_cache_modes", fig8_cache_modes),
        ("fig10_inmemory (figs 9-10)", fig10_inmemory),
        ("fig_batch_frontiers", fig_batch_frontiers),
        ("fig_cache_tiers", fig_cache_tiers),
        ("fig_pipeline_overlap", fig_pipeline_overlap),
        ("fig_multidevice", fig_multidevice),
        ("fig_serve_throughput", fig_serve_throughput),
        ("fig_delta_incremental", fig_delta_incremental),
        ("fig_autotune", fig_autotune),
        ("kernel_spmv", kernel_spmv),
        ("grad_compression", grad_compression),
        ("roofline_report", roofline_report),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,EXCEPTION", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
