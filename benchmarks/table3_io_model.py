"""Paper Table 3: analytic per-iteration I/O of PSW/ESG/VSP/DSW/VSW, plus a
MEASURED check that our engine's actual disk bytes match the VSW prediction
θ·D·|E| (and that the PSW/ESG baselines match theirs).

Instantiated both with the benchmark graph and with the paper's own datasets
(|V|, |E| from Table 4) so the predicted read volumes can be compared against
the paper's reported behaviour.
"""
from __future__ import annotations

import shutil

import numpy as np

from benchmarks.common import BENCH_DIR, get_graph, get_store, row
from repro.baselines.esg import ESGEngine
from repro.baselines.psw import PSWEngine
from repro.core import apps
from repro.session import GraphSession

C, D = 4, 8  # bytes per vertex record / edge record (f32 value, 2xint32 edge)


def models(V, E, P, davg, theta):
    delta = (1 - np.exp(-davg / P)) * P
    return {
        "PSW": (C * V + 2 * (C + D) * E, C * V + 2 * (C + D) * E),
        "ESG": (C * V + (C + D) * E, C * V + C * E),
        "VSP": (C * (1 + delta) * V + D * E, C * V),
        "DSW": (C * np.sqrt(P) * V + D * E, C * np.sqrt(P) * V),
        "VSW": (theta * D * E, 0),
    }


PAPER_GRAPHS = {  # Table 4 of the paper
    "twitter": (42e6, 1.5e9, 35.3),
    "uk-2007": (134e6, 5.5e9, 41.2),
    "uk-2014": (788e6, 47.6e9, 60.4),
    "eu-2015": (1.1e9, 91.8e9, 85.7),
}


def run() -> list[str]:
    out = []
    # analytic table on the paper's graphs (P from 20M-edge shards, θ=0.2
    # like the paper's EU-2015 cache-0 measurement)
    for name, (V, E, davg) in PAPER_GRAPHS.items():
        P = max(int(E // 20e6), 1)
        m = models(V, E, P, davg, theta=0.2)
        ratios = {k: m["PSW"][0] / max(v[0], 1) for k, v in m.items()}
        out.append(row(f"table3_predicted_read_GB_{name}", 0.0,
                       ";".join(f"{k}={v[0]/1e9:.1f}GB(x{ratios[k]:.0f})"
                                for k, v in m.items())))
    # measured: our engine vs prediction on the bench graph
    src, dst, n = get_graph()
    store = get_store()
    E = store.num_edges
    sess = GraphSession(store, cache_mode=0)
    sess.run("pagerank", max_iters=3)
    per_iter = sess.stats.disk_bytes / 3
    pred = store.total_shard_bytes()  # θ=1 at cache-0: every shard read once
    out.append(row("table3_measured_vsw_read", 0.0,
                   f"bytes/iter={per_iter/1e6:.1f}MB;pred={pred/1e6:.1f}MB;"
                   f"ratio={per_iter/pred:.2f}"))
    # baselines measured (1 iteration I/O pattern)
    sub = slice(0, min(len(src), 1 << 18))
    psw = PSWEngine(str(BENCH_DIR / "psw_t3"), src[sub], dst[sub], n)
    psw.io.reset()
    psw.run(apps.pagerank(), max_iters=2)
    esg = ESGEngine(str(BENCH_DIR / "esg_t3"), src[sub], dst[sub], n)
    esg.io.reset()
    esg.run(apps.pagerank(), max_iters=2)
    ne = sub.stop
    psw_pred = (C * n + 2 * (C + D) * ne) * 2
    esg_pred = (C * n + (C + D) * ne) * 2
    out.append(row("table3_measured_psw_read", 0.0,
                   f"bytes={psw.io.read/1e6:.1f}MB;pred={psw_pred/1e6:.1f}MB"))
    out.append(row("table3_measured_esg_read", 0.0,
                   f"bytes={esg.io.read/1e6:.1f}MB;pred={esg_pred/1e6:.1f}MB"))
    shutil.rmtree(BENCH_DIR / "psw_t3", ignore_errors=True)
    shutil.rmtree(BENCH_DIR / "esg_t3", ignore_errors=True)
    return out
