"""Two-tier adaptive edge cache vs. the paper's static modes (beyond-paper).

Sweeps cache budget × tier policy over warm PageRank iterations:

  * policies: adaptive (two-tier, frequency promotion) against the static
    mode-1/2/4 baselines (fig8_cache_modes.py is the paper's original
    static sweep at one budget);
  * budgets: tight (35% of the raw graph — eviction pressure, the regime
    the cold tier exists for) and ample (4× the raw graph — the regime the
    hot tier exists for: zero decode on every warm hit).

Reported per cell: warm-run edges/sec (the cold first run is separate),
tier occupancy, hit ratio, decompress seconds actually paid,
decode-seconds-saved by the hot tier, and promotion/demotion/eviction
counters.  The acceptance shape: at an ample budget the adaptive cache
beats static mode-2/mode-4 on warm edges/sec (it stops paying decompression
once the working set promotes) with decode_seconds_saved > 0.
"""
from __future__ import annotations

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.session import GraphSession

WARM_ITERS = 10
POLICIES = (
    ("adaptive", "adaptive"),
    ("static_mode1", 1),
    ("static_mode2", 2),
    ("static_mode4", 4),
)


def run() -> list[str]:
    out = []
    store = get_store()
    S = store.total_shard_bytes()
    for budget_name, budget in (("tight", int(S * 0.35)), ("ample", 4 * S)):
        for policy_name, mode in POLICIES:
            sess = GraphSession(store, cache_mode=mode,
                                cache_budget_bytes=budget)
            sess.run("pagerank", max_iters=3)       # cold fill + promotion
            rep0 = sess.cache_report()
            warm = sess.run("pagerank", max_iters=WARM_ITERS)
            rep = sess.cache_report()
            eps = warm.edges_per_second()
            out.append(row(
                f"fig_cache_tiers_{budget_name}_{policy_name}",
                warm.total_seconds * 1e6,
                f"warm_edges_per_s={eps:.3e};"
                f"actual_mode={sess.cache.mode};"
                f"hot={rep['hot_shards']};cold={rep['cold_shards']};"
                f"hit={rep['hit_ratio']:.2f};"
                f"disk_MB={rep['disk_bytes'] / 1e6:.1f};"
                f"decomp_s={rep['decompress_seconds']:.3f};"
                f"decode_saved_s={rep['decode_seconds_saved'] - rep0['decode_seconds_saved']:.3f};"
                f"promote={rep['promotions']};demote={rep['demotions']};"
                f"evict={rep['evictions']}"))
    return out
