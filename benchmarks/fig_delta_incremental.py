"""Mutable-graph overlay: incremental recompute, cache retention, memo survival.

Three claims under measurement (ISSUE 6 acceptance), all on the shared
scale-16 RMAT store wrapped in a ``DeltaGraphStore`` (mutations live in the
overlay; the on-disk benchmark store is never modified):

  1. After a small monotone delta, ``run_incremental`` (frontier seeded from
     the commit's affected sources) beats a cold rerun on iterations AND disk
     bytes while staying bitwise-identical to it.  Swept over delta sizes;
     the cache is disabled for this leg so disk bytes are an honest per-run
     measure.
  2. Mutating edges confined to <= 10% of shards keeps >= 80% of the warm
     compressed cache: only the dirty shards' entries are epoch-invalidated
     (``stale_drops``), everything else is served from memory.
  3. A serving memo survives ``GraphService.apply_mutations``: converged
     results of incremental-capable apps are refreshed in place (one short
     barrier), only non-incremental entries drop.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.session import GraphSession

DELTA_SIZES = (16, 256, 4096)
MAX_ITERS = 64
WARM_ITERS = 3


def _fresh_edges(rng, n, count, lo=0, hi=None):
    """``count`` random (src, dst) pairs with destinations in [lo, hi)."""
    src = rng.integers(0, n, size=count, dtype=np.int64)
    dst = rng.integers(lo, hi if hi is not None else n, size=count,
                       dtype=np.int64)
    keep = src != dst
    return src[keep], dst[keep]


def run() -> list[str]:
    out = []
    store = get_store()
    n = int(store.properties["num_vertices"])
    rng = np.random.default_rng(23)

    # -- leg 1: incremental vs cold across delta sizes ----------------------
    for m in DELTA_SIZES:
        with GraphSession(store, mutable=True, cache_budget_bytes=0) as sess:
            prev = sess.run("sssp", source=0, max_iters=MAX_ITERS)
            sess.apply_mutations(inserts=_fresh_edges(rng, n, m))
            t0 = time.perf_counter()
            inc = sess.run_incremental("sssp", prev=prev, source=0,
                                       max_iters=MAX_ITERS)
            inc_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            cold = sess.run("sssp", source=0, max_iters=MAX_ITERS)
            cold_s = time.perf_counter() - t0
            assert np.array_equal(inc.values, cold.values), \
                f"incremental sssp diverged from cold rerun at delta={m}"
            inc_b = sum(h.disk_bytes for h in inc.history)
            cold_b = sum(h.disk_bytes for h in cold.history)
            out.append(row(
                f"fig_delta_incremental_sssp_d{m}",
                inc_s * 1e6,
                f"cold_us={cold_s * 1e6:.1f};"
                f"iters={inc.iterations}/{cold.iterations};"
                f"disk_MB={inc_b / 1e6:.2f}/{cold_b / 1e6:.2f};"
                f"byte_save={1 - inc_b / max(cold_b, 1):.2f};bitwise=1"))
            assert inc.iterations <= cold.iterations
            assert inc_b <= cold_b

    # -- leg 2: cache retention under a confined delta ----------------------
    S = store.total_shard_bytes()
    with GraphSession(store, mutable=True,
                      cache_budget_bytes=4 * S) as sess:
        sess.run("pagerank", max_iters=WARM_ITERS)  # cold fill
        sess.run("pagerank", max_iters=WARM_ITERS)  # settle promotions
        rep0 = sess.cache_report()
        iv = sess.store.intervals
        # 64 edits, every destination inside shard 0's interval: exactly one
        # of P shards goes dirty (<= 10% for the P >= 10 benchmark store)
        sess.apply_mutations(inserts=_fresh_edges(
            rng, n, 64, lo=int(iv[0]), hi=int(iv[1])))
        dirty = len(sess.store.dirty_shards())
        P = sess.store.num_shards
        warm = sess.run("pagerank", max_iters=WARM_ITERS)
        rep1 = sess.cache_report()
        stale = rep1["stale_drops"] - rep0["stale_drops"]
        refetched = rep1["misses"] - rep0["misses"]
        retention = 1.0 - stale / max(rep0["cached_shards"], 1)
        out.append(row(
            "fig_delta_cache_retention",
            warm.total_seconds * 1e6,
            f"dirty_shards={dirty}/{P};stale_drops={stale};"
            f"refetched={refetched};retention={retention:.2f};"
            f"disk_MB={(rep1['disk_bytes'] - rep0['disk_bytes']) / 1e6:.2f}"))
        assert dirty <= max(1, P // 10), f"delta not confined: {dirty}/{P}"
        assert retention >= 0.8, f"cache retention {retention:.2f} < 0.8"

    # -- leg 3: serving memo survives a mutation barrier --------------------
    with GraphSession(store, mutable=True) as sess, \
            sess.service(max_batch=4, max_wait_ms=1.0) as svc:
        for s in range(8):
            svc.submit("sssp", source=s).result()
        svc.submit("pagerank", max_iters=10).result()
        t0 = time.perf_counter()
        rep = svc.apply_mutations(inserts=_fresh_edges(rng, n, 64))
        barrier_s = time.perf_counter() - t0
        snap0 = svc.stats.snapshot()
        t0 = time.perf_counter()
        svc.submit("sssp", source=3).result()  # must hit the refreshed memo
        hit_s = time.perf_counter() - t0
        hits = svc.stats.snapshot()["memo_hits"] - snap0["memo_hits"]
        out.append(row(
            "fig_delta_memo_survival",
            barrier_s * 1e6,
            f"epoch={rep.epoch};refreshed={rep.memo_refreshed};"
            f"dropped={rep.memo_dropped};post_hit_us={hit_s * 1e6:.1f};"
            f"post_hits={hits}"))
        assert rep.memo_refreshed == 8 and rep.memo_dropped == 1
        assert hits == 1, "refreshed memo entry did not serve the query"
    return out
