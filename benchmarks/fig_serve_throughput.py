"""Concurrent serving throughput: dynamic micro-batching vs sequential.

The claim under measurement (ISSUE 5 acceptance): a closed-loop load of
concurrent personalized-PageRank point queries (distinct seeds — the
"recommendations for user u" workload) against one ``GraphService`` must
beat one-query-at-a-time serving by >= 2x at 16 clients on the scale-14
RMAT graph: compatible queries coalesce into K-column ``run_batch`` sweeps
that pay ONE pass of shard traffic + per-shard overhead for K answers.

PPR is the honest amortization workload here: every query sweeps all
shards each iteration, so a K-column sweep replaces K full sweeps.  (Point
SSSP is the anti-case on a page-cache-resident graph — solo runs exploit
Bloom selective scheduling that the union frontier gives up, so batching
buys little until real disk latency is in the loop; the bench CLI can
measure that trade with --app sssp.)

For clients in {1, 4, 16} x policy in {sequential, batched} we report
queries/sec, p50/p95 latency, mean batch occupancy, and disk bytes.  At 1
client batching cannot help (every batch has occupancy 1); the speedup must
appear as the client count grows.  Memoization is OFF: the speedup measured
is coalescing alone.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.serve.bench import SEQUENTIAL, ServiceConfig, prepare_store, run_load
from repro.session import GraphSession

SCALE = 14
CLIENTS = (1, 4, 16)
QUERIES_PER_CLIENT = 8
MAX_ITERS = 30
# 25ms straggler window ≈ 5% of one PPR sweep: cheap latency for full
# occupancy (at 4ms, 16 closed-loop clients only ever coalesce 8-wide)
BATCHED = ServiceConfig(max_batch=16, max_wait_ms=25.0, max_inflight=2,
                        memoize=False)


def run() -> list[str]:
    out = []
    store = prepare_store(scale=SCALE, edge_factor=8)
    speedup_at = {}
    for clients in CLIENTS:
        qps = {}
        for policy, cfg in (("seq", SEQUENTIAL), ("batched", BATCHED)):
            with GraphSession(store) as session:
                r = run_load(session, clients=clients,
                             queries_per_client=QUERIES_PER_CLIENT,
                             config=cfg, app="ppr", max_iters=MAX_ITERS)
            qps[policy] = r["qps"]
            out.append(row(
                f"fig_serve_throughput_{policy}_c{clients}",
                r["wall_seconds"] * 1e6,
                f"qps={r['qps']:.2f};p50_ms={r['p50_ms']:.1f};"
                f"p95_ms={r['p95_ms']:.1f};occ={r['mean_occupancy']:.2f};"
                f"disk_MB={r['disk_bytes']/1e6:.1f}"))
        speedup_at[clients] = qps["batched"] / max(qps["seq"], 1e-9)
        out.append(row(f"fig_serve_throughput_speedup_c{clients}", 0.0,
                       f"batched_over_seq={speedup_at[clients]:.2f}"))
    # the acceptance bar: >= 2x at 16 concurrent clients
    assert speedup_at[16] >= 2.0, (
        f"batched serving only {speedup_at[16]:.2f}x sequential at 16 "
        f"clients (acceptance requires >= 2x)")
    return out
