"""SpMV kernel microbench: Pallas (interpret on CPU) vs pure-jnp reference.

On this container the Pallas kernels run in interpret mode, so wall-clock
favours the jnp path — the structural numbers that matter for the TPU target
are bytes-per-edge of the ELL layout and padding overhead, reported in the
derived column.  (On real TPU the same pallas_call compiles to fused VMEM
tiles; see kernels/spmv/spmv.py.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_store, row
from repro.core.shards import quantize_edge_vals
from repro.kernels.spmv.ops import describe_dispatch, ell_spmv, ell_spmv_batch

# roofline variant grid (ISSUE satellite: fp32/fp16/int8 × K ∈ {1, 16})
VARIANT_DTYPES = ("float32", "float16", "int8")
VARIANT_KS = (1, 16)
_R, _W, _N = 2048, 256, 1 << 15  # synthetic ELL problem, ~0.5M edge slots


def _variant_problem(seed: int = 7):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, _N, (_R, _W)).astype(np.int32)
    cols[rng.random((_R, _W)) < 0.2] = -1  # ~20% padding, like a real shard
    vals = (rng.random((_R, _W), dtype=np.float32) * 2.0 - 0.5).astype(np.float32)
    row_map = np.arange(_R, dtype=np.int32)
    x = rng.random((_N, max(VARIANT_KS)), dtype=np.float32)
    return cols, vals, row_map, x


def spmv_variants(use_pallas="auto", reps: int = 3) -> list[dict]:
    """Time one SpMV per (edge dtype × K) variant; return records for the
    roofline report.

    ``model_bytes`` is the minimum HBM traffic of the path actually taken
    (``describe_dispatch``): edge arrays once (cols int32 + vals at their
    *stored* dtype — the quantization win), sources once, partials out.  The
    unfused paths additionally materialize the gathered [R, W, K] matrix
    (one write + one read).  Achieved bandwidth = model_bytes / seconds, an
    *upper bound* on usefully-moved bytes — honest for compiled backends,
    pessimistic in interpret mode (which is why the report prints the path).
    """
    cols_np, vals_np, row_map_np, x_np = _variant_problem()
    cols = jnp.asarray(cols_np)
    row_map = jnp.asarray(row_map_np)
    out = []
    for dtype in VARIANT_DTYPES:
        q, scale, zero = quantize_edge_vals(vals_np, dtype)
        vals = jnp.asarray(q)
        qp = jnp.asarray([scale, zero], jnp.float32)
        for k in VARIANT_KS:
            if k == 1:
                x = jnp.asarray(x_np[:, 0])
                f = lambda: ell_spmv(x, cols, vals, row_map, _R, "min_plus",
                                     use_pallas=use_pallas, qparams=qp)
            else:
                x = jnp.asarray(x_np[:, :k])
                f = lambda: ell_spmv_batch(x, cols, vals, row_map, _R,
                                           "min_plus", use_pallas=use_pallas,
                                           qparams=qp)
            path = describe_dispatch(use_pallas, n=_N, k=k)
            jax.block_until_ready(f())  # compile
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(f())
            dt = (time.perf_counter() - t0) / reps
            model_bytes = (cols_np.nbytes + q.nbytes        # edge pass
                           + _N * k * 4 + _R * k * 4)       # sources + out
            if "fused" not in path:
                model_bytes += 2 * _R * _W * k * 4          # gathered matrix
            out.append(dict(dtype=dtype, k=k, seconds=dt,
                            model_bytes=model_bytes, path=path))
    return out


def run() -> list[str]:
    out = []
    store = get_store()
    shard = store.read_shard(0)
    n = store.num_vertices
    x = jnp.asarray(np.random.default_rng(0).random(n).astype(np.float32))
    cols, vals = jnp.asarray(shard.cols), jnp.asarray(shard.vals)
    rmap = jnp.asarray(shard.row_map)
    R = shard.shape[0]
    for use, tag in ((False, "jnp_ref"), (True, "pallas_interpret")):
        f = lambda: ell_spmv(x, cols, vals, rmap, R, "plus_src", use_pallas=use)
        jax.block_until_ready(f())  # compile
        t0 = time.perf_counter()
        reps = 20 if not use else 3
        for _ in range(reps):
            jax.block_until_ready(f())
        dt = (time.perf_counter() - t0) / reps
        eps = shard.nnz / dt
        out.append(row(f"kernel_spmv_{tag}", dt * 1e6,
                       f"edges_per_s={eps/1e6:.0f}M"))
    fill = shard.nnz / (shard.shape[0] * shard.shape[1])
    out.append(row("kernel_spmv_ell_layout", 0.0,
                   f"R={shard.shape[0]};W={shard.shape[1]};fill={fill:.2f};"
                   f"bytes_per_edge={shard.padded_bytes()/max(shard.nnz,1):.1f}"))
    return out
