"""SpMV kernel microbench: Pallas (interpret on CPU) vs pure-jnp reference.

On this container the Pallas kernels run in interpret mode, so wall-clock
favours the jnp path — the structural numbers that matter for the TPU target
are bytes-per-edge of the ELL layout and padding overhead, reported in the
derived column.  (On real TPU the same pallas_call compiles to fused VMEM
tiles; see kernels/spmv/spmv.py.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_store, row
from repro.kernels.spmv.ops import ell_spmv


def run() -> list[str]:
    out = []
    store = get_store()
    shard = store.read_shard(0)
    n = store.num_vertices
    x = jnp.asarray(np.random.default_rng(0).random(n).astype(np.float32))
    cols, vals = jnp.asarray(shard.cols), jnp.asarray(shard.vals)
    rmap = jnp.asarray(shard.row_map)
    R = shard.shape[0]
    for use, tag in ((False, "jnp_ref"), (True, "pallas_interpret")):
        f = lambda: ell_spmv(x, cols, vals, rmap, R, "plus_src", use_pallas=use)
        jax.block_until_ready(f())  # compile
        t0 = time.perf_counter()
        reps = 20 if not use else 3
        for _ in range(reps):
            jax.block_until_ready(f())
        dt = (time.perf_counter() - t0) / reps
        eps = shard.nnz / dt
        out.append(row(f"kernel_spmv_{tag}", dt * 1e6,
                       f"edges_per_s={eps/1e6:.0f}M"))
    fill = shard.nnz / (shard.shape[0] * shard.shape[1])
    out.append(row("kernel_spmv_ell_layout", 0.0,
                   f"R={shard.shape[0]};W={shard.shape[1]};fill={fill:.2f};"
                   f"bytes_per_edge={shard.padded_bytes()/max(shard.nnz,1):.1f}"))
    return out
