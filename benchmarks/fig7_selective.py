"""Paper Fig. 7: effect of selective scheduling (GraphMP-SS vs GraphMP-NSS).

Runs PR/SSSP/CC with the Bloom-gated scheduler on and off; reports total
time, per-late-iteration speedup, and how many shard loads were skipped —
the paper's three reported effects."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_store, row
from repro.core import apps
from repro.core.engine import EngineConfig
from repro.session import GraphSession


def run() -> list[str]:
    out = []
    store = get_store()
    cfg = EngineConfig(cache_mode=1, cache_budget_bytes=1 << 28,
                       selective_threshold=1e-3)
    for name, prog, iters in (("pagerank", apps.pagerank(tol=1e-4), 120),
                              ("sssp", apps.sssp(0), 50),
                              ("cc", apps.cc(), 50)):
        # separate sessions: SS on/off must each run against a cold cache
        on = GraphSession(store, cfg)
        off = GraphSession(store, cfg.replace(selective_threshold=-1.0))
        r_on = on.run(prog, max_iters=iters)
        r_off = off.run(prog, max_iters=iters)
        assert np.allclose(r_on.values, r_off.values, atol=1e-6, equal_nan=True)
        skipped = sum(h.shards_skipped for h in r_on.history)
        total = sum(h.shards_processed + h.shards_skipped for h in r_on.history)
        late_on = [h.seconds for h in r_on.history if h.selective_enabled]
        late_off = r_off.history[-len(late_on):] if late_on else []
        sp = (np.mean([h.seconds for h in late_off]) / np.mean(late_on)
              if late_on else 1.0)
        out.append(row(
            f"fig7_selective_{name}", r_on.total_seconds * 1e6,
            f"nss_s={r_off.total_seconds:.2f};ss_s={r_on.total_seconds:.2f};"
            f"skipped={skipped}/{total};late_iter_speedup={sp:.2f}x"))
    return out
