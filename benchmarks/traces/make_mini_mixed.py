"""Regenerate benchmarks/traces/mini_mixed.jsonl (committed load trace).

The committed trace is the fixed traffic every policy comparison runs
against (fig_autotune.py, the CI autotune job, tests/test_trace.py), so it
is checked in rather than synthesized on the fly — a generator tweak must
show up as a trace diff, not silently move the goalposts.

Shape: ~6 s of Poisson arrivals at 25 qps base with a 3x burst through the
middle third (75 qps), 3:1 cheap-bfs:sssp mix over the scale-10 bench
graph (``prepare_store(scale=10)``, 1024 vertices).  Both apps are exact
min-propagation families, so replays resolve bitwise-identically however
the policy coalesces them — the determinism acceptance bar depends on
this; do NOT add ppr/pagerank events here.

Usage::

    PYTHONPATH=src python benchmarks/traces/make_mini_mixed.py
"""
from pathlib import Path

SCALE = 10
EDGE_FACTOR = 8
QPS = 25.0
DURATION_S = 6.0
SEED = 42


def main() -> None:
    from repro.obs import LoadTrace

    trace = LoadTrace.synthesize(
        duration_s=DURATION_S, qps=QPS, mix={"bfs": 3.0, "sssp": 1.0},
        num_vertices=1 << SCALE, seed=SEED, max_iters=32,
        burst=(DURATION_S / 3, 2 * DURATION_S / 3, 3.0))
    trace.meta["store"] = {"scale": SCALE, "edge_factor": EDGE_FACTOR}
    out = trace.save(Path(__file__).parent / "mini_mixed.jsonl")
    print(f"{out}: {len(trace)} events over {trace.duration:.2f}s "
          f"({trace.mean_qps():.1f} qps mean), mix {trace.apps()}")


if __name__ == "__main__":
    main()
