"""Regenerate the marked tables in EXPERIMENTS.md from artifacts/dryrun.

Usage: PYTHONPATH=src:. python -m benchmarks.report_experiments
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ART = Path("artifacts/dryrun")
EXP = Path("EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "gemma-2b", "starcoder2-7b", "minitron-4b", "stablelm-1.6b",
    "jamba-v0.1-52b", "seamless-m4t-large-v2", "mixtral-8x22b",
    "kimi-k2-1t-a32b", "qwen2-vl-72b", "xlstm-1.3b",
]

MOVE_HINT = {
    "compute_s": "raise arithmetic intensity (fuse elementwise chains, bf16 "
                 "accumulation where safe)",
    "memory_s": "cut HBM round-trips: narrower scan dtypes, fewer "
                "materialized dispatch buffers, remat policy keeping dots",
    "collective_s": "restructure the collective pattern (replicated-token EP, "
                    "serve-time weight layout without FSDP gathers, int8 "
                    "gradient exchange)",
}


def load(tag_filter=None):
    recs = {}
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        mesh = r["mesh"]
        tag = ""
        if "__" in mesh:
            mesh, tag = mesh.split("__", 1)
        if (tag_filter or "") != tag:
            continue
        recs[(r["arch"], r["shape"], mesh)] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.1f}GiB"


def dryrun_table() -> str:
    recs = load()
    lines = [
        "Every applicable (arch × shape) cell lowers **and compiles** on both "
        "production meshes; `[skip]` rows are the documented long_500k "
        "inapplicabilities (DESIGN.md §5). Memory columns are per-device from "
        "`compiled.memory_analysis()` of the real (scanned) program.",
        "",
        "| arch | shape | 16x16 | temp/dev | args/dev | 2x16x16 | temp/dev | params |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r1 = recs.get((arch, shape, "pod16x16"))
            r2 = recs.get((arch, shape, "pod2x16x16"))
            if r1 is None and r2 is None:
                continue
            base = r1 or r2
            if not base.get("applicable"):
                lines.append(f"| {arch} | {shape} | [skip] | - | - | [skip] | - | - |")
                continue

            def cell(r):
                if r is None:
                    return "-", "-", "-"
                if not r.get("ok"):
                    return "FAIL", "-", "-"
                m = r.get("full_program", {}).get("memory", {})
                return (f"ok {r.get('compile_seconds', 0):.0f}s",
                        fmt_bytes(m.get("temp_size_in_bytes")),
                        fmt_bytes(m.get("argument_size_in_bytes")))

            c1, t1, a1 = cell(r1)
            c2, t2, _ = cell(r2)
            n = (base.get("param_counts") or {}).get("total")
            pstr = f"{n/1e9:.2f}B" if n else "-"
            lines.append(f"| {arch} | {shape} | {c1} | {t1} | {a1} | {c2} | {t2} | {pstr} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = load()
    lines = [
        "Single-pod (16×16, 256 chips) roofline terms per cell "
        "(delta-extrapolated; see §Methodology). `useful` = "
        "MODEL_FLOPS / HLO_FLOPS_global.",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | "
        "useful | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "pod16x16"))
            if r is None:
                continue
            if not r.get("applicable"):
                lines.append(f"| {arch} | {shape} | - | - | - | [skip] | - | - |")
                continue
            if not r.get("ok") or "roofline" not in r:
                lines.append(f"| {arch} | {shape} | FAIL | | | | | |")
                continue
            rf = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {rf['compute_s']:.3f} | "
                f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
                f"{rf['bottleneck'].replace('_s','')} | "
                f"{rf['useful_flops_ratio']:.3f} | "
                f"{MOVE_HINT[rf['bottleneck']]} |")
    # collective breakdown for the most collective-bound cells
    lines.append("")
    lines.append("Collective-bytes breakdown (per device, per step) for the "
                 "most collective-bound cells:")
    lines.append("")
    rows = []
    for (arch, shape, mesh), r in recs.items():
        if mesh != "pod16x16" or not r.get("ok") or "roofline" not in r:
            continue
        if r["roofline"]["bottleneck"] == "collective_s":
            rows.append((r["roofline"]["collective_s"], arch, shape,
                         r["roofline_inputs"]["collective_bytes_per_device"]))
    for _, arch, shape, colls in sorted(rows, reverse=True)[:6]:
        det = "; ".join(f"{k}={v/2**30:.2f}GiB" for k, v in sorted(
            colls.items(), key=lambda kv: -kv[1]))
        lines.append(f"* **{arch} × {shape}**: {det}")
    return "\n".join(lines)


def perf_table() -> str:
    base = load()
    lines = []
    cells = [("kimi-k2-1t-a32b", "train_4k"),
             ("kimi-k2-1t-a32b", "decode_32k"),
             ("jamba-v0.1-52b", "train_4k")]
    variants = ["perf_it1", "perf_it2", "perf_it3"]
    header = ("| cell | variant | compute_s | memory_s | collective_s | "
              "useful | Δ dominant |")
    lines += [header, "|---|---|---|---|---|---|---|"]
    for arch, shape in cells:
        b = base.get((arch, shape, "pod16x16"))
        if not b or not b.get("ok"):
            continue
        rb = b["roofline"]
        dom = rb["bottleneck"]
        lines.append(
            f"| {arch} × {shape} | baseline (paper-faithful) | "
            f"{rb['compute_s']:.3f} | "
            f"{rb['memory_s']:.3f} | {rb['collective_s']:.3f} | "
            f"{rb['useful_flops_ratio']:.3f} | dom={dom.replace('_s','')} |")
        for tag in variants:
            v = load(tag).get((arch, shape, "pod16x16"))
            if not v or not v.get("ok") or "roofline" not in v:
                continue
            rv = v["roofline"]
            delta = rv[dom] / max(rb[dom], 1e-12)
            lines.append(
                f"| | {tag} {json.dumps(v.get('variant', {}))} | "
                f"{rv['compute_s']:.3f} | {rv['memory_s']:.3f} | "
                f"{rv['collective_s']:.3f} | {rv['useful_flops_ratio']:.3f} | "
                f"×{delta:.3f} |")
    return "\n".join(lines)


def replace_block(text: str, marker: str, content: str) -> str:
    pat = re.compile(rf"(<!-- {marker}:BEGIN -->).*?(<!-- {marker}:END -->)",
                     re.DOTALL)
    return pat.sub(lambda m: m.group(1) + "\n" + content + "\n" + m.group(2),
                   text)


def main():
    text = EXP.read_text()
    text = replace_block(text, "DRYRUN", dryrun_table())
    text = replace_block(text, "ROOFLINE", roofline_table())
    text = replace_block(text, "PERF", perf_table())
    EXP.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
