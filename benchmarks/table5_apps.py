"""Paper Tables 5-7: PR / SSSP / CC end-to-end vs the out-of-core baselines
(PSW=GraphChi-like, ESG=X-Stream-like), first-10-iterations wall time and
edges/s — the paper's headline comparison, at container scale."""
from __future__ import annotations

import shutil

import numpy as np

from benchmarks.common import BENCH_DIR, get_graph, get_store, row
from repro.baselines.esg import ESGEngine
from repro.baselines.psw import PSWEngine
from repro.core import apps
from repro.session import GraphSession


def run() -> list[str]:
    out = []
    src, dst, n = get_graph()
    store = get_store()
    iters = 10
    progs = {"pagerank": apps.pagerank(), "sssp": apps.sssp(0), "cc": apps.cc()}
    psw = PSWEngine(str(BENCH_DIR / "psw_t5"), src, dst, n)
    esg = ESGEngine(str(BENCH_DIR / "esg_t5"), src, dst, n)
    # no-cache variant: one session is fine (mode 0 holds nothing)
    sess_nc = GraphSession(store, cache_mode=0)
    for name, prog in progs.items():
        r_nc = sess_nc.run(prog, max_iters=iters)
        # cached variant: fresh session per app keeps the paper's
        # cold-cache-per-application measurement methodology
        sess_c = GraphSession(store, cache_mode="auto",
                              cache_budget_bytes=1 << 30)
        r_c = sess_c.run(prog, max_iters=iters)
        _, _, t_psw = psw.run(prog, max_iters=iters)
        _, _, t_esg = esg.run(prog, max_iters=iters)
        eps = r_c.edges_per_second()
        out.append(row(
            f"table5_{name}", r_c.total_seconds * 1e6,
            f"graphmp_c_s={r_c.total_seconds:.2f};"
            f"graphmp_nc_s={r_nc.total_seconds:.2f};"
            f"psw_s={t_psw:.2f};esg_s={t_esg:.2f};"
            f"speedup_vs_psw={t_psw/max(r_c.total_seconds,1e-9):.1f}x;"
            f"edges_per_s={eps/1e6:.0f}M"))
    # correctness cross-check between engines (same fixpoint)
    v1, _, _ = psw.run(apps.cc(), max_iters=60)
    r = GraphSession(store, cache_mode=1).run("cc", max_iters=60)
    ok = bool(np.array_equal(v1, r.values))
    out.append(row("table5_engines_agree", 0.0, f"cc_fixpoint_equal={ok}"))
    shutil.rmtree(BENCH_DIR / "psw_t5", ignore_errors=True)
    shutil.rmtree(BENCH_DIR / "esg_t5", ignore_errors=True)
    return out
