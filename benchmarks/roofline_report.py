"""Roofline summary from the dry-run artifacts (one row per cell) — the
benchmark-side view of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

ART = Path("artifacts/dryrun")


def run() -> list[str]:
    out = []
    if not ART.exists():
        return [row("roofline_report", 0.0, "no artifacts (run launch/dryrun)")]
    for p in sorted(ART.glob("*__pod16x16.json")):
        rec = json.loads(p.read_text())
        if not rec.get("applicable"):
            out.append(row(f"roofline_{rec['arch']}_{rec['shape']}", 0.0, "skipped"))
            continue
        if not rec.get("ok") or "roofline" not in rec:
            out.append(row(f"roofline_{rec['arch']}_{rec['shape']}", 0.0,
                           "FAILED" if not rec.get("ok") else "no-delta"))
            continue
        r = rec["roofline"]
        out.append(row(
            f"roofline_{rec['arch']}_{rec['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"compute_s={r['compute_s']:.3f};memory_s={r['memory_s']:.3f};"
            f"collective_s={r['collective_s']:.3f};bottleneck={r['bottleneck']};"
            f"useful={r['useful_flops_ratio']:.3f}"))
    return out
