"""Achieved-vs-peak bandwidth per SpMV kernel variant (EXPERIMENTS.md §Roofline).

The SpMV hot loop is memory-bound by design — the paper's thesis is that
once vertices are resident, *edge bandwidth* is the only cost left.  So the
honest kernel scorecard is bandwidth, not FLOPs:

  * ``peak``     — measured on this machine with a simple out-of-cache
    float32 triad (read + write), not a spec-sheet number.
  * ``achieved`` — per (edge dtype × K) variant from
    ``kernel_spmv.spmv_variants``: the path's minimum HBM traffic model
    divided by measured wall-clock.  Quantized variants move fewer edge
    bytes for the same edge count, which is exactly the dequant-in-kernel
    claim this report gates.

Each variant emits one row: ``achieved_GBps;peak_GBps;frac;path``.  ``path``
is the dispatch actually taken (``repro.kernels.spmv.ops.describe_dispatch``)
— on this CPU container interpret-mode rows are *expected* to sit far below
peak; the report exists so compiled backends have a go/no-go number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row

_PROBE_ELEMS = 1 << 24  # 64 MiB float32: far beyond LLC, measures DRAM


def measure_peak_bandwidth(reps: int = 5) -> float:
    """Bytes/second of a float32 triad y = 2x (one read + one write)."""
    x = jnp.arange(_PROBE_ELEMS, dtype=jnp.float32)
    f = jax.jit(lambda a: a * 2.0)
    jax.block_until_ready(f(x))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(x))
    dt = (time.perf_counter() - t0) / reps
    return 2 * x.nbytes / dt


def run() -> list[str]:
    from benchmarks import kernel_spmv

    peak = measure_peak_bandwidth()
    out = [row("roofline_peak_bw", 0.0,
               f"peak_GBps={peak / 1e9:.2f};probe=triad_f32_64MiB")]
    for v in kernel_spmv.spmv_variants():
        achieved = v["model_bytes"] / v["seconds"]
        out.append(row(
            f"roofline_spmv_{v['dtype']}_K{v['k']}",
            v["seconds"] * 1e6,
            f"achieved_GBps={achieved / 1e9:.2f};peak_GBps={peak / 1e9:.2f};"
            f"frac={achieved / peak:.3f};path={v['path']}"))
    return out
