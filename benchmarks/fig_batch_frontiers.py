"""Batched multi-source traversal: cost of K frontiers vs K single runs.

The claim under measurement (ISSUE 2 tentpole): one VSW sweep serves K
frontiers, so K landmark SSSP queries should cost far closer to ONE sweep of
disk + decompression than K.  For K ∈ {1, 4, 16, 64} we run ``run_batch``
on a COLD session (cache budget ~35% of the graph so shards keep streaming)
and report wall time, effective edges/sec (edge-column work done per second:
processed edges × K), disk bytes, and the same for K sequential single-source
runs as the baseline.
"""
from __future__ import annotations

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.session import GraphSession

KS = (1, 4, 16, 64)
MAX_ITERS = 30


def run() -> list[str]:
    out = []
    store = get_store()
    budget = int(store.total_shard_bytes() * 0.35)
    # deterministic, distinct landmark sources spread over the id space
    n = store.num_vertices
    for K in KS:
        sources = [(i * 977) % n for i in range(K)]
        batch_sess = GraphSession(store, cache_mode=1, cache_budget_bytes=budget)
        results = batch_sess.run_batch("sssp", sources=sources,
                                       max_iters=MAX_ITERS)
        bres = batch_sess.last_batch_result
        secs = bres.total_seconds
        # edge-column throughput, weighted by columns still live in each
        # iteration (column k is live for its first column_iterations[k]
        # sweeps) — crediting the full K to every sweep would overstate the
        # batch once most landmarks have converged
        edge_cols = sum(
            h.edges_processed * int((bres.column_iterations > i).sum())
            for i, h in enumerate(bres.history))
        ecps = edge_cols / max(secs, 1e-9)
        out.append(row(
            f"fig_batch_frontiers_K{K}", secs * 1e6,
            f"edge_cols_per_s={ecps:.3g};"
            f"disk_MB={batch_sess.stats.disk_bytes/1e6:.1f};"
            f"iters={bres.iterations};"
            f"col_iters_max={int(bres.column_iterations.max())}"))
        # baseline: the same K queries, one engine run each, same cold cache
        seq_sess = GraphSession(store, cache_mode=1, cache_budget_bytes=budget)
        seq_secs = 0.0
        seq_edges = 0
        for s in sources:
            r = seq_sess.run("sssp", source=s, max_iters=MAX_ITERS)
            seq_secs += r.total_seconds
            seq_edges += r.total_edges_processed
        out.append(row(
            f"fig_batch_frontiers_seq_K{K}", seq_secs * 1e6,
            f"edge_cols_per_s={seq_edges / max(seq_secs, 1e-9):.3g};"
            f"disk_MB={seq_sess.stats.disk_bytes/1e6:.1f}"))
        assert all(r.values.shape == (n,) for r in results)
    return out
