"""Sharded VSW: edges/sec and per-lane stall vs device count.

The claim under measurement (ISSUE 7 tentpole): routing one VSW iteration
through ``ShardedVSWEngine`` folds N shards per wave across N devices while
keeping results bitwise-identical and disk accounting canonical — so
edges/sec should hold or rise with the device count and the summed per-lane
stall should not blow up, while disk bytes stay EXACTLY constant across
device counts (same schedule, same shards, split across cache partitions).

jax fixes the process's device count at first init, so each count runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  On
one physical CPU the N "devices" share cores — this measures the sharded
path's overhead and accounting, not real scaling.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import BENCH_DIR, get_store, row

DEVICE_COUNTS = (1, 2, 4, 8)
MAX_ITERS = 8

_CHILD = """
import json, sys
import numpy as np
from repro.session import GraphSession

path, devices, max_iters = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
with GraphSession(path, num_devices=devices, prefetch_depth=2) as sess:
    sess.run("pagerank", max_iters=1)  # warm the jit caches (not measured)
    disk0 = sess.stats.disk_bytes
    res = sess.run("pagerank", max_iters=max_iters)
    print(json.dumps({
        "eps": res.edges_per_second(),
        "disk": sess.stats.disk_bytes - disk0,
        "stall": sum(h.stall_seconds for h in res.history),
        "fetch": sum(h.fetch_seconds for h in res.history),
        "secs": res.total_seconds,
        "checksum": float(np.asarray(res.values).sum()),
    }))
"""


def _measure(path: str, devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""), *sys.path) if p)
    env["BENCH_DIR"] = str(BENCH_DIR.parent)  # reuse the shared store
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, path, str(devices), str(MAX_ITERS)],
        capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"devices={devices} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run() -> list[str]:
    out = []
    path = str(get_store().path)
    disk_seen, checksums = set(), set()
    for d in DEVICE_COUNTS:
        m = _measure(path, d)
        disk_seen.add(m["disk"])
        checksums.add(m["checksum"])
        out.append(row(
            f"fig_multidevice_pagerank_dev{d}", m["secs"] * 1e6,
            f"edges_per_s={m['eps']:.3g};stall_s={m['stall']:.3f};"
            f"fetch_s={m['fetch']:.3f};disk_MB={m['disk']/1e6:.1f}"))
    # same schedule + shards at every device count: canonical disk bytes and
    # the result itself must not drift
    out.append(row(
        "fig_multidevice_disk_invariant", 0.0,
        f"identical={'yes' if len(disk_seen) == 1 else 'NO'}"))
    out.append(row(
        "fig_multidevice_result_invariant", 0.0,
        f"identical={'yes' if len(checksums) == 1 else 'NO'}"))
    return out
