"""App-zoo sweep: every registered application end-to-end on the bench
graph — wall time, edges/s, and Table-3 disk-byte accounting per app.

The app list comes from ``repro.core.apps.list_apps()`` (the same registry
GraphService serves from), so registering a new application automatically
adds a row here; only its invocation arguments need an entry below.
"""
from __future__ import annotations

from benchmarks.common import get_store, row
from repro.core.apps import list_apps
from repro.session import GraphSession

ITERS = 10
# per-app invocation arguments (mirrors tests/_zoo_runner.py at bench scale)
SOLO_ARGS = {
    "pagerank": {"max_iters": ITERS},
    "sssp": {"source": 5},
    "bfs": {"source": 7},
    "cc": {},
    "label_propagation": {},
    "kcore": {"k": 4},
    # full-graph triangle count is quadratic in n at bench scale; a 256-vertex
    # slab still streams every shard per chunk, which is what we measure
    "triangles": {"chunk": 64, "lo": 0, "hi": 256},
}
BATCH_ARGS = {
    "sssp_multi": {"sources": (1, 5, 9, 13)},
    "bfs_multi": {"sources": (2, 6, 10, 14)},
    "personalized_pagerank": {"seeds": (3, 11), "max_iters": ITERS},
    "lp_multi": {"sources": (0, 5, 9)},
    "kcore_multi": {"ks": (2, 4)},
    "triangles_multi": {"vertices": (1, 2, 3, 4)},
    "random_walks": {"sources": (1, 5, 9, 13), "length": 16, "seed": 3},
}


def run() -> list[str]:
    out = []
    store = get_store()
    for info in list_apps():
        if info.kind == "alias":
            continue
        # cold cache per app: the paper's per-application measurement
        with GraphSession(store, cache_mode="auto",
                          cache_budget_bytes=1 << 30) as sess:
            if info.name in BATCH_ARGS:  # batched programs AND drivers
                kw = dict(BATCH_ARGS[info.name])
                kw.setdefault("max_iters", 400)
                if info.name == "triangles_multi":
                    kw["max_iters"] = 4
                sess.run_batch(info.name, **kw)
                res = sess.last_batch_result
                width = res.num_columns
            else:
                kw = dict(SOLO_ARGS[info.name])
                res = sess.run(info.name, max_iters=kw.pop("max_iters", 400),
                               **kw)
                width = 1
        disk = sum(h.disk_bytes for h in res.history)
        out.append(row(
            f"fig_app_zoo_{info.name}", res.total_seconds * 1e6,
            f"kind={info.kind};k={width};iters={res.iterations};"
            f"edges_per_s={res.edges_per_second() / 1e6:.1f}M;"
            f"disk_mb={disk / 1e6:.1f}"))
    return out
