"""Paper Figs. 9-10: GraphMP vs an in-memory engine (GraphMat stand-in).

The in-memory competitor is our own engine with preload=True (all shards
resident, no disk) — the fair analogue of GraphMat's position: same compute
kernels, zero disk I/O, full-memory footprint.  Reports load time vs
preprocessing reuse, per-iteration time, and memory-ish footprint (cached
bytes), mirroring the paper's two comparison cases."""
from __future__ import annotations

import time

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.session import GraphSession


def run() -> list[str]:
    out = []
    store = get_store()
    t0 = time.perf_counter()
    inmem = GraphSession(store, cache_mode=1, cache_budget_bytes=1 << 34)
    inmem.warm()  # all shards resident before the clock starts
    t_load = time.perf_counter() - t0
    r_mem = inmem.run("pagerank", max_iters=10)
    ooc = GraphSession(store, cache_mode=0)
    r_ooc = ooc.run("pagerank", max_iters=10)
    out.append(row(
        "fig10_inmemory_vs_ooc", r_mem.total_seconds * 1e6,
        f"load_s={t_load:.2f};inmem_10it_s={r_mem.total_seconds:.2f};"
        f"outofcore_10it_s={r_ooc.total_seconds:.2f};"
        f"resident_MB={inmem.cache.cached_bytes/1e6:.0f}"))
    return out
