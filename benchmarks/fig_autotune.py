"""Static vs adaptive batching policy on the committed load trace.

The claim under measurement (ISSUE 8 acceptance): replaying
``benchmarks/traces/mini_mixed.jsonl`` — ~6 s of open-loop Poisson bfs+sssp
traffic with a 3x burst through the middle third — the SLO-aware
``AdaptiveServeController`` must meet or beat the BEST static
``ServiceConfig`` on p99 latency at equal-or-better throughput, without
being told where the trade-off lives.

The static ladder spans the straggler-window trade-off the controller has
to discover at runtime:

* ``tight``  (0.5 ms) — latency-greedy: near-empty batches, so the burst
  saturates the runners and queueing delay blows the tail up;
* ``mid``    (8 ms)   — a hand-picked compromise (the "best static" in
  practice — exactly what an operator would have to find by sweeping);
* ``wide``   (40 ms)  — occupancy-greedy: every off-burst request eats the
  window as pure added latency.

The adaptive run STARTS at the wide config: converging down to (or past)
``mid``'s tail latency is the controller earning its keep.  Latencies are
exact nearest-rank percentiles measured from intended arrival times (the
replay harness's own list, not the serving reservoirs), and every run
reports its ``result_digest`` — identical digests across policies double-
check that policy only moves WHEN work happens, never what it computes.

Every policy is replayed ``REPS`` times and compared on MEDIAN p99/qps —
a ~220-request open-loop trace puts p99 three samples from the max, so a
single draw on a shared machine is a coin flip (observed spread on one
box: the same mid config drew 210 ms and 1326 ms back to back).

Acceptance (asserted): adaptive median p99 <= 1.10x best-static median
p99 AND adaptive median qps >= 0.95x best-static median qps AND every
adaptive rep converged with no controller errors.  (The 10%/5% slack
absorbs residual noise; the committed PR-description run shows the real
margins.)
"""
from __future__ import annotations

import statistics
from pathlib import Path

from benchmarks.common import row
from repro.obs import LoadTrace
from repro.serve.bench import ServiceConfig, prepare_store, replay_trace
from repro.session import GraphSession

TRACE = Path(__file__).parent / "traces" / "mini_mixed.jsonl"
SLO_P99_MS = 60.0
REPS = 3
STATICS = (
    ("tight", ServiceConfig(max_batch=16, max_wait_ms=0.5, max_inflight=2,
                            memoize=False)),
    ("mid", ServiceConfig(max_batch=16, max_wait_ms=8.0, max_inflight=2,
                          memoize=False)),
    ("wide", ServiceConfig(max_batch=16, max_wait_ms=40.0, max_inflight=2,
                           memoize=False)),
)


def _fmt(r: dict) -> str:
    return (f"qps={r['qps']:.2f};p50_ms={r['p50_ms']:.1f};"
            f"p99_ms={r['p99_ms']:.1f};occ={r['mean_occupancy']:.2f};"
            f"max_batch={r['max_batch']};max_wait_ms={r['max_wait_ms']:.2f}")


def _replay(store, trace, cfg, adaptive: bool) -> dict:
    # fresh session per rep: no policy run inherits another's warm cache
    with GraphSession(store) as session:
        return replay_trace(
            session, trace, cfg, adaptive=adaptive, slo_p99_ms=SLO_P99_MS,
            controller_interval_s=0.25)


def run() -> list[str]:
    out = []
    trace = LoadTrace.load(TRACE)
    store_meta = trace.meta.get("store", {})
    store = prepare_store(scale=store_meta.get("scale", 10),
                          edge_factor=store_meta.get("edge_factor", 8))
    reps: dict[str, list[dict]] = {}
    digests = set()
    # adaptive starts from the WIDE (worst-tail) static and must find its
    # own way down; same trace for every rep of every policy
    policies = [(f"static_{name}", cfg, False) for name, cfg in STATICS]
    policies.append(("adaptive", STATICS[-1][1], True))
    for name, cfg, adaptive in policies:
        for i in range(REPS):
            r = _replay(store, trace, cfg, adaptive)
            reps.setdefault(name, []).append(r)
            digests.add(r["result_digest"])
            derived = _fmt(r)
            if adaptive:
                derived += (f";adjustments={r['adjustments']}"
                            f";converged={r['converged']}")
            out.append(row(f"fig_autotune_{name}_rep{i}",
                           r["wall_seconds"] * 1e6, derived))

    med = {name: {k: statistics.median(r[k] for r in rs)
                  for k in ("p50_ms", "p99_ms", "qps", "mean_occupancy")}
           for name, rs in reps.items()}
    for name in med:
        m = med[name]
        out.append(row(f"fig_autotune_{name}_median", 0.0,
                       f"p50_ms={m['p50_ms']:.1f};p99_ms={m['p99_ms']:.1f};"
                       f"qps={m['qps']:.2f};occ={m['mean_occupancy']:.2f}"))
    best_name = min((n for n in med if n != "adaptive"),
                    key=lambda n: med[n]["p99_ms"])
    best, adaptive_med = med[best_name], med["adaptive"]
    out.append(row("fig_autotune_best_static", 0.0,
                   f"name={best_name};p99_ms={best['p99_ms']:.1f};"
                   f"qps={best['qps']:.2f}"))

    # every replay of every policy must compute the SAME answers
    assert len(digests) == 1, (
        f"policies produced different results: {digests} — batching policy "
        "may never change WHAT gets computed")
    for name, rs in reps.items():
        for r in rs:
            assert r["failed"] == 0 and r["rejected"] == 0, (
                f"{name}: {r['failed']} failed / {r['rejected']} rejected")
    for r in reps["adaptive"]:
        assert r["converged"] and not r["controller_error"], (
            f"controller did not converge cleanly: {r}")
    # the acceptance bar: adaptive meets-or-beats the best static on median
    # p99 at equal-or-better median qps
    assert adaptive_med["p99_ms"] <= best["p99_ms"] * 1.10, (
        f"adaptive median p99 {adaptive_med['p99_ms']:.1f}ms vs best static "
        f"({best_name}) {best['p99_ms']:.1f}ms — must meet or beat")
    assert adaptive_med["qps"] >= best["qps"] * 0.95, (
        f"adaptive median qps {adaptive_med['qps']:.2f} vs best static "
        f"({best_name}) {best['qps']:.2f} — must not trade throughput away")
    return out
