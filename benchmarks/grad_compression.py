"""Distributed-optimization trick: int8 error-feedback gradient compression.

Measures the thing jit-level code can't show directly — the collective bytes
of the DP gradient psum — by lowering an explicit shard_map reduction in f32
vs int8 on a forced-8-device subprocess and parsing the HLO (the same parser
the roofline uses).  The convergence effect of the compression math is
covered by tests/test_train.py."""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import row

REPO = Path(__file__).resolve().parent.parent


def run() -> list[str]:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, %r)
        from jax.sharding import PartitionSpec as P
        from repro.launch.dryrun import collective_bytes
        from repro.train.train_step import quantize_int8, dequantize_int8

        mesh = jax.make_mesh((8,), ('d',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        G = (1024, 1024)

        N = 8

        def f32_reduce(g):
            return jax.lax.psum(g, 'd')

        def int8_two_phase(g):
            # quantized ring-equivalent all-reduce: int8 all_to_all chunks ->
            # local widen+sum -> requantize -> int8 all_gather.  All wire
            # payloads are int8 (4x narrower than f32).
            q, s = quantize_int8(g)
            qc = q.reshape(N, -1)
            qx = jax.lax.all_to_all(qc, 'd', split_axis=0, concat_axis=0,
                                    tiled=True)            # int8 on the wire
            sx = jax.lax.all_gather(s, 'd')                 # 8 scalars
            part = (qx.reshape(N, -1).astype(jnp.float32) *
                    sx[:, None]).sum(0)                      # local reduce
            q2, s2 = quantize_int8(part)
            qa = jax.lax.all_gather(q2, 'd', tiled=True)    # int8 on the wire
            sa = jax.lax.all_gather(s2, 'd')
            me = jax.lax.axis_index('d')
            return qa.astype(jnp.float32) * sa[me]

        import numpy as np
        x = jnp.zeros(G, jnp.float32)
        # modeled wire bytes per device: all-reduce 2B(N-1)/N; gather/a2a B(N-1)/N
        def wire(colls):
            w = 0.0
            for kind, b in colls.items():
                w += b * (N - 1) / N * (2.0 if kind == 'all-reduce' else 1.0)
            return int(w)
        for name, fn, spec in (('f32_psum', f32_reduce, P()),
                               ('int8_two_phase', int8_two_phase, P())):
            sm = jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=P(),
                               check_vma=False)
            txt = jax.jit(sm).lower(x.reshape(-1)).compile().as_text()
            c = collective_bytes(txt)
            print(name, wire(c))
    """) % str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    out = []
    if r.returncode != 0:
        return [row("grad_compression_bytes", 0.0, "FAILED:" + r.stderr[-200:])]
    res = dict(line.split() for line in r.stdout.strip().splitlines())
    f32b, i8b = int(res["f32_psum"]), int(res["int8_two_phase"])
    out.append(row("grad_compression_bytes", 0.0,
                   f"f32_psum_wire={f32b};int8_two_phase_wire={i8b};"
                   f"wire_reduction={f32b/max(i8b,1):.1f}x"))
    return out
