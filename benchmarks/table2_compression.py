"""Paper Table 2: compression ratio + (de)compression throughput per core.

The paper reports snappy / zlib-1 / zlib-3 on Twitter..EU-2015 shards; we
measure whatever codec the cache actually uses (zstd, or the paper's own
zlib where zstandard is absent — core/cache.py), on shard bytes from the
benchmark RMAT store.  The derived column reports ratio and MB/s — the
numbers that justify cache modes 2-4 (decompress >> disk bandwidth)."""
from __future__ import annotations

import time

from benchmarks.common import get_store, row
from repro.core.cache import _make_codec, zstandard


def run() -> list[str]:
    codec_name = "zstd" if zstandard is not None else "zlib"
    store = get_store()
    blob = b"".join(store.read_shard_bytes(p)
                    for p in range(min(store.num_shards, 8)))
    out = []
    for cache_mode in (2, 3, 4):
        mode = f"mode{cache_mode}/{codec_name}"
        compress, decompress = _make_codec(cache_mode)
        t0 = time.perf_counter()
        comp = compress(blob)
        t_c = time.perf_counter() - t0
        t0 = time.perf_counter()
        raw = decompress(comp)
        t_d = time.perf_counter() - t0
        assert raw == blob
        ratio = len(blob) / len(comp)
        out.append(row(f"table2_compress_{mode}", t_c * 1e6,
                       f"ratio={ratio:.2f};comp_MBps={len(blob)/t_c/1e6:.0f}"))
        out.append(row(f"table2_decompress_{mode}", t_d * 1e6,
                       f"decomp_MBps={len(blob)/t_d/1e6:.0f}"))
    return out
