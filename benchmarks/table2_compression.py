"""Paper Table 2: compression ratio + (de)compression throughput per core.

The paper reports snappy / zlib-1 / zlib-3 on Twitter..EU-2015 shards; this
container has zstd (mode mapping in core/cache.py), and the shard bytes come
from the benchmark RMAT store.  The derived column reports ratio and MB/s —
the numbers that justify cache modes 2-4 (decompress >> disk bandwidth)."""
from __future__ import annotations

import time

try:
    import zstandard
except ImportError:  # mirror core/cache.py: degrade, don't crash the sweep
    zstandard = None

from benchmarks.common import get_store, row


def run() -> list[str]:
    if zstandard is None:
        return [row("table2_compression_skipped", 0.0,
                    "zstandard not installed")]
    store = get_store()
    blob = b"".join(store.read_shard_bytes(p)
                    for p in range(min(store.num_shards, 8)))
    out = []
    for mode, level in (("mode2/zstd-1", 1), ("mode3/zstd-3", 3), ("mode4/zstd-9", 9)):
        c = zstandard.ZstdCompressor(level=level)
        t0 = time.perf_counter()
        comp = c.compress(blob)
        t_c = time.perf_counter() - t0
        d = zstandard.ZstdDecompressor()
        t0 = time.perf_counter()
        raw = d.decompress(comp)
        t_d = time.perf_counter() - t0
        assert raw == blob
        ratio = len(blob) / len(comp)
        out.append(row(f"table2_compress_{mode}", t_c * 1e6,
                       f"ratio={ratio:.2f};comp_MBps={len(blob)/t_c/1e6:.0f}"))
        out.append(row(f"table2_decompress_{mode}", t_d * 1e6,
                       f"decomp_MBps={len(blob)/t_d/1e6:.0f}"))
    return out
