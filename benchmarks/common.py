"""Shared benchmark fixtures: a synthetic power-law graph preprocessed once,
sized so the suite finishes on this CPU container but still exercises real
disk I/O through every code path."""
from __future__ import annotations

import os
import tempfile
from pathlib import Path

from repro.graph.generate import rmat_edges, materialize
from repro.graph.preprocess import preprocess_graph
from repro.graph.storage import GraphStore, write_edge_list

BENCH_DIR = Path(os.environ.get("BENCH_DIR", tempfile.gettempdir())) / "repro_bench"
SCALE = int(os.environ.get("BENCH_SCALE", "16"))          # 2^16 = 65k vertices
EDGE_FACTOR = int(os.environ.get("BENCH_EDGE_FACTOR", "16"))  # ~1M edges

# persistent jit cache: shard-step compiles amortize across bench processes
import jax  # noqa: E402

jax.config.update("jax_compilation_cache_dir", str(BENCH_DIR / "jit_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)


def get_graph():
    """(src, dst, n) for the benchmark RMAT graph (cached per process)."""
    src, dst = materialize(rmat_edges(scale=SCALE, edge_factor=EDGE_FACTOR, seed=11))
    return src, dst, 1 << SCALE


def get_store(threshold_edge_num: int = 1 << 16) -> GraphStore:
    tag = f"v3_s{SCALE}_e{EDGE_FACTOR}_t{threshold_edge_num}"
    out = BENCH_DIR / f"store_{tag}"
    if (out / "property.json").exists():
        return GraphStore(out)
    src, dst, n = get_graph()
    el = BENCH_DIR / f"el_{tag}"
    if not (el / "meta.json").exists():
        write_edge_list(el, [(src, dst)], num_vertices=n)
    # lane=16: CPU-friendly vector width for the benches (TPU default is 128;
    # the layout algebra is identical — see core/shards.py)
    return preprocess_graph(str(el), str(out), threshold_edge_num=threshold_edge_num,
                            lane=16)


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
