"""Paper Fig. 8: effect of compressed edge caching — modes 0-4 with a cache
budget smaller than the graph, reporting first-10-iteration time, % shards
cached, hit ratio and disk bytes (the paper's panels a-d)."""
from __future__ import annotations

from benchmarks.common import get_store, row
from repro.core import apps  # noqa: F401  (registers the standard programs)
from repro.core.cache import auto_select_mode
from repro.session import GraphSession


def run() -> list[str]:
    out = []
    store = get_store()
    # budget ~35% of the raw graph => raw caching can't hold it, zstd can
    budget = int(store.total_shard_bytes() * 0.35)
    for mode in (0, 1, 2, 3, 4):
        sess = GraphSession(store, cache_mode=mode, cache_budget_bytes=budget)
        res = sess.run("pagerank", max_iters=10)
        st = sess.stats
        cached_frac = sess.cache.cached_shards / store.num_shards
        out.append(row(
            f"fig8_cache_mode{mode}", res.total_seconds * 1e6,
            f"actual_mode={sess.cache.mode};"
            f"cached={cached_frac:.0%};hit={st.hit_ratio:.2f};"
            f"disk_MB={st.disk_bytes/1e6:.1f};"
            f"decomp_s={st.decompress_seconds:.2f}"))
    auto = auto_select_mode(store.total_shard_bytes(), budget)
    out.append(row("fig8_auto_selected_mode", 0.0, f"mode={auto}"))
    return out
