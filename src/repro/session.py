"""GraphSession: the unified entry point for graph analytics.

GraphMP's central economics are "preprocess once, serve many applications
from the same shards, with the compressed edge cache absorbing the disk
I/O" (paper §2.2, §2.4.2).  A ``GraphSession`` is the long-lived object
that realises that: it owns the ``GraphStore``, exactly ONE
``CompressedShardCache``, the device-resident padded degree arrays, the
per-shard Bloom filters, and a per-program cache of constructed engines
(so re-running an application reuses its jitted step functions).

    from repro import GraphSession

    with GraphSession(store_path, cache_budget_bytes=1 << 28) as s:
        pr = s.run("pagerank", max_iters=30)
        d  = s.run("sssp", source=0)          # warm cache: ~no disk reads
        cc = s.run("cc")
        print(s.stats.hit_ratio, s.stats.disk_bytes)
        print(s.cache_report())               # tier occupancy, promotions,
        #                                       decode seconds saved, ...

The shared cache is the two-tier adaptive edge cache of core/cache.py
(hot decompressed tier + cold compressed tier under one strict budget —
``cache_budget_bytes`` / env ``GRAPHMP_CACHE_BUDGET``); pass
``cache_mode=0..4`` for the paper's static modes.

Storage is pluggable through the ``ShardSource`` protocol —
``backend="npz" | "packed" | "memory"`` selects the layer (packed = one
mmap'd file, zero-copy shard views), and ``prefetch_depth=N`` (env
``GRAPHMP_PREFETCH``) streams shards through a double-buffered background
pipeline so disk reads, decompression and host->device staging overlap the
SpMV:

    with GraphSession(store_path, backend="packed", prefetch_depth=2) as s:
        pr = s.run("pagerank", max_iters=30)

Multi-device: ``num_devices=N`` (env ``GRAPHMP_DEVICES``) makes every run
drive N local jax devices per edge sweep — the session builds a
``PartitionedShardCache`` (per-device slices of the one budget) and routes
engines to ``repro.core.distributed.ShardedVSWEngine``; results are
bitwise-identical to ``num_devices=1`` and the whole API above is
unchanged (on CPU, set ``XLA_FLAGS=--xla_force_host_platform_device_count``
before jax initializes):

    with GraphSession(store_path, num_devices=8, prefetch_depth=2) as s:
        pr = s.run("pagerank", max_iters=30)   # 8 shards folded per wave

Applications dispatch through the ``@register_app`` registry
(core/apps.py) by name, or a ``VertexProgram`` can be passed directly.
``run_many`` batches several applications; ``iter_run`` yields an
``IterationStats`` per iteration for live monitoring; ``run_batch``
answers K single-source queries (SSSP/BFS landmarks, personalized-PageRank
seeds) through ONE sweep of the edge shards per iteration:

    dists = s.run_batch("sssp", sources=[0, 17, 4095])   # 3 frontiers,
    # ...one [n, 3] value matrix, one pass of disk + decompression

For many concurrent CLIENTS (a query-serving workload rather than one
analyst), ``session.service()`` wraps the session in a thread-safe
``GraphService`` that coalesces independent submissions into those
K-column batches dynamically — see repro/serve/graph_service.py.

Thread-safety: ``run``/``run_batch`` may be called from multiple threads.
The compressed cache takes its own lock, the engine cache is locked here,
engines are shared by ``jit_signature`` (identical compiled steps) with
the concrete program pinned per call, and each engine serializes its runs.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Iterable, Iterator

import jax.numpy as jnp
import numpy as np

from pathlib import Path

from repro.core.apps import (BatchedVertexProgram, DriverProgram,
                             VertexProgram, get_app, is_incremental)
from repro.core.cache import CompressedShardCache, PartitionedShardCache
from repro.core.engine import (BatchRunResult, EngineConfig, IterationStats,
                               RunResult, VSWEngine, _store_epoch)
from repro.graph.source import ShardSource, path_mtime_ns
from repro.graph.storage import GraphStore

BACKENDS = ("npz", "packed", "memory")


def _resolve_source(store, backend: str | None):
    """Turn (path, backend) into a ShardSource; pass storage objects through."""
    from repro.graph.memory import MemoryGraphStore
    from repro.graph.packed import (DEFAULT_PACKED_NAME, PackedGraphStore,
                                    is_packed_file, pack_graph)

    if not isinstance(store, (str, os.PathLike)):
        if backend is not None:
            raise TypeError(
                "backend= only applies when a graph path is given; got a "
                f"storage object ({type(store).__name__}) — pass its path, "
                "or drop backend=")
        return store
    path = Path(store)
    if backend is None:
        backend = "packed" if is_packed_file(path) else "npz"
    if backend == "npz":
        store = GraphStore(path)
        store.properties  # validate up front: clear MissingGraphError, not a
        #                   raw ENOENT from vertex_info.npz deeper in __init__
        return store
    if backend == "packed":
        if path.is_dir():
            # auto-pack (and re-pack after a fresh preprocess): property.json
            # is written last by preprocess_graph, so its mtime dates the store
            packed = path / DEFAULT_PACKED_NAME
            prop = path / "property.json"
            packed_ns = path_mtime_ns(packed)  # -1 when missing
            if packed_ns < 0 or packed_ns <= path_mtime_ns(prop):
                pack_graph(GraphStore(path), packed)
            path = packed
        return PackedGraphStore(path)
    if backend == "memory":
        inner = (PackedGraphStore(path) if is_packed_file(path)
                 else GraphStore(path))
        return MemoryGraphStore.from_source(inner)
    raise ValueError(
        f"unknown backend {backend!r}; expected one of {BACKENDS}")

# run_batch accepts the single-source names and maps them onto the batched
# program factories (which are also directly addressable by name).
_BATCH_ALIASES = {
    "sssp": "sssp_multi",
    "bfs": "bfs_multi",
    "pagerank": "personalized_pagerank",
    "ppr": "personalized_pagerank",
    "lp": "lp_multi",
    "kcore": "kcore_multi",
    "triangle_count": "triangles_multi",
    "random_walk": "random_walks",
}
# factories whose per-column parameter is not called "sources" (PPR seeds,
# k-core thresholds, triangle-count probe vertices); sources= still works
# for all of them and is rewritten onto the factory's own vocabulary
_BATCH_PARAMS = {
    "personalized_pagerank": "seeds",
    "kcore_multi": "ks",
    "triangles_multi": "vertices",
}


class GraphSession:
    """Long-lived analytics session over one preprocessed graph.

    Parameters
    ----------
    store:
        A path to a preprocessed graph (npz directory or packed ``.gmpk``
        file), or any constructed ``ShardSource``.  Passing a constructed
        ``GraphStore`` (the pre-backend ``GraphSession(store=...)`` style)
        still works, but ``backend=`` then does not apply — prefer handing
        the session a path and letting ``backend`` pick the storage layer.
    backend:
        Storage backend for a path: ``"npz"`` (directory of per-shard npz
        files), ``"packed"`` (single mmap'd file with zero-copy shard views;
        a directory path is auto-packed to ``packed.gmpk`` on first use), or
        ``"memory"`` (whole graph RAM-resident — tests/benchmarks).  Default:
        sniffed — ``"packed"`` for a packed file, else ``"npz"``.
    config:
        ``EngineConfig`` shared by every engine the session builds.  When
        omitted it comes from ``EngineConfig.from_env()``; extra keyword
        arguments (``cache_budget_bytes=...``, ``prefetch_depth=...``, ...)
        override single fields.
    max_engines:
        LRU bound on cached engines.  Engines are keyed by (program,
        config) — for ``run_batch`` that includes the sources tuple — so a
        long-lived session answering many distinct landmark sets would
        otherwise retain one jitted engine per set forever.
    mutable:
        Wrap the resolved store in a ``repro.graph.delta.DeltaGraphStore``
        so ``apply_mutations`` can commit edge inserts/deletes/upserts.
        Each commit bumps the graph epoch; the shared cache drops only the
        dirty shards, and ``run_incremental`` can continue a previous
        result instead of rerunning cold.  ``repro.graph.compact.compact``
        folds accumulated deltas back into the base storage.
    """

    def __init__(self, store: ShardSource | str | os.PathLike,
                 config: EngineConfig | None = None, max_engines: int = 16,
                 *, backend: str | None = None, mutable: bool = False,
                 **overrides):
        self._owns_store = isinstance(store, (str, os.PathLike))
        store = _resolve_source(store, backend)
        if mutable:
            from repro.graph.delta import DeltaGraphStore
            if not isinstance(store, DeltaGraphStore):
                store = DeltaGraphStore(store)
        if config is None:
            config = EngineConfig.from_env(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        self.store = store
        self.config = config
        if config.num_devices > 1:
            # multi-device sessions partition the ONE edge cache by shard
            # owner: each device's shards hash into its own
            # CompressedShardCache slice, all under the same global budget
            from repro.core.distributed import assign_shards
            owner, _ = assign_shards(
                np.asarray(store.intervals),
                [int(m.get("nnz", 0)) for m in store.properties["shards"]],
                config.num_devices)
            self.cache = PartitionedShardCache(
                store, owner, config.num_devices, mode=config.cache_mode,
                budget_bytes=config.cache_budget_bytes,
                hot_fraction=config.cache_hot_fraction,
                promote_after=config.cache_promote_after)
        else:
            self.cache = CompressedShardCache(
                store, mode=config.cache_mode,
                budget_bytes=config.cache_budget_bytes,
                hot_fraction=config.cache_hot_fraction,
                promote_after=config.cache_promote_after)
        # graph epoch the shared arrays below were read at; engines inherit
        # it and re-sync per run when a mutable store moves past it
        self._graph_epoch = _store_epoch(store)
        # shared vertex metadata: read from disk exactly once per session
        self.in_deg, self.out_deg = store.read_vertex_info()
        self.blooms = store.read_all_blooms()
        shard_meta = store.properties["shards"]
        self.max_rows = max((m["rows"] for m in shard_meta), default=8)
        self.n = store.num_vertices
        self.n_pad = self.n + self.max_rows
        # device-resident padded out-degrees, shared by every engine
        self.out_deg_dev = jnp.asarray(
            np.pad(self.out_deg, (0, self.n_pad - self.n)).astype(np.float32))
        if max_engines < 1:
            raise ValueError(f"max_engines must be >= 1, got {max_engines}")
        self.max_engines = max_engines
        self._engines: "OrderedDict" = OrderedDict()
        # engine-cache lock: GraphService runner threads resolve engines
        # concurrently; the cache itself (CompressedShardCache) has its own
        # lock, and each engine serializes its runs — together these make
        # run()/run_batch() safe to call from many threads
        self._engines_lock = threading.RLock()
        # combined [n, K] result of the most recent run_batch (survives
        # engine-cache eviction, unlike engine(...).last_result)
        self.last_batch_result: BatchRunResult | None = None
        # telemetry taps shared (by reference) with every engine this
        # session builds: each entry is called with every IterationStats as
        # sweeps produce them.  Appending here — e.g. via attach_hub — is
        # seen by engines built BEFORE the append too (same list object).
        self.iteration_observers: list = []

    # -- engine construction / reuse ------------------------------------
    def _resolve(self, app, app_kwargs) -> tuple[VertexProgram, object]:
        if isinstance(app, (VertexProgram, BatchedVertexProgram,
                            DriverProgram)):
            if app_kwargs:
                raise TypeError(
                    "application kwargs only apply when dispatching by name; "
                    f"got a VertexProgram plus {sorted(app_kwargs)}")
            program = app
        else:
            program = get_app(app, **app_kwargs)
        if isinstance(program, DriverProgram):
            # host-driven: no engine, no jit cache — the key is unused
            return program, ("driver", program.name)
        # programs declaring a jit_signature share engines across every
        # parameterization with identical device callables (e.g. ALL sssp
        # sources, ALL K-landmark sets of the same K): the signature is the
        # cache key and the concrete program is handed to run() per call,
        # so a serving workload never recompiles per source set
        sig = getattr(program, "jit_signature", None)
        if sig is not None:
            return program, ("sig", sig)
        if isinstance(app, str):
            return program, ("name", app, tuple(sorted(app_kwargs.items())))
        return program, ("prog", id(program))

    def engine(self, app: str | VertexProgram, config: EngineConfig | None = None,
               **app_kwargs) -> VSWEngine:
        """The session-shared engine for an application (built once per
        (jit_signature or program, config); reuse keeps the jitted step
        caches warm).  The returned engine's default program is rebound to
        the one just requested, so single-threaded ``engine(...).run()``
        works; concurrent callers should go through ``session.run`` /
        ``run_batch`` (which pin the program per call) instead."""
        program, prog_key = self._resolve(app, app_kwargs)
        if isinstance(program, DriverProgram):
            raise TypeError(
                f"{program.name!r} is a host-driven application and has no "
                "engine; dispatch it through session.run / run_batch")
        return self._engine_for(program, prog_key, config)

    def _run_target(self, app, app_kwargs, config):
        """(engine, program-to-pin) for one run.

        Signature-keyed engines get the resolved program pinned per call
        (thread-safe sharing across parameterizations).  Name-keyed engines
        (no jit_signature) run their OWN program: the cache key already
        proves name+kwargs equality, and a fresh factory instance would
        fail _check_program's identity test.  Host-driven programs have no
        engine at all — (None, driver)."""
        program, prog_key = self._resolve(app, app_kwargs)
        if isinstance(program, DriverProgram):
            return None, program
        eng = self._engine_for(program, prog_key, config)
        return eng, (program if prog_key[0] == "sig" else None)

    def _engine_for(self, program, prog_key, config) -> VSWEngine:
        key = (prog_key, config or self.config)
        with self._engines_lock:
            eng = self._engines.get(key)
            if eng is None:
                cls = VSWEngine
                if (config or self.config).num_devices > 1:
                    # transparent multi-device routing: same run/run_batch/
                    # iter_run surface, N devices per edge sweep
                    from repro.core.distributed import ShardedVSWEngine
                    cls = ShardedVSWEngine
                eng = cls.from_session(self, program, config)
                if prog_key[0] == "prog":
                    # a raw-id key must keep the program alive to stay unique
                    eng._keyed_program = program
                self._engines[key] = eng
                while len(self._engines) > self.max_engines:
                    self._engines.popitem(last=False)  # drop the LRU engine
            else:
                self._engines.move_to_end(key)
                if eng.program is not program and prog_key[0] == "sig":
                    # same compiled steps, new default host-side identity;
                    # _check_program trips on a false jit_signature claim
                    # (device callables differing from the compiled ones)
                    eng._check_program(program)
                    eng.program = program
            return eng

    # -- running --------------------------------------------------------
    def run(self, app: str | VertexProgram, *, max_iters: int = 200,
            checkpoint_dir: str | None = None, checkpoint_every: int = 0,
            resume: bool = False, config: EngineConfig | None = None,
            **app_kwargs) -> RunResult:
        """Run one application to ``max_iters`` or convergence.

        Parameters
        ----------
        app:
            A registered application name (see
            ``repro.core.apps.available_apps()``; extra keyword arguments go
            to its factory, e.g. ``run("sssp", source=3)`` or
            ``run("pagerank", damping=0.9)``) or a constructed
            ``VertexProgram``.
        max_iters:
            Iteration cap; the run also stops early when no vertex value
            changes (``RunResult.converged``).
        checkpoint_dir / checkpoint_every / resume:
            Fault tolerance: snapshot (values, frontier, iteration) into
            ``checkpoint_dir`` every ``checkpoint_every`` iterations;
            ``resume=True`` restarts from the latest snapshot (and refuses a
            checkpoint written by a different program or source set).
        config:
            ``EngineConfig`` overriding the session config for this
            application's engine (the compressed edge cache stays shared
            either way).

        Returns
        -------
        RunResult with ``values`` (one float per vertex), ``iterations``,
        ``converged``, and ``history`` (one ``IterationStats`` per
        iteration — disk bytes, cache hit ratio, stall/fetch seconds).
        """
        # the program rides along explicitly: engines shared by jit_signature
        # stay stateless across concurrent runs (thread-safety contract)
        eng, run_program = self._run_target(app, app_kwargs, config)
        if eng is None:  # host-driven application
            return self._run_driver(
                run_program, max_iters=max_iters,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every, resume=resume,
                config=config)
        return eng.run(max_iters=max_iters, checkpoint_dir=checkpoint_dir,
                       checkpoint_every=checkpoint_every, resume=resume,
                       program=run_program)

    def _run_driver(self, program: DriverProgram, *, max_iters,
                    checkpoint_dir, checkpoint_every, resume, config):
        if checkpoint_dir or checkpoint_every or resume:
            raise TypeError(
                f"{program.name!r} is a host-driven application; engine "
                "checkpoint/resume do not apply to it")
        result = program.run(self, max_iters=max_iters, config=config)
        if isinstance(result, BatchRunResult):
            self.last_batch_result = result
        return result

    def iter_run(self, app: str | VertexProgram, *, max_iters: int = 200,
                 checkpoint_dir: str | None = None, checkpoint_every: int = 0,
                 resume: bool = False, config: EngineConfig | None = None,
                 **app_kwargs) -> Iterator[IterationStats]:
        """Streaming form of ``run``: yields an ``IterationStats`` after
        every iteration, for live monitoring of long runs.

        Takes exactly the arguments of ``run``.  The finished ``RunResult``
        is the generator's return value (``StopIteration.value``) and is
        also available afterwards as ``session.engine(app, ...).last_result``:

            gen = session.iter_run("pagerank", max_iters=100)
            while True:
                try:
                    print(next(gen).active_ratio)
                except StopIteration as stop:
                    result = stop.value
                    break
        """
        eng, run_program = self._run_target(app, app_kwargs, config)
        if eng is None:
            raise TypeError(
                f"{run_program.name!r} is a host-driven application; "
                "iter_run streams engine iterations — use run() for it")
        return eng.iter_run(max_iters=max_iters, checkpoint_dir=checkpoint_dir,
                            checkpoint_every=checkpoint_every, resume=resume,
                            program=run_program)

    def run_batch(self, app: str | BatchedVertexProgram = "sssp", *,
                  sources: Iterable[int] | None = None, max_iters: int = 200,
                  checkpoint_dir: str | None = None, checkpoint_every: int = 0,
                  resume: bool = False, config: EngineConfig | None = None,
                  **app_kwargs) -> list[RunResult]:
        """K single-source queries through ONE sweep of the edge shards.

        Each iteration pays disk + decompression for a shard once and
        advances every column against it, so K landmark queries cost close
        to one query's I/O instead of K (paper §2.2's amortization, applied
        across *queries*).

        Parameters
        ----------
        app:
            A single-source name (``"sssp"``/``"bfs"``/``"pagerank"`` — the
            latter becomes personalized PageRank over the given seeds), a
            batched factory name (``"sssp_multi"``/``"bfs_multi"``/
            ``"personalized_pagerank"``), or a ``BatchedVertexProgram``.
        sources:
            One frontier vertex per column (for PPR these are the ``seeds``;
            either spelling works).  Required when dispatching by name.
        max_iters / checkpoint_dir / checkpoint_every / resume / config:
            As in ``run``; checkpoints hold the full [n, K] state, so a
            resumed batch continues every column.

        Returns
        -------
        One ``RunResult`` per source, in order, with honest per-column
        iteration counts (a column is only billed for sweeps it entered
        with a live frontier).  The combined ``BatchRunResult`` ([n, K]
        values, shared history) stays available as
        ``session.last_batch_result`` until the next ``run_batch`` call.
        """
        if isinstance(app, (BatchedVertexProgram, DriverProgram)):
            if sources is not None:
                raise TypeError(
                    "sources= only applies when dispatching by name; the "
                    "BatchedVertexProgram already fixes its frontiers")
            # forward app_kwargs so misuse raises like run() does
            program, prog_key = self._resolve(app, app_kwargs)
        else:
            name = _BATCH_ALIASES.get(app, app)
            param = _BATCH_PARAMS.get(name, "sources")
            if sources is not None:
                if param in app_kwargs:
                    raise TypeError(
                        f"pass sources= or {param}=, not both")
                app_kwargs[param] = tuple(int(s) for s in sources)
            elif param in app_kwargs:
                # the factory's own vocabulary (e.g. seeds= for PPR) works too
                app_kwargs[param] = tuple(int(s) for s in app_kwargs[param])
            else:
                raise TypeError("run_batch needs sources=[...] when "
                                "dispatching by name")
            # signature-keyed dispatch so repeat calls reuse the engine (and
            # its jitted [n, K] shard steps) — across DIFFERENT landmark
            # sets of the same K, not just repeats of one set
            try:
                program, prog_key = self._resolve(name, app_kwargs)
            except TypeError as exc:
                if f"unexpected keyword argument {param!r}" in str(exc):
                    # the factory has no frontier parameter at all
                    raise TypeError(
                        f"{name!r} is not a batched application") from None
                raise  # genuine bad kwarg — keep the factory's own message
        if isinstance(program, DriverProgram):
            if not program.batched:
                raise TypeError(f"{app!r} is not a batched application")
            result = self._run_driver(
                program, max_iters=max_iters, checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every, resume=resume,
                config=config)
            assert isinstance(result, BatchRunResult)
            return result.columns()
        if not isinstance(program, BatchedVertexProgram):
            raise TypeError(f"{app!r} is not a batched application")
        eng = self._engine_for(program, prog_key, config)
        result = eng.run(max_iters=max_iters, checkpoint_dir=checkpoint_dir,
                         checkpoint_every=checkpoint_every, resume=resume,
                         program=program if prog_key[0] == "sig" else None)
        assert isinstance(result, BatchRunResult)
        self.last_batch_result = result
        return result.columns()

    def run_many(self, apps: Iterable, **run_kwargs) -> list[RunResult]:
        """Run several applications back-to-back over the shared cache.

        Each item is a registered name, a ``(name, factory_kwargs)`` pair,
        or a ``VertexProgram``; ``run_kwargs`` (``max_iters=...``) apply to
        every run.  Returns results in input order.
        """
        results = []
        for item in apps:
            if isinstance(item, tuple):
                name, kw = item
                results.append(self.run(name, **run_kwargs, **kw))
            else:
                results.append(self.run(item, **run_kwargs))
        return results

    # -- mutation / incremental recompute -------------------------------
    def apply_mutations(self, inserts=None, deletes=None,
                        updates=None) -> int:
        """Commit one batch of edge edits to a ``mutable=True`` session.

        ``inserts``/``updates`` (synonyms — both upsert) take ``(src, dst)``
        or ``(src, dst, weight)`` arrays or triple iterables; ``deletes``
        takes ``(src, dst)`` pairs.  Returns the new graph epoch.  The
        session's shared degree arrays and Bloom filters are refreshed for
        exactly the shards that changed; the shared cache drops stale
        entries lazily on next access.  Runs already in flight pinned the
        previous epoch and will raise ``ConcurrentMutationError`` rather
        than mix epochs — drain them first (``GraphService.apply_mutations``
        does this for serving workloads).
        """
        apply = getattr(self.store, "apply", None)
        if apply is None:
            raise TypeError(
                "this session's store is frozen; open it with "
                "GraphSession(path, mutable=True) (or wrap the store in a "
                "DeltaGraphStore) before applying edge mutations")
        epoch = apply(inserts=inserts, deletes=deletes, updates=updates)
        self._refresh_graph_state()
        return epoch

    def _refresh_graph_state(self) -> None:
        """Re-read graph-derived session state after the store's epoch moved.

        Mirrors ``VSWEngine._sync_graph_state`` for the session-owned shared
        arrays, so engines built *after* a mutation start consistent.  The
        blooms list is shared by reference with every live engine — updating
        entries in place keeps them all coherent.
        """
        prev = self._graph_epoch
        cur = _store_epoch(self.store)
        if cur == prev:
            return
        self.in_deg, self.out_deg = self.store.read_vertex_info()
        shard_meta = self.store.properties["shards"]
        self.max_rows = max((m["rows"] for m in shard_meta), default=8)
        self.n_pad = max(self.n_pad, self.n + self.max_rows)  # grow-only
        self.out_deg_dev = jnp.asarray(
            np.pad(self.out_deg, (0, self.n_pad - self.n)).astype(np.float32))
        shard_epoch = getattr(self.store, "shard_epoch", None)
        for p in range(self.store.num_shards):
            if shard_epoch is None or shard_epoch(p) > prev:
                self.blooms[p] = self.store.read_bloom(p)
        self._graph_epoch = cur

    def run_incremental(self, app: str | VertexProgram, *,
                        prev: RunResult, max_iters: int = 200,
                        config: EngineConfig | None = None,
                        **app_kwargs) -> RunResult:
        """Continue a previous run's fixpoint across graph mutations.

        ``prev`` must be the ``RunResult`` of the same application and
        source over this session's store.  When every commit since
        ``prev.epoch`` was *monotone* (insert-only / weight-non-increasing)
        and the application is registered ``incremental=True`` (SSSP, BFS,
        CC — min-propagations whose old fixpoint stays a valid upper
        bound), the run seeds its values from ``prev`` and its frontier
        from just the source vertices the deltas touched: convergence takes
        the few iterations the change actually propagates, and selective
        scheduling reads only the shards those frontiers reach.

        Falls back to a cold full run whenever the shortcut would be
        unsound: a non-incremental app, a delete or weight increase since
        ``prev.epoch``, an unconverged ``prev``, or an epoch log truncated
        past it.  If the store has not moved since ``prev``, returns the
        previous values directly (0 iterations).
        """
        program, prog_key = self._resolve(app, app_kwargs)
        if isinstance(program, (BatchedVertexProgram, DriverProgram)):
            raise TypeError(
                "run_incremental takes single-frontier applications; "
                "run_batch results cannot seed it")
        tag = VSWEngine._tag_for(program)
        if prev.tag is not None and prev.tag != tag:
            raise ValueError(
                f"prev result was produced by {prev.tag!r}, not {tag!r}; "
                "incremental recompute must continue the same program and "
                "source")
        cur = _store_epoch(self.store)
        if cur == prev.epoch and prev.converged:
            # nothing changed since prev: its fixpoint is still the answer
            return RunResult(values=np.array(prev.values), iterations=0,
                             history=[], converged=True, epoch=cur, tag=tag)
        name = app if isinstance(app, str) else program.name
        monotone_since = getattr(self.store, "monotone_since", None)
        seeds = None
        if (prev.converged and is_incremental(name)
                and monotone_since is not None
                and monotone_since(prev.epoch)):
            # None when the epoch log no longer reaches back to prev.epoch
            seeds = self.store.affected_sources_since(prev.epoch)
        eng = self._engine_for(program, prog_key, config)
        run_program = program if prog_key[0] == "sig" else None
        if seeds is None:
            return eng.run(max_iters=max_iters, program=run_program)
        values = np.array(prev.values)
        active = np.zeros(self.n, dtype=bool)
        active[seeds] = True
        return eng.run(max_iters=max_iters, program=run_program,
                       init_state=(values, active))

    def service(self, config=None, **overrides):
        """A concurrent query service over this session.

        Returns a started ``repro.serve.GraphService`` wrapping this
        session: many client threads ``submit()`` single queries, the
        service coalesces compatible ones into K-column micro-batches served
        by ``run_batch`` through the shared compressed cache, and each
        caller gets its own future/``RunResult``.  ``config`` is a
        ``repro.serve.ServiceConfig``; keyword overrides
        (``max_batch=...``, ``max_wait_ms=...``) adjust single fields::

            with GraphSession(path) as s, s.service(max_batch=16) as svc:
                fut = svc.submit("sssp", source=42)
                print(fut.result().values[:10])

        The session must outlive the service (close the service first —
        the ``with`` form above nests them correctly).
        """
        from repro.serve.graph_service import GraphService
        return GraphService(self, config, **overrides)

    # -- observability / lifecycle --------------------------------------
    @property
    def stats(self):
        """Shared CompressedShardCache stats (hits, disk_bytes, ...)."""
        return self.cache.stats

    def cache_report(self) -> dict:
        """Snapshot of the shared edge cache: policy ("adaptive"/"static"),
        mode, budget, per-tier occupancy (``hot_bytes``/``hot_shards``,
        ``cold_bytes``/``cold_shards``), hit/miss/promotion/demotion/eviction
        counters, ``decode_seconds_saved`` (decompression cost hot-tier hits
        skipped) and the achieved compression ratio.  All values are
        self-consistent (taken under the cache lock)."""
        return self.cache.report()

    def attach_hub(self, hub, prefix: str = "session"):
        """Wire this session's telemetry into a ``repro.obs.MetricsHub``:

        * ``{prefix}.cache.*`` — a poller over ``cache_report()`` (numeric
          leaves flattened into gauges at each hub sample: tier occupancy,
          hit/miss/eviction counters, achieved compression ratio; the
          partitioned cache's per-partition sub-reports flatten too);
        * ``{prefix}.engine.*`` — an ``iteration_observers`` tap converting
          every ``IterationStats`` into counters (iterations,
          disk_bytes, edges_processed, stall/fetch/decode-saved seconds,
          per-device ``engine.devN.*`` splits for sharded runs), gauges
          (last active_ratio / cache_hit_ratio), and an
          ``{prefix}.engine.iteration_s`` histogram of sweep durations.

        Engines already built share the observer list by reference, so
        attaching mid-flight captures every subsequent iteration.  Returns
        ``hub`` for chaining.
        """
        hub.register_poller(f"{prefix}.cache", self.cache_report)
        iter_hist = hub.histogram(f"{prefix}.engine.iteration_s")
        eng = f"{prefix}.engine"

        def observe(stats) -> None:
            hub.counter(f"{eng}.iterations").inc()
            hub.counter(f"{eng}.disk_bytes").inc(stats.disk_bytes)
            hub.counter(f"{eng}.edges_processed").inc(stats.edges_processed)
            hub.counter(f"{eng}.shards_processed").inc(stats.shards_processed)
            hub.counter(f"{eng}.shards_skipped").inc(stats.shards_skipped)
            hub.counter(f"{eng}.stall_seconds").inc(stats.stall_seconds)
            hub.counter(f"{eng}.fetch_seconds").inc(stats.fetch_seconds)
            hub.counter(f"{eng}.decode_seconds_saved").inc(
                stats.decode_seconds_saved)
            hub.gauge(f"{eng}.active_ratio").set(stats.active_ratio)
            hub.gauge(f"{eng}.cache_hit_ratio").set(stats.cache_hit_ratio)
            iter_hist.observe(stats.seconds)
            for d, (db, ds, df) in enumerate(zip(stats.device_disk_bytes,
                                                 stats.device_stall_seconds,
                                                 stats.device_fetch_seconds)):
                hub.counter(f"{eng}.dev{d}.disk_bytes").inc(db)
                hub.counter(f"{eng}.dev{d}.stall_seconds").inc(ds)
                hub.counter(f"{eng}.dev{d}.fetch_seconds").inc(df)

        self.iteration_observers.append(observe)
        return hub

    def warm(self) -> int:
        """Pull every shard through the cache once (prefetch); returns the
        bytes now resident."""
        for p in range(self.store.num_shards):
            self.cache.get(p)
        return self.cache.cached_bytes

    def close(self) -> None:
        """Drop engine and cache references (jit caches, cached blobs)."""
        self._engines.clear()
        self.cache.clear()
        if self._owns_store:
            try:
                getattr(self.store, "close", lambda: None)()
            except BufferError:
                # jax aliases mmap'd shard segments zero-copy on CPU and
                # releases them asynchronously; the mapping closes when the
                # last consumer drops its buffer
                pass

    def __enter__(self) -> "GraphSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"GraphSession({str(self.store.path)!r}, |V|={self.n}, "
                f"|E|={self.store.num_edges}, shards={self.store.num_shards}, "
                f"cache_mode={self.cache.mode})")
