"""GraphMP reproduction: I/O-efficient big-graph analytics (single machine).

Public surface: ``GraphSession`` (the one entry point for analytics —
preprocess once, run many applications over a shared compressed cache),
``EngineConfig`` for tuning, and ``register_app`` for new vertex programs.

    from repro import GraphSession, preprocess_graph, write_edge_list

    write_edge_list(edges_dir, [(src, dst)])
    store = preprocess_graph(edges_dir, graph_dir)
    with GraphSession(store, cache_budget_bytes=1 << 28) as s:
        pr = s.run("pagerank", max_iters=30)
"""
import repro._compat  # noqa: F401  (jax version bridge; must import first)

# lazy attribute exports (PEP 562) keep `import repro` light — jax-heavy
# modules load on first touch of the corresponding name.
_EXPORTS = {
    "GraphSession": ("repro.session", "GraphSession"),
    "EngineConfig": ("repro.core.engine", "EngineConfig"),
    "VSWEngine": ("repro.core.engine", "VSWEngine"),
    "RunResult": ("repro.core.engine", "RunResult"),
    "BatchRunResult": ("repro.core.engine", "BatchRunResult"),
    "IterationStats": ("repro.core.engine", "IterationStats"),
    "register_app": ("repro.core.apps", "register_app"),
    "get_app": ("repro.core.apps", "get_app"),
    "available_apps": ("repro.core.apps", "available_apps"),
    "VertexProgram": ("repro.core.apps", "VertexProgram"),
    "BatchedVertexProgram": ("repro.core.apps", "BatchedVertexProgram"),
    "CompressedShardCache": ("repro.core.cache", "CompressedShardCache"),
    "ShardPipeline": ("repro.core.pipeline", "ShardPipeline"),
    "ShardSource": ("repro.graph.source", "ShardSource"),
    "MissingGraphError": ("repro.graph.source", "MissingGraphError"),
    "ConcurrentMutationError": ("repro.graph.source",
                                "ConcurrentMutationError"),
    "DeltaGraphStore": ("repro.graph.delta", "DeltaGraphStore"),
    "DeltaBudgetError": ("repro.graph.delta", "DeltaBudgetError"),
    "compact": ("repro.graph.compact", "compact"),
    "CompactionReport": ("repro.graph.compact", "CompactionReport"),
    "GraphStore": ("repro.graph.storage", "GraphStore"),
    "PackedGraphStore": ("repro.graph.packed", "PackedGraphStore"),
    "MemoryGraphStore": ("repro.graph.memory", "MemoryGraphStore"),
    "pack_graph": ("repro.graph.packed", "pack_graph"),
    "write_edge_list": ("repro.graph.storage", "write_edge_list"),
    "preprocess_graph": ("repro.graph.preprocess", "preprocess_graph"),
    "rmat_edges": ("repro.graph.generate", "rmat_edges"),
    "uniform_edges": ("repro.graph.generate", "uniform_edges"),
    "zipf_edges": ("repro.graph.generate", "zipf_edges"),
    "materialize": ("repro.graph.generate", "materialize"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
