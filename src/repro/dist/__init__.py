# Distributed substrate: logical-axis sharding rules (see dist/context.py).
# No eager re-exports — importing this package must not touch jax device
# state (launch/dryrun.py sets XLA_FLAGS before its imports).
