"""Logical-axis sharding context: the one place mesh layout policy lives.

Models and launch code never name mesh axes directly; they annotate arrays
with *logical* axes ('batch', 'ffn', 'experts', ...) and ask the ``ShardCtx``
to map them.  ``make_rules`` builds the mapping for a concrete mesh + arch:

  * activation rules (``ctx.rules``) drive ``constrain`` /
    ``logical_sharding`` — batch over the data axes (and 'pod' when
    present), tensor-parallel dims over 'model', the KV-cache sequence dim
    over 'data' only for long-context serving;
  * weight rules (``ctx.weight_rules``) drive ``param_sharding`` — TP dims
    over 'model', plus FSDP of the embed dim over 'data' when
    ``serve_fsdp`` (always on for training);
  * the serve 2-D MoE layout (``serve_fsdp=False``) flips experts onto the
    token ('data') axis with second-level TP on the expert ff dim —
    consumed by models/moe.py.

A ``ShardCtx(None, {}, {})`` is the disabled single-device context:
``constrain`` is the identity and every ``axis_size`` is 1, so model code is
mesh-agnostic without branching.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

import numpy as np

import repro._compat  # noqa: F401  (jax.shard_map/AxisType aliases)
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_data_mesh(num_devices: int, axis: str = "data") -> Mesh:
    """1-D mesh over the first ``num_devices`` local devices.

    The graph engines (``repro.core.distributed``) partition destination
    intervals over this single axis; the model stack builds its own 2-D
    meshes via ``make_rules``.  Raises with the CPU-emulation hint when the
    process has fewer devices than requested (jax locks the device count at
    first init, so the flag must be set before importing jax).
    """
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    devices = jax.devices()
    if num_devices > len(devices):
        raise RuntimeError(
            f"num_devices={num_devices} but only {len(devices)} jax "
            f"device(s) are visible; on CPU launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_devices} "
            f"(set before jax initializes)")
    return Mesh(np.asarray(devices[:num_devices]), (axis,))

# a rule value: one mesh axis name, a tuple of them (e.g. ('pod', 'data')),
# or None for replicated
Rule = Any


def _axes_tuple(rule: Rule) -> tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh | None
    rules: Mapping[str, Rule]         # activation logical axis -> mesh axes
    weight_rules: Mapping[str, Rule]  # parameter logical axis -> mesh axes
    ep_mode: str = "a2a"              # 'a2a' | 'replicated' (models/moe.py)

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    # -- sizes -----------------------------------------------------------
    def axis_size(self, logical: str) -> int:
        """Total device count the logical axis is split over (1 if replicated)."""
        if not self.enabled:
            return 1
        return math.prod(self.mesh.shape[a]
                         for a in _axes_tuple(self.rules.get(logical)))

    # -- spec construction ----------------------------------------------
    def _spec(self, logical_axes, rules: Mapping[str, Rule],
              shape=None) -> P:
        """Map logical dim names to a PartitionSpec.

        A mesh axis may appear at most once in a spec; when ``shape`` is
        known, a dim that the mesh axis does not divide evenly stays
        replicated (reduced test configs have tiny dims).
        """
        used: set[str] = set()
        out: list[Rule] = []
        for i, name in enumerate(logical_axes):
            rule = rules.get(name) if name is not None else None
            axes = _axes_tuple(rule)
            if axes and not (used & set(axes)):
                size = math.prod(self.mesh.shape[a] for a in axes)
                if shape is None or (size and shape[i] % size == 0):
                    used.update(axes)
                    out.append(rule if isinstance(rule, str) else tuple(axes))
                    continue
            out.append(None)
        return P(*out)

    def logical_sharding(self, logical_axes) -> NamedSharding | None:
        """NamedSharding for an activation/input tree leaf (None if disabled)."""
        if not self.enabled:
            return None
        return NamedSharding(self.mesh, self._spec(logical_axes, self.rules))

    def param_sharding(self, param) -> NamedSharding | None:
        """NamedSharding for a Param-annotated weight (by its logical axes)."""
        if not self.enabled:
            return None
        axes = tuple(param.axes or ())
        shape = tuple(getattr(param.value, "shape", ()) or ())
        if len(axes) != len(shape):
            axes = axes + (None,) * (len(shape) - len(axes))
        return NamedSharding(
            self.mesh, self._spec(axes[: len(shape)], self.weight_rules, shape))

    def constrain(self, x, logical_axes):
        """with_sharding_constraint by logical axes; identity when disabled."""
        if not self.enabled:
            return x
        spec = self._spec(logical_axes, self.rules, shape=x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
def make_rules(mesh: Mesh | None, cfg, *, long_context: bool = False,
               ep_mode: str = "a2a", serve_fsdp: bool = True) -> ShardCtx:
    """Derive the logical->mesh mapping for one (mesh, arch, variant) cell.

    ``mesh=None`` yields the disabled single-device context."""
    if mesh is None:
        return ShardCtx(None, {}, {}, ep_mode=ep_mode)
    names = tuple(mesh.axis_names)
    model = "model" if "model" in names else None
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    batch: Rule = (data_axes if len(data_axes) > 1
                   else (data_axes[0] if data_axes else None))
    data = "data" if "data" in names else None

    rules: dict[str, Rule] = {
        "batch": batch,
        "seq": None,                 # activations keep seq replicated;
        "kv_seq": (data if long_context else None),  # ...KV caches may not
        "embed": None,
        "ffn": model,
        "swiglu": model,
        "geglu": model,
        "q_heads": model,
        "kv_heads": None,            # few KV heads: replicate, repeat for TP
        "head_dim": None,
        "lstm_heads": model,
        "mamba_inner": model,
        "vocab": model,
        "experts": model,
    }

    weight_rules: dict[str, Rule] = {
        "layers": None,
        # FSDP over the data axes: on for training and the default serve
        # layout, off for the 2-D expert serve variant
        "embed": (batch if serve_fsdp else None),
        "ffn": model,
        "swiglu": model,
        "geglu": model,
        "q_heads": model,
        "kv_heads": None,
        "head_dim": None,
        "lstm_heads": model,
        "mamba_inner": model,
        "vocab": model,
        "experts": model,
        "expert_ff": None,
    }
    if not serve_fsdp and data is not None and model is not None:
        # serve 2-D MoE layout: experts over the token axis, second-level TP
        # on the expert ff dim (models/moe.py routes around the a2a for it)
        rules["experts"] = data
        weight_rules["experts"] = data
        weight_rules["expert_ff"] = model

    return ShardCtx(mesh, rules, weight_rules, ep_mode=ep_mode)
