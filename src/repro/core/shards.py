"""Graph sharding: vertex intervals (Algorithm 1), CSR shards, blocked-ELL.

Faithful to the paper's §2.2:
  * vertices are split into P disjoint intervals; shard(i) holds every edge
    whose *destination* lies in interval i (pull-mode, single writer);
  * Algorithm 1 greedily cuts intervals so each shard holds at most
    ``threshold_edge_num`` edges (paper default: 20M edges ≈ 80MB);
  * edges inside a shard are grouped by destination and stored in CSR.

TPU adaptation (DESIGN.md §4): CSR rows are re-laid out as **blocked-ELL** —
``(rows, width)`` rectangles with lane-aligned width (multiple of 128) and
sentinel columns ``col < 0``.  Rows whose degree exceeds the shard's ELL
width are wrapped onto extra ELL rows mapped to the same destination vertex
(`row_map`), which is how we absorb power-law skew without padding the whole
shard to the max in-degree.  The reduce over duplicated rows re-applies the
semiring, preserving exact results for +, min.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

LANE = 128  # TPU lane width; ELL width is padded to a multiple of this.
SUBLANE = 8  # TPU sublane; ELL row count padded to a multiple of this.

# Edge-value storage dtypes (GRAPHMP_EDGE_DTYPE / preprocess val_dtype).
# float32 is the exact baseline; float16/int8 trade bounded error for halved/
# quartered edge-value bytes on disk, in cache, AND over the HBM read the
# SpMV kernel performs (dequantization happens inside the kernel).
EDGE_VAL_DTYPES = ("float32", "float16", "int8")


# --------------------------------------------------------------------------
# edge-value quantization (per-shard affine scheme)
# --------------------------------------------------------------------------
def quantize_edge_vals(vals: np.ndarray, dtype: str) -> tuple[np.ndarray, float, float]:
    """Quantize a float32 edge-value array -> (q, scale, zero).

    Dequantization is the single affine formula used everywhere (kernel,
    jnp fallback, delta re-layout)::

        v_hat = (q.astype(float32) - zero) * scale

    * float32 — identity (scale=1, zero=0).
    * float16 — plain downcast (scale=1, zero=0); error <= 2^-11 * |v|.
    * int8    — affine over [vmin, vmax] widened to include 0, with the
      zero point rounded to an *integer* so v=0 (and therefore padded
      slots) quantizes to q=zero and dequantizes to exactly 0.0:
      scale=(vmax-vmin)/255, zero=rint(-128-vmin/scale),
      q=clip(rint(v/scale+zero)).  Max abs error stays scale/2: rounding
      the zero point shifts the whole grid by delta in [-1/2, 1/2] steps,
      and a range endpoint pushed past +-128 clips back by that same
      delta.  An all-zero array quantizes exactly (scale=1, zero=-128).

    scale/zero are rounded to float32 so every consumer (device kernels
    included) dequantizes with bit-identical parameters.
    """
    dt = np.dtype(dtype)
    if dt == np.float32:
        return vals.astype(np.float32), 1.0, 0.0
    if dt == np.float16:
        return vals.astype(np.float16), 1.0, 0.0
    if dt != np.int8:
        raise ValueError(f"unsupported edge-value dtype {dtype!r}; "
                         f"choose from {EDGE_VAL_DTYPES}")
    v = np.asarray(vals, dtype=np.float32)
    vmin = min(float(v.min(initial=0.0)), 0.0)
    vmax = max(float(v.max(initial=0.0)), 0.0)
    scale = (vmax - vmin) / 255.0
    if scale == 0.0:
        scale = 1.0
    scale = float(np.float32(scale))
    # Integer zero point: 0 lies in [vmin, vmax] by construction, so zero
    # lands in [-128, 127] and rint keeps it there — exact in float32.
    zero = float(np.float32(np.rint(-128.0 - vmin / scale)))
    q = np.clip(np.rint(v / np.float32(scale) + np.float32(zero)),
                -128, 127).astype(np.int8)
    return q, scale, zero


def dequantize_edge_vals(vals: np.ndarray, scale: float = 1.0,
                         zero: float = 0.0) -> np.ndarray:
    """Invert :func:`quantize_edge_vals` (float32 passes through untouched)."""
    if vals.dtype == np.float32:
        return vals
    return ((vals.astype(np.float32) - np.float32(zero))
            * np.float32(scale)).astype(np.float32)


# --------------------------------------------------------------------------
# Algorithm 1: compute vertex intervals
# --------------------------------------------------------------------------
def compute_intervals(in_degrees: np.ndarray, threshold_edge_num: int) -> np.ndarray:
    """Greedy interval cut, exactly Algorithm 1.

    Returns ``starts`` of shape [P+1]: shard p owns vertices
    [starts[p], starts[p+1]).  A single vertex whose in-degree exceeds the
    threshold gets its own interval (the paper requires the threshold to be
    no smaller than the max in-degree; we relax that by allowing singleton
    intervals, which the ELL row-wrapping then handles).
    """
    n = int(in_degrees.shape[0])
    if n == 0:
        return np.array([0], dtype=np.int64)
    csum = np.concatenate([[0], np.cumsum(in_degrees.astype(np.int64))])
    starts = [0]
    v = 0
    while v < n:
        # Largest end such that csum[end] - csum[v] <= threshold, end > v.
        end = int(np.searchsorted(csum, csum[v] + threshold_edge_num, side="right")) - 1
        end = max(end, v + 1)  # always make progress (singleton heavy vertex)
        end = min(end, n)
        starts.append(end)
        v = end
    return np.asarray(starts, dtype=np.int64)


# --------------------------------------------------------------------------
# CSR shard
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CSRShard:
    """One destination-interval shard in CSR (paper's on-disk format)."""

    shard_id: int
    start_vertex: int  # first destination vertex id owned by this shard
    end_vertex: int    # one past the last destination vertex id
    row: np.ndarray    # [rows+1] int64 — CSR row pointers (rows = end-start)
    col: np.ndarray    # [nnz] int32/int64 — source vertex ids
    val: np.ndarray | None  # [nnz] float32 — edge weights (None ⇒ unweighted)

    @property
    def num_rows(self) -> int:
        return self.end_vertex - self.start_vertex

    @property
    def nnz(self) -> int:
        return int(self.col.shape[0])

    def source_vertices(self) -> np.ndarray:
        return np.unique(self.col)


def build_csr_shards(
    src: np.ndarray,
    dst: np.ndarray,
    num_vertices: int,
    threshold_edge_num: int,
    val: np.ndarray | None = None,
) -> list[CSRShard]:
    """Preprocessing steps 2+3 (in memory): bucket edges by destination
    interval, sort/group by destination, emit CSR per shard."""
    in_deg = np.bincount(dst, minlength=num_vertices).astype(np.int64)
    starts = compute_intervals(in_deg, threshold_edge_num)
    order = np.argsort(dst, kind="stable")
    dst_sorted = dst[order]
    src_sorted = src[order]
    val_sorted = val[order] if val is not None else None
    # row pointer over *all* vertices, then slice per shard
    row_all = np.concatenate([[0], np.cumsum(in_deg)])
    shards = []
    for p in range(len(starts) - 1):
        lo, hi = int(starts[p]), int(starts[p + 1])
        e_lo, e_hi = int(row_all[lo]), int(row_all[hi])
        shards.append(
            CSRShard(
                shard_id=p,
                start_vertex=lo,
                end_vertex=hi,
                row=(row_all[lo : hi + 1] - row_all[lo]).astype(np.int64),
                col=src_sorted[e_lo:e_hi].astype(np.int32),
                val=None if val_sorted is None else val_sorted[e_lo:e_hi].astype(np.float32),
            )
        )
    return shards


# --------------------------------------------------------------------------
# Blocked-ELL shard (TPU layout)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ELLShard:
    """TPU-native shard: fixed-width padded rows, sentinel col = -1.

    ``row_map[r]`` gives the *local* destination row (0-based within the
    interval) that ELL row r accumulates into; heavy CSR rows occupy several
    consecutive ELL rows.  rows % SUBLANE == 0 and width % LANE == 0.
    """

    shard_id: int
    start_vertex: int
    end_vertex: int
    cols: np.ndarray     # [R, W] int32, sentinel -1
    vals: np.ndarray     # [R, W] float32 | float16 | int8 (see val_scale)
    row_map: np.ndarray  # [R] int32 — local destination row per ELL row
    nnz: int
    # Affine dequantization parameters for non-float32 ``vals`` (identity for
    # float32): true value = (vals.astype(f32) - val_zero) * val_scale.
    val_scale: float = 1.0
    val_zero: float = 0.0

    @property
    def shape(self) -> tuple[int, int]:
        return self.cols.shape  # (R, W)

    @property
    def quantized(self) -> bool:
        return self.vals.dtype != np.float32

    def vals_f32(self) -> np.ndarray:
        """Edge values dequantized to float32 (host-side consumers)."""
        return dequantize_edge_vals(self.vals, self.val_scale, self.val_zero)

    def padded_bytes(self) -> int:
        return self.cols.nbytes + self.vals.nbytes

    def decoded_nbytes(self) -> int:
        """Host bytes of the decoded shard (cols + vals + row_map) — the one
        definition shared by cache hot-tier accounting and pipeline
        staged-bytes accounting."""
        return self.padded_bytes() + self.row_map.nbytes

    def source_vertices(self) -> np.ndarray:
        c = self.cols[self.cols >= 0]
        return np.unique(c)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _bucket_pow2(x: int, floor: int) -> int:
    """Round up to a power of two (>= floor): shards share few distinct ELL
    shapes, so the jitted shard step compiles once per bucket, not per shard."""
    n = max(x, floor)
    return 1 << (n - 1).bit_length()


def _bucket_quarter_pow2(x: int, floor: int) -> int:
    """Round up to a quarter-power-of-two bucket (…, 1024, 1280, 1536, 1792,
    2048, …): ≤4 shapes per octave keeps jit compiles bounded while wasting
    ≤25% rows (vs ≤100% for pure pow2)."""
    n = max(x, floor)
    p = max(1 << max((n - 1).bit_length() - 2, 0), floor)
    return -(-n // p) * p


def csr_to_ell(shard: CSRShard, max_width: int = 512, lane: int = LANE) -> ELLShard:
    """Re-lay a CSR shard as blocked-ELL with row wrapping.

    ``max_width`` caps the ELL width (multiple of ``lane``); rows with degree
    above it wrap onto multiple ELL rows.  Width targets ~1.5× the mean
    degree — the row-wrapping absorbs the power-law tail, so sizing for the
    tail (e.g. p95) would only inflate padding.  ``lane`` is the hardware
    vector width the layout aligns to (128 on TPU; benches on CPU may pass
    a smaller value — the layout algebra is identical).
    """
    deg = np.diff(shard.row)
    if deg.size == 0 or deg.max() == 0:
        w = lane
    else:
        mean = float(deg[deg > 0].mean()) if (deg > 0).any() else 1.0
        w = min(_bucket_pow2(max(int(mean * 1.2), 1), lane),
                _round_up(max_width, lane))
    # number of ELL rows each CSR row expands into (>=1 so empty rows exist)
    reps = np.maximum(1, -(-deg // w)).astype(np.int64)
    r_used = int(reps.sum())
    R = _bucket_quarter_pow2(r_used, SUBLANE)
    # vectorized expansion: ELL row -> (csr row, occurrence within that row)
    row_map = np.zeros(R, dtype=np.int32)
    row_map[:r_used] = np.repeat(np.arange(shard.num_rows, dtype=np.int32), reps)
    ell_start = np.concatenate([[0], np.cumsum(reps)])  # first ELL row per CSR row
    occ = np.arange(r_used, dtype=np.int64) - ell_start[row_map[:r_used]]
    base = shard.row[row_map[:r_used]] + occ * w  # first edge idx per ELL row
    idx = base[:, None] + np.arange(w, dtype=np.int64)[None, :]
    valid = idx < shard.row[row_map[:r_used] + 1][:, None]
    idx = np.where(valid, idx, 0)
    cols = np.full((R, w), -1, dtype=np.int32)
    vals = np.zeros((R, w), dtype=np.float32)
    if shard.nnz:  # an interval can own zero edges: keep all-sentinel rows
        cols[:r_used] = np.where(valid, shard.col[idx], -1).astype(np.int32)
        if shard.val is not None:
            vals[:r_used] = np.where(valid, shard.val[idx], 0.0).astype(np.float32)
        else:
            vals[:r_used] = valid.astype(np.float32)
    return ELLShard(
        shard_id=shard.shard_id,
        start_vertex=shard.start_vertex,
        end_vertex=shard.end_vertex,
        cols=cols,
        vals=vals,
        row_map=row_map,
        nnz=shard.nnz,
    )


def quantize_shard(shard: ELLShard, dtype: str) -> ELLShard:
    """Return ``shard`` with edge values stored as ``dtype`` (see
    :func:`quantize_edge_vals`).  float32 (or already-matching dtype) is a
    no-op returning the same object."""
    if np.dtype(dtype) == shard.vals.dtype:
        return shard
    if shard.quantized:  # re-quantizing: recover float32 first
        shard = dataclasses.replace(shard, vals=shard.vals_f32(),
                                    val_scale=1.0, val_zero=0.0)
    if np.dtype(dtype) == np.float32:
        return shard
    q, scale, zero = quantize_edge_vals(shard.vals, dtype)
    return dataclasses.replace(shard, vals=q, val_scale=scale, val_zero=zero)


def bucket_shards(shards: Sequence[ELLShard]) -> dict[tuple[int, int], list[ELLShard]]:
    """Group shards by (R, W) so each bucket jits once (VSW scan batches)."""
    buckets: dict[tuple[int, int], list[ELLShard]] = {}
    for s in shards:
        buckets.setdefault(s.shape, []).append(s)
    return buckets


def iter_edges(shard: CSRShard) -> Iterator[tuple[int, int, float]]:
    """Debug helper: yield (src, dst, val) triples of a CSR shard."""
    for local in range(shard.num_rows):
        lo, hi = int(shard.row[local]), int(shard.row[local + 1])
        for e in range(lo, hi):
            v = 1.0 if shard.val is None else float(shard.val[e])
            yield int(shard.col[e]), shard.start_vertex + local, v
