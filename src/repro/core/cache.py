"""Compressed edge cache (paper §2.4.2), modes 0-4 with auto-selection.

Spare host memory caches shard blobs; decompression throughput beats disk.
snappy/zlib-1/zlib-3 from the paper map onto zstd levels 1/3/9 (zstandard is
the compressor available in this container — DESIGN.md §8.2); the mode
semantics, γ table and auto-selection rule `min i s.t. S/γᵢ ≤ C` are kept
verbatim from the paper.

  mode 0: no application cache (OS page cache only)    γ₀ = 1
  mode 1: cache raw (uncompressed) shard arrays        γ₁ = 1 (paper: 2*)
  mode 2: cache zstd-1 blobs   (paper: snappy)         γ₂ = 2
  mode 3: cache zstd-3 blobs   (paper: zlib-1)         γ₃ = 4
  mode 4: cache zstd-9 blobs   (paper: zlib-3)         γ₄ = 5

(*the paper's γ₁=2 reflects that its disk format is CSV-ish while its cache
is binary; our disk format is already binary ELL, so γ₁=1. The selection
rule is unchanged.)

The cache sits on any ``ShardSource`` backend (npz directory, packed file,
in-memory — graph/source.py) and is **thread-safe**: the ShardPipeline calls
``get`` from a prefetch thread while stats are read from the main loop, so
every get/clear and every ``CacheStats`` update happens under one lock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import OrderedDict

try:
    import zstandard
except ImportError:  # optional: modes 2-4 degrade to raw caching (mode 1)
    zstandard = None

from repro.core.shards import ELLShard
from repro.graph.source import ShardSource, unpack_shard_npz

GAMMA = {0: 1.0, 1: 1.0, 2: 2.0, 3: 4.0, 4: 5.0}
ZSTD_LEVEL = {2: 1, 3: 3, 4: 9}

# canonical blob decoder, shared with the storage backends
_unpack = unpack_shard_npz


def auto_select_mode(graph_bytes: int, cache_budget_bytes: int) -> int:
    """Paper's rule: minimal i with S/γᵢ ≤ C; fall back to mode 4."""
    for i in range(5):
        if graph_bytes / GAMMA[i] <= cache_budget_bytes:
            return i
    return 4


@dataclasses.dataclass
class CacheStats:
    """Lifetime counters; mutate through ``bump`` (atomic under a lock)."""

    hits: int = 0
    misses: int = 0
    disk_bytes: int = 0
    decompress_seconds: float = 0.0
    compress_seconds: float = 0.0
    evictions: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def bump(self, **deltas) -> None:
        with self._lock:
            for field, delta in deltas.items():
                setattr(self, field, getattr(self, field) + delta)

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CompressedShardCache:
    """LRU cache over shard blobs with byte budget; wraps a ShardSource."""

    def __init__(self, store: ShardSource, mode: int | str = "auto",
                 budget_bytes: int = 1 << 30):
        self.store = store
        self.budget = int(budget_bytes)
        if mode == "auto":
            mode = auto_select_mode(store.total_shard_bytes(), self.budget)
        if int(mode) in ZSTD_LEVEL and zstandard is None:
            warnings.warn(
                f"zstandard is not installed; cache mode {int(mode)} needs it "
                "— falling back to mode 1 (raw shard caching)",
                RuntimeWarning, stacklevel=2)
            mode = 1
        self.mode = int(mode)
        self.stats = CacheStats()
        self._lru: OrderedDict[int, bytes | ELLShard] = OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()  # one prefetch thread + main loop
        self._cctx = (
            zstandard.ZstdCompressor(level=ZSTD_LEVEL[self.mode])
            if self.mode in ZSTD_LEVEL else None
        )
        self._dctx = zstandard.ZstdDecompressor() if self.mode in ZSTD_LEVEL else None

    @property
    def cached_bytes(self) -> int:
        return self._bytes

    @property
    def cached_shards(self) -> int:
        return len(self._lru)

    def _entry_nbytes(self, entry) -> int:
        if isinstance(entry, bytes):
            return len(entry)
        return entry.padded_bytes() + entry.row_map.nbytes

    def _evict_until(self, need: int) -> None:
        while self._bytes + need > self.budget and self._lru:
            _, old = self._lru.popitem(last=False)
            self._bytes -= self._entry_nbytes(old)
            self.stats.bump(evictions=1)

    def get(self, shard_id: int) -> ELLShard:
        with self._lock:
            if self.mode == 0:
                self.stats.bump(misses=1,
                                disk_bytes=self.store.shard_nbytes(shard_id))
                return self.store.read_shard(shard_id)
            if shard_id in self._lru:
                entry = self._lru.pop(shard_id)
                self._lru[shard_id] = entry  # LRU bump
                if isinstance(entry, bytes):
                    t = time.perf_counter()
                    blob = self._dctx.decompress(entry)
                    self.stats.bump(hits=1, decompress_seconds=time.perf_counter() - t)
                    return _unpack(shard_id, blob)
                self.stats.bump(hits=1)
                return entry
            # miss: disk read, then insert if it fits
            self.stats.bump(misses=1,
                            disk_bytes=self.store.shard_nbytes(shard_id))
            if self.mode == 1:
                shard = self.store.read_shard(shard_id)
                entry: bytes | ELLShard = shard
            else:
                # compress the canonical blob straight off the backend — no
                # decode->re-encode round trip on the miss path
                blob = self.store.read_shard_bytes(shard_id)
                shard = _unpack(shard_id, blob)
                t = time.perf_counter()
                entry = self._cctx.compress(blob)
                self.stats.bump(compress_seconds=time.perf_counter() - t)
            need = self._entry_nbytes(entry)
            if need <= self.budget:
                self._evict_until(need)
                self._lru[shard_id] = entry
                self._bytes += need
            return shard

    def clear(self) -> None:
        """Drop every cached entry (budget and stats are kept)."""
        with self._lock:
            self._lru.clear()
            self._bytes = 0

    def measured_ratio(self) -> float:
        """Achieved compression ratio over currently cached shards."""
        with self._lock:
            if self.mode in (0, 1) or not self._lru:
                return 1.0
            raw = sum(self.store.shard_nbytes(i) for i in self._lru)
            return raw / max(self._bytes, 1)
