"""Compressed edge cache (paper §2.4.2): static modes 0-4 + two-tier adaptive.

Spare host memory caches shard data; decompression throughput beats disk.
snappy/zlib-1/zlib-3 from the paper map onto zstd levels 1/3/9, falling back
to the paper's own zlib (levels 1/3/9) when zstandard is not installed — see
docs/ARCHITECTURE.md, "Edge cache: two tiers under one budget"; the mode
semantics, γ table and auto-selection rule `min i s.t. S/γᵢ ≤ C` are kept
verbatim from the paper.

  mode 0: no application cache (OS page cache only)    γ₀ = 1
  mode 1: cache raw (uncompressed) shard arrays        γ₁ = 1 (paper: 2*)
  mode 2: cache zstd-1 blobs   (paper: snappy)         γ₂ = 2
  mode 3: cache zstd-3 blobs   (paper: zlib-1)         γ₃ = 4
  mode 4: cache zstd-9 blobs   (paper: zlib-3)         γ₄ = 5

(*the paper's γ₁=2 reflects that its disk format is CSV-ish while its cache
is binary; our disk format is already binary ELL, so γ₁=1. The selection
rule is unchanged.)

**Static** caches (``mode`` = an int) pick one of the five modes for the
whole cache lifetime — the paper's design, kept as the baseline.  The
default ``mode="auto"`` (alias ``"adaptive"``) is the **two-tier adaptive**
cache: the paper's rule becomes the *admission default*, not a lifetime
commitment.

  * **cold tier** — zstd blobs at the admission level (the rule's pick,
    floored at mode 2 so a first-touch shard always enters compressed);
  * **hot tier** — decompressed ``ELLShard`` arrays: a hit costs zero
    decode.  A shard is promoted cold→hot once it has been touched
    ``promote_after`` times (hubs and frontier-dense shards are touched
    every iteration; rarely-scheduled shards stay compressed or fall out);
    when the hot tier is full it may only displace a STRICTLY
    less-frequently-used resident, so equal-heat shards (a uniform
    PageRank sweep) never promote/demote ping-pong.
  * **budget** — one strict byte budget covers BOTH tiers
    (``hot_bytes + cold_bytes <= budget`` after every operation); the hot
    tier is additionally capped at ``hot_fraction * budget``.  Eviction
    cascades hot→cold→out: the hot LRU shard is *demoted* (re-compressed
    into the cold tier), the cold LRU blob falls out of the cache.
  * ``budget_bytes=0`` degrades to mode 0 (no application cache at all).

Every placement decision is a deterministic function of the ``get``
sequence, so results, hit/miss sequences and the Table-3 disk-byte
accounting are invariant to storage backend and prefetch depth (property
tests in tests/test_backends.py).

The cache sits on any ``ShardSource`` backend (npz directory, packed file,
in-memory — graph/source.py) and is **thread-safe**: the ShardPipeline calls
``get`` from a prefetch thread while stats are read from the main loop, so
every get/promotion/demotion/eviction and every ``CacheStats`` update
happens under one lock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
import zlib
from collections import OrderedDict

try:
    import zstandard
except ImportError:  # optional: compressed tiers fall back to stdlib zlib
    zstandard = None

from repro.core.shards import ELLShard
from repro.graph.source import ShardSource, pack_shard_npz, unpack_shard_npz

GAMMA = {0: 1.0, 1: 1.0, 2: 2.0, 3: 4.0, 4: 5.0}
ZSTD_LEVEL = {2: 1, 3: 3, 4: 9}
ZLIB_LEVEL = {2: 1, 3: 3, 4: 9}  # the paper's own codec, always available


def _make_codec(mode: int):
    """(compress, decompress) for a compressed mode: zstd, else zlib."""
    if zstandard is not None:
        cctx = zstandard.ZstdCompressor(level=ZSTD_LEVEL[mode])
        dctx = zstandard.ZstdDecompressor()
        return cctx.compress, dctx.decompress
    level = ZLIB_LEVEL[mode]
    return (lambda blob: zlib.compress(blob, level)), zlib.decompress

# canonical blob codecs, shared with the storage backends
_unpack = unpack_shard_npz
_pack = pack_shard_npz

ADAPTIVE_MODES = ("auto", "adaptive")


def auto_select_mode(graph_bytes: int, cache_budget_bytes: int) -> int:
    """Paper's rule: minimal i with S/γᵢ ≤ C; fall back to mode 4."""
    for i in range(5):
        if graph_bytes / GAMMA[i] <= cache_budget_bytes:
            return i
    return 4


@dataclasses.dataclass
class CacheStats:
    """Lifetime counters; mutate through ``bump`` (atomic under a lock).

    ``hits``/``misses``/``evictions`` keep their historic meaning (an
    eviction drops a shard out of the cache entirely).  The two-tier cache
    splits hits into ``hot_hits`` (decompressed array returned as-is, zero
    decode) and ``cold_hits`` (blob decompressed on the way out), and counts
    tier migrations: ``promotions`` (cold→hot) and ``demotions`` (hot→cold).
    ``decode_seconds_saved`` accumulates, on every hot hit, the measured
    decompress+unpack cost that hit did NOT pay — the hot tier's benefit in
    seconds (compare against ``decompress_seconds``, what the cold tier and
    a static compressed cache DO pay).
    """

    hits: int = 0
    misses: int = 0
    disk_bytes: int = 0
    decompress_seconds: float = 0.0
    compress_seconds: float = 0.0
    evictions: int = 0
    hot_hits: int = 0
    cold_hits: int = 0
    promotions: int = 0
    demotions: int = 0
    decode_seconds_saved: float = 0.0
    stale_drops: int = 0  # entries dropped because their shard's epoch moved

    def __post_init__(self):
        self._lock = threading.Lock()

    def bump(self, **deltas) -> None:
        with self._lock:
            for field, delta in deltas.items():
                setattr(self, field, getattr(self, field) + delta)

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CompressedShardCache:
    """Budget-enforced shard cache over a ShardSource: static or two-tier.

    Parameters
    ----------
    store:
        Any ``ShardSource`` backend; misses are charged to its byte counter
        at the shard's canonical nbytes (Table-3 accounting).
    mode:
        ``"auto"``/``"adaptive"`` (default) — the two-tier adaptive cache;
        an int 0-4 — the paper's static modes, kept as baselines.
    budget_bytes:
        Strict byte budget across both tiers; 0 degrades to mode 0.
    hot_fraction:
        Fraction of the budget the hot (decompressed) tier may occupy
        (adaptive only).
    promote_after:
        Accesses (including the admitting miss) after which a cold shard
        becomes a promotion candidate (adaptive only).
    """

    def __init__(self, store: ShardSource, mode: int | str = "auto",
                 budget_bytes: int = 1 << 30, *,
                 hot_fraction: float = 0.5, promote_after: int = 2):
        self.store = store
        self.budget = int(budget_bytes)
        if self.budget < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes!r}")
        if not 0.0 < hot_fraction <= 1.0:
            raise ValueError(
                f"hot_fraction must be in (0, 1], got {hot_fraction!r}")
        if promote_after < 1:
            raise ValueError(
                f"promote_after must be >= 1, got {promote_after!r}")
        self.hot_fraction = float(hot_fraction)
        self.promote_after = int(promote_after)
        self.adaptive = mode in ADAPTIVE_MODES
        if self.budget == 0:
            # a zero budget cannot hold anything: degrade to mode 0 (no
            # application cache) whatever policy was asked for
            self.adaptive = False
            mode = 0
        if self.adaptive:
            # the paper's rule picks the admission level; the floor at mode 2
            # means a first-touch shard always enters compressed (the hot
            # tier is earned by reuse, not granted on admission)
            rule = auto_select_mode(store.total_shard_bytes(), self.budget)
            mode = max(2, rule)
        if int(mode) in ZSTD_LEVEL and zstandard is None:
            warnings.warn(
                "zstandard is not installed; compressed cache modes use "
                "stdlib zlib (the paper's codec; slower than zstd)",
                RuntimeWarning, stacklevel=2)
        self.mode = int(mode)
        self.stats = CacheStats()
        # static tier (modes 1-4): one LRU of bytes-or-ELLShard entries
        self._lru: OrderedDict[int, bytes | ELLShard] = OrderedDict()
        self._bytes = 0
        # adaptive tiers: hot = decompressed shards, cold = zstd blobs,
        # plus per-shard lifetime access counts and measured decode costs
        self._hot: OrderedDict[int, ELLShard] = OrderedDict()
        self._cold: OrderedDict[int, bytes] = OrderedDict()
        self._hot_bytes = 0
        self._cold_bytes = 0
        self._freq: dict[int, int] = {}
        self._decode_cost: dict[int, float] = {}
        # epoch each resident entry was cached at: a mutable store bumps a
        # shard's epoch on commit, and `get` lazily drops ONLY that shard's
        # entry (clean shards keep their hot/cold placement across mutations)
        self._epoch_of: dict[int, int] = {}
        self._lock = threading.RLock()  # prefetch thread(s) + main loop
        self._compress, self._decompress = (
            _make_codec(self.mode) if self.mode in ZSTD_LEVEL
            else (None, None))

    # -- occupancy ------------------------------------------------------
    @property
    def hot_budget(self) -> int:
        """Byte cap of the hot tier (adaptive; static mode 1 IS a hot tier)."""
        if self.adaptive:
            return int(self.budget * self.hot_fraction)
        return self.budget if self.mode == 1 else 0

    @property
    def hot_bytes(self) -> int:
        if self.adaptive:
            return self._hot_bytes
        return self._bytes if self.mode == 1 else 0

    @property
    def cold_bytes(self) -> int:
        if self.adaptive:
            return self._cold_bytes
        return self._bytes if self.mode in ZSTD_LEVEL else 0

    @property
    def hot_shards(self) -> int:
        if self.adaptive:
            return len(self._hot)
        return len(self._lru) if self.mode == 1 else 0

    @property
    def cold_shards(self) -> int:
        if self.adaptive:
            return len(self._cold)
        return len(self._lru) if self.mode in ZSTD_LEVEL else 0

    @property
    def cached_bytes(self) -> int:
        return self._hot_bytes + self._cold_bytes if self.adaptive else self._bytes

    @property
    def cached_shards(self) -> int:
        return len(self._hot) + len(self._cold) if self.adaptive else len(self._lru)

    def shard_tier(self, shard_id: int) -> str:
        """'hot' | 'cold' | 'out' — where a shard currently lives."""
        with self._lock:
            if self.adaptive:
                if shard_id in self._hot:
                    return "hot"
                return "cold" if shard_id in self._cold else "out"
            if shard_id not in self._lru:
                return "out"
            return "hot" if isinstance(self._lru[shard_id], ELLShard) else "cold"

    def _entry_nbytes(self, entry) -> int:
        if isinstance(entry, bytes):
            return len(entry)
        return entry.decoded_nbytes()

    # -- adaptive internals (all callers hold self._lock) ---------------
    def _demote(self, shard_id: int, shard: ELLShard) -> None:
        """Hot LRU leaves the hot tier: re-compressed into the cold tier."""
        t = time.perf_counter()
        blob = self._compress(_pack(shard))
        self.stats.bump(compress_seconds=time.perf_counter() - t,
                        demotions=1)
        self._cold[shard_id] = blob  # most-recently-used end of the cold LRU
        self._cold_bytes += len(blob)

    def _enforce(self) -> None:
        """Restore both invariants by the hot→cold→out cascade."""
        hot_budget = self.hot_budget
        while self._hot_bytes > hot_budget and self._hot:
            sid, shard = self._hot.popitem(last=False)
            self._hot_bytes -= self._entry_nbytes(shard)
            self._demote(sid, shard)
        while self._hot_bytes + self._cold_bytes > self.budget and self._cold:
            sid, blob = self._cold.popitem(last=False)
            self._cold_bytes -= len(blob)
            self.stats.bump(evictions=1)

    def _should_promote(self, shard_id: int, shard: ELLShard) -> bool:
        if self._freq.get(shard_id, 0) < self.promote_after:
            return False
        need = self._entry_nbytes(shard)
        hot_budget = self.hot_budget
        if need > hot_budget:
            return False
        if self._hot_bytes + need <= hot_budget:
            return True
        # tier is full: displace only if strictly hotter than the coolest
        # resident (equal heat = no churn; PageRank's uniform sweeps settle)
        lru_id = next(iter(self._hot))
        return self._freq[shard_id] > self._freq.get(lru_id, 0)

    def _get_adaptive(self, shard_id: int) -> ELLShard:
        if shard_id in self._hot:
            shard = self._hot.pop(shard_id)
            self._hot[shard_id] = shard  # LRU bump
            self._freq[shard_id] = self._freq.get(shard_id, 0) + 1
            self.stats.bump(hits=1, hot_hits=1,
                            decode_seconds_saved=self._decode_cost.get(
                                shard_id, 0.0))
            return shard
        if shard_id in self._cold:
            blob = self._cold.pop(shard_id)
            self._freq[shard_id] = self._freq.get(shard_id, 0) + 1
            t = time.perf_counter()
            shard = _unpack(shard_id, self._decompress(blob))
            dt = time.perf_counter() - t
            self._decode_cost[shard_id] = dt
            self.stats.bump(hits=1, cold_hits=1, decompress_seconds=dt)
            if self._should_promote(shard_id, shard):
                self._cold_bytes -= len(blob)
                self._hot[shard_id] = shard
                self._hot_bytes += self._entry_nbytes(shard)
                self.stats.bump(promotions=1)
                self._enforce()
            else:
                self._cold[shard_id] = blob  # LRU bump, stays compressed
            return shard
        # miss: one canonical blob read serves decode AND admission
        self.stats.bump(misses=1,
                        disk_bytes=self.store.shard_nbytes(shard_id))
        self._freq[shard_id] = self._freq.get(shard_id, 0) + 1
        blob = self.store.read_shard_bytes(shard_id)
        shard = _unpack(shard_id, blob)
        t = time.perf_counter()
        centry = self._compress(blob)
        self.stats.bump(compress_seconds=time.perf_counter() - t)
        if len(centry) <= self.budget:
            self._cold[shard_id] = centry
            self._cold_bytes += len(centry)
            self._enforce()
        return shard

    # -- epoch-grained invalidation (mutable stores) ---------------------
    def _store_shard_epoch(self, shard_id: int) -> int:
        fn = getattr(self.store, "shard_epoch", None)
        return int(fn(shard_id)) if fn is not None else 0

    def _invalidate_locked(self, shard_id: int) -> bool:
        dropped = False
        entry = self._hot.pop(shard_id, None)
        if entry is not None:
            self._hot_bytes -= self._entry_nbytes(entry)
            dropped = True
        blob = self._cold.pop(shard_id, None)
        if blob is not None:
            self._cold_bytes -= len(blob)
            dropped = True
        entry = self._lru.pop(shard_id, None)
        if entry is not None:
            self._bytes -= self._entry_nbytes(entry)
            dropped = True
        if dropped:
            # not an `eviction` (those mean budget pressure): a stale drop
            self.stats.bump(stale_drops=1)
        return dropped

    def invalidate(self, shard_ids=None) -> int:
        """Eagerly drop the entries of ``shard_ids`` (default: every shard
        whose epoch moved since it was cached); returns the drop count.
        ``get`` does this lazily per shard, so calling this is optional."""
        with self._lock:
            if shard_ids is None:
                resident = set(self._hot) | set(self._cold) | set(self._lru)
                shard_ids = [p for p in resident
                             if self._store_shard_epoch(p)
                             != self._epoch_of.get(p, 0)]
            dropped = 0
            for p in shard_ids:
                if self._invalidate_locked(p):
                    dropped += 1
                self._epoch_of.pop(p, None)
            return dropped

    # -- the one public entry point -------------------------------------
    def get(self, shard_id: int) -> ELLShard:
        """Return a decoded shard, through whatever tier currently holds it.

        Thread-safe; every byte-accounting invariant
        (``cached_bytes <= budget``, and for the adaptive cache
        ``hot_bytes <= hot_fraction * budget``) holds on return.
        """
        with self._lock:
            cur = self._store_shard_epoch(shard_id)
            if cur != self._epoch_of.get(shard_id, 0):
                self._invalidate_locked(shard_id)
                self._epoch_of[shard_id] = cur
            if self.adaptive:
                return self._get_adaptive(shard_id)
            if self.mode == 0:
                self.stats.bump(misses=1,
                                disk_bytes=self.store.shard_nbytes(shard_id))
                return self.store.read_shard(shard_id)
            if shard_id in self._lru:
                entry = self._lru.pop(shard_id)
                self._lru[shard_id] = entry  # LRU bump
                if isinstance(entry, bytes):
                    t = time.perf_counter()
                    blob = self._decompress(entry)
                    self.stats.bump(hits=1, cold_hits=1,
                                    decompress_seconds=time.perf_counter() - t)
                    return _unpack(shard_id, blob)
                self.stats.bump(hits=1, hot_hits=1)
                return entry
            # miss: disk read, then insert if it fits
            self.stats.bump(misses=1,
                            disk_bytes=self.store.shard_nbytes(shard_id))
            if self.mode == 1:
                shard = self.store.read_shard(shard_id)
                entry: bytes | ELLShard = shard
            else:
                # compress the canonical blob straight off the backend — no
                # decode->re-encode round trip on the miss path
                blob = self.store.read_shard_bytes(shard_id)
                shard = _unpack(shard_id, blob)
                t = time.perf_counter()
                entry = self._compress(blob)
                self.stats.bump(compress_seconds=time.perf_counter() - t)
            need = self._entry_nbytes(entry)
            if need <= self.budget:
                self._evict_until(need)
                self._lru[shard_id] = entry
                self._bytes += need
            return shard

    def _evict_until(self, need: int) -> None:
        while self._bytes + need > self.budget and self._lru:
            _, old = self._lru.popitem(last=False)
            self._bytes -= self._entry_nbytes(old)
            self.stats.bump(evictions=1)

    # -- maintenance / observability -------------------------------------
    def clear(self) -> None:
        """Drop every cached entry and placement state (budget and stats
        are kept)."""
        with self._lock:
            self._lru.clear()
            self._bytes = 0
            self._hot.clear()
            self._cold.clear()
            self._hot_bytes = 0
            self._cold_bytes = 0
            self._freq.clear()
            self._epoch_of.clear()

    def audit(self) -> int:
        """Recount both tiers from scratch and assert the running byte
        counters match exactly; returns ``cached_bytes``.  Used by the
        concurrency tests after every operation — any drift between the
        counters and the actual entries is an accounting bug."""
        with self._lock:
            hot = sum(self._entry_nbytes(s) for s in self._hot.values())
            cold = sum(len(b) for b in self._cold.values())
            static = sum(self._entry_nbytes(e) for e in self._lru.values())
            assert hot == self._hot_bytes, (hot, self._hot_bytes)
            assert cold == self._cold_bytes, (cold, self._cold_bytes)
            assert static == self._bytes, (static, self._bytes)
            total = self.cached_bytes
            assert total <= self.budget, (total, self.budget)
            assert self.hot_bytes <= max(self.hot_budget, 0)
            return total

    def measured_ratio(self) -> float:
        """Achieved compression ratio over currently compressed entries."""
        with self._lock:
            if self.adaptive:
                if not self._cold:
                    return 1.0
                raw = sum(self.store.shard_nbytes(i) for i in self._cold)
                return raw / max(self._cold_bytes, 1)
            if self.mode in (0, 1) or not self._lru:
                return 1.0
            raw = sum(self.store.shard_nbytes(i) for i in self._lru)
            return raw / max(self._bytes, 1)

    def report(self) -> dict:
        """One self-describing snapshot of policy, occupancy and counters
        (what ``GraphSession.cache_report()`` returns)."""
        with self._lock:
            s = self.stats
            return {
                "policy": "adaptive" if self.adaptive else "static",
                "mode": self.mode,
                "budget_bytes": self.budget,
                "hot_budget_bytes": self.hot_budget,
                "hot_bytes": self.hot_bytes,
                "hot_shards": self.hot_shards,
                "cold_bytes": self.cold_bytes,
                "cold_shards": self.cold_shards,
                "cached_bytes": self.cached_bytes,
                "cached_shards": self.cached_shards,
                "hits": s.hits,
                "hot_hits": s.hot_hits,
                "cold_hits": s.cold_hits,
                "misses": s.misses,
                "hit_ratio": s.hit_ratio,
                "promotions": s.promotions,
                "demotions": s.demotions,
                "evictions": s.evictions,
                "stale_drops": s.stale_drops,
                "disk_bytes": s.disk_bytes,
                "decompress_seconds": s.decompress_seconds,
                "compress_seconds": s.compress_seconds,
                "decode_seconds_saved": s.decode_seconds_saved,
                "measured_ratio": self.measured_ratio(),
            }


# ---------------------------------------------------------------------------
class PartitionedShardCache:
    """Per-device slices of the edge cache under ONE global byte budget.

    The multi-device engine (``repro.core.distributed.ShardedVSWEngine``)
    splits the shard schedule across devices; each device's shards hash to
    its own ``CompressedShardCache`` partition (``owner[p]`` names the
    partition caching shard ``p``), so per-device prefetch lanes never
    contend on one LRU and the Table-3 disk-byte accounting splits honestly
    per device.  The partition budgets sum EXACTLY to the configured global
    budget (partition 0 absorbs the remainder), keeping the strict-budget
    invariant of the single cache.

    The facade keeps the single-cache surface (``get`` / ``stats`` /
    ``report`` / ``clear`` / ``audit`` / ``invalidate`` / ``cached_bytes``)
    so ``GraphSession`` observability and the serving layer work unchanged;
    ``stats`` aggregates the partition counters into one ``CacheStats``.
    """

    def __init__(self, store: ShardSource, owner, num_partitions: int,
                 mode: int | str = "auto", budget_bytes: int = 1 << 30,
                 hot_fraction: float = 0.5, promote_after: int = 2):
        import numpy as np
        self.store = store
        self.owner = np.asarray(owner, dtype=np.int64)
        self.num_partitions = int(num_partitions)
        if self.num_partitions < 1:
            raise ValueError(
                f"num_partitions must be >= 1, got {num_partitions!r}")
        if self.owner.size and int(self.owner.max()) >= self.num_partitions:
            raise ValueError(
                f"owner maps shards to partition {int(self.owner.max())}, "
                f"but only {self.num_partitions} partitions exist")
        per = budget_bytes // self.num_partitions
        budgets = ([budget_bytes - per * (self.num_partitions - 1)]
                   + [per] * (self.num_partitions - 1))
        self.parts = [
            CompressedShardCache(store, mode=mode, budget_bytes=b,
                                 hot_fraction=hot_fraction,
                                 promote_after=promote_after)
            for b in budgets
        ]

    def partition_for(self, shard_id: int) -> CompressedShardCache:
        return self.parts[int(self.owner[shard_id])]

    def get(self, shard_id: int) -> ELLShard:
        return self.partition_for(shard_id).get(shard_id)

    def invalidate(self, shard_ids=None) -> int:
        return sum(p.invalidate(shard_ids) for p in self.parts)

    # -- aggregated observability (single-cache surface) ----------------
    @property
    def mode(self):
        return self.parts[0].mode

    @property
    def adaptive(self) -> bool:
        return self.parts[0].adaptive

    @property
    def budget(self) -> int:
        return sum(p.budget for p in self.parts)

    @property
    def cached_bytes(self) -> int:
        return sum(p.cached_bytes for p in self.parts)

    @property
    def cached_shards(self) -> int:
        return sum(p.cached_shards for p in self.parts)

    @property
    def stats(self) -> CacheStats:
        """Fresh aggregate of every partition's counters (the partitions
        keep their own live ``CacheStats``; mutate those, not this)."""
        agg = CacheStats()
        for part in self.parts:
            s = part.stats
            for f in dataclasses.fields(CacheStats):
                setattr(agg, f.name, getattr(agg, f.name) + getattr(s, f.name))
        return agg

    def clear(self) -> None:
        for p in self.parts:
            p.clear()

    def audit(self) -> int:
        return sum(p.audit() for p in self.parts)

    def report(self) -> dict:
        """Aggregate + per-partition snapshot (``partitions`` holds one
        ordinary cache report per device slice)."""
        s = self.stats
        return {
            "policy": "partitioned",
            "num_partitions": self.num_partitions,
            "mode": self.mode,
            "budget_bytes": self.budget,
            "cached_bytes": self.cached_bytes,
            "cached_shards": self.cached_shards,
            "hits": s.hits,
            "misses": s.misses,
            "hit_ratio": s.hit_ratio,
            "evictions": s.evictions,
            "stale_drops": s.stale_drops,
            "disk_bytes": s.disk_bytes,
            "decompress_seconds": s.decompress_seconds,
            "decode_seconds_saved": s.decode_seconds_saved,
            "partitions": [p.report() for p in self.parts],
        }
