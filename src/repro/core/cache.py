"""Compressed edge cache (paper §2.4.2), modes 0-4 with auto-selection.

Spare host memory caches shard blobs; decompression throughput beats disk.
snappy/zlib-1/zlib-3 from the paper map onto zstd levels 1/3/9 (zstandard is
the compressor available in this container — DESIGN.md §8.2); the mode
semantics, γ table and auto-selection rule `min i s.t. S/γᵢ ≤ C` are kept
verbatim from the paper.

  mode 0: no application cache (OS page cache only)    γ₀ = 1
  mode 1: cache raw (uncompressed) shard arrays        γ₁ = 1 (paper: 2*)
  mode 2: cache zstd-1 blobs   (paper: snappy)         γ₂ = 2
  mode 3: cache zstd-3 blobs   (paper: zlib-1)         γ₃ = 4
  mode 4: cache zstd-9 blobs   (paper: zlib-3)         γ₄ = 5

(*the paper's γ₁=2 reflects that its disk format is CSV-ish while its cache
is binary; our disk format is already binary ELL, so γ₁=1. The selection
rule is unchanged.)
"""
from __future__ import annotations

import dataclasses
import io as _io
import time
import warnings
from collections import OrderedDict

import numpy as np

try:
    import zstandard
except ImportError:  # optional: modes 2-4 degrade to raw caching (mode 1)
    zstandard = None

from repro.core.shards import ELLShard
from repro.graph.storage import GraphStore

GAMMA = {0: 1.0, 1: 1.0, 2: 2.0, 3: 4.0, 4: 5.0}
ZSTD_LEVEL = {2: 1, 3: 3, 4: 9}


def auto_select_mode(graph_bytes: int, cache_budget_bytes: int) -> int:
    """Paper's rule: minimal i with S/γᵢ ≤ C; fall back to mode 4."""
    for i in range(5):
        if graph_bytes / GAMMA[i] <= cache_budget_bytes:
            return i
    return 4


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    disk_bytes: int = 0
    decompress_seconds: float = 0.0
    compress_seconds: float = 0.0
    evictions: int = 0

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def _pack(shard: ELLShard) -> bytes:
    buf = _io.BytesIO()
    mask = shard.cols >= 0
    unit = bool(np.array_equal(shard.vals, mask.astype(np.float32)))
    payload = dict(
        cols=shard.cols,
        row_map=shard.row_map,
        meta=np.array([shard.start_vertex, shard.end_vertex, shard.nnz,
                       int(unit)], dtype=np.int64),
    )
    if not unit:
        payload["vals"] = shard.vals
    np.savez(buf, **payload)
    return buf.getvalue()


def _unpack(shard_id: int, blob: bytes) -> ELLShard:
    with np.load(_io.BytesIO(blob)) as z:
        meta = z["meta"]
        cols = z["cols"]
        unit = len(meta) > 3 and bool(meta[3])
        vals = (cols >= 0).astype(np.float32) if unit else z["vals"]
        return ELLShard(
            shard_id=shard_id,
            start_vertex=int(meta[0]),
            end_vertex=int(meta[1]),
            nnz=int(meta[2]),
            cols=cols,
            vals=vals,
            row_map=z["row_map"],
        )


class CompressedShardCache:
    """LRU cache over shard blobs with byte budget; wraps a GraphStore."""

    def __init__(self, store: GraphStore, mode: int | str = "auto",
                 budget_bytes: int = 1 << 30):
        self.store = store
        self.budget = int(budget_bytes)
        if mode == "auto":
            mode = auto_select_mode(store.total_shard_bytes(), self.budget)
        if int(mode) in ZSTD_LEVEL and zstandard is None:
            warnings.warn(
                f"zstandard is not installed; cache mode {int(mode)} needs it "
                "— falling back to mode 1 (raw shard caching)",
                RuntimeWarning, stacklevel=2)
            mode = 1
        self.mode = int(mode)
        self.stats = CacheStats()
        self._lru: OrderedDict[int, bytes | ELLShard] = OrderedDict()
        self._bytes = 0
        self._cctx = (
            zstandard.ZstdCompressor(level=ZSTD_LEVEL[self.mode])
            if self.mode in ZSTD_LEVEL else None
        )
        self._dctx = zstandard.ZstdDecompressor() if self.mode in ZSTD_LEVEL else None

    @property
    def cached_bytes(self) -> int:
        return self._bytes

    @property
    def cached_shards(self) -> int:
        return len(self._lru)

    def _entry_nbytes(self, entry) -> int:
        if isinstance(entry, bytes):
            return len(entry)
        return entry.padded_bytes() + entry.row_map.nbytes

    def _evict_until(self, need: int) -> None:
        while self._bytes + need > self.budget and self._lru:
            _, old = self._lru.popitem(last=False)
            self._bytes -= self._entry_nbytes(old)
            self.stats.evictions += 1

    def get(self, shard_id: int) -> ELLShard:
        if self.mode == 0:
            self.stats.misses += 1
            self.stats.disk_bytes += self.store.shard_nbytes(shard_id)
            return self.store.read_shard(shard_id)
        if shard_id in self._lru:
            self.stats.hits += 1
            entry = self._lru.pop(shard_id)
            self._lru[shard_id] = entry  # LRU bump
            if isinstance(entry, bytes):
                t = time.perf_counter()
                blob = self._dctx.decompress(entry)
                self.stats.decompress_seconds += time.perf_counter() - t
                return _unpack(shard_id, blob)
            return entry
        # miss: disk read, then insert if it fits
        self.stats.misses += 1
        self.stats.disk_bytes += self.store.shard_nbytes(shard_id)
        shard = self.store.read_shard(shard_id)
        if self.mode == 1:
            entry: bytes | ELLShard = shard
        else:
            t = time.perf_counter()
            entry = self._cctx.compress(_pack(shard))
            self.stats.compress_seconds += time.perf_counter() - t
        need = self._entry_nbytes(entry)
        if need <= self.budget:
            self._evict_until(need)
            self._lru[shard_id] = entry
            self._bytes += need
        return shard

    def clear(self) -> None:
        """Drop every cached entry (budget and stats are kept)."""
        self._lru.clear()
        self._bytes = 0

    def measured_ratio(self) -> float:
        """Achieved compression ratio over currently cached shards."""
        if self.mode in (0, 1) or not self._lru:
            return 1.0
        raw = sum(self.store.shard_nbytes(i) for i in self._lru)
        return raw / max(self._bytes, 1)
