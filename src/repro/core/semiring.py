"""Semirings for vertex-centric pull-mode updates (Algorithm 3, vectorized).

A GraphMP ``Update`` function factors into three pieces:

  partial[v] = REDUCE_{(u,v) in shard}  COMBINE(edge_val(u,v), src[u])
  dst[v]     = POST(partial[v], old[v], aux)

PageRank : REDUCE=+,   COMBINE=(w, s) -> s            POST = 0.15/n + 0.85*p
SSSP     : REDUCE=min, COMBINE=(w, s) -> s + w        POST = min(p, old)
CC       : REDUCE=min, COMBINE=(w, s) -> s            POST = min(p, old)
BFS      : REDUCE=min, COMBINE=(w, s) -> s + 1        POST = min(p, old)
LP       : REDUCE=max, COMBINE=(w, s) -> s            POST = max(p, old)

The semiring is the device-side contract shared by the pure-jnp reference
(`kernels/spmv/ref.py`), the Pallas kernels (`kernels/spmv/spmv.py`) and the
VSW engine.  ``identity`` is the REDUCE identity and is what padded (sentinel)
ELL slots must contribute.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    # reduce(a, b) -> elementwise monoid used to fold the ELL width dim
    reduce: Callable[[Array, Array], Array]
    # combine(edge_val, src_val) -> contribution of one edge
    combine: Callable[[Array, Array], Array]
    # identity element of `reduce` (what masked slots contribute)
    identity: float
    # whether `reduce` is `+` (enables the one-hot MXU SpMV variant)
    is_plus: bool = False
    # whether `reduce` is `max` (the non-plus default is `min`, the
    # propagation direction of sssp/bfs/cc; label propagation flips it)
    is_max: bool = False

    def fold(self, edge_vals: Array, src_vals: Array, mask: Array, axis: int = -1) -> Array:
        """Reduce COMBINE(edge, src) over `axis`, treating ~mask as identity."""
        contrib = self.combine(edge_vals, src_vals)
        contrib = jnp.where(mask, contrib, jnp.asarray(self.identity, contrib.dtype))
        if self.is_plus:
            return jnp.sum(contrib, axis=axis)
        if self.is_max:
            return jnp.max(contrib, axis=axis)
        return jnp.min(contrib, axis=axis)

    def fold_batch(self, edge_vals: Array, src_vals: Array, mask: Array) -> Array:
        """Batched fold: one edge pass serves K value columns.

        edge_vals/mask are [R, W] (shared by every column); src_vals carries a
        trailing batch axis [R, W, K].  Reduces the ELL width dim -> [R, K].
        All four semirings broadcast: COMBINE sees edge [R, W, 1] against
        source [R, W, K], so the edge data is read once however large K is.
        """
        return self.fold(edge_vals[..., None], src_vals, mask[..., None], axis=1)


PLUS_TIMES = Semiring(
    name="plus_times",
    reduce=jnp.add,
    combine=lambda w, s: w * s,
    identity=0.0,
    is_plus=True,
)

# PageRank pulls src/out_deg along in-edges; the division is folded into the
# gather-transform, so on the shard the combine is just "take the source".
PLUS_SRC = Semiring(
    name="plus_src",
    reduce=jnp.add,
    combine=lambda w, s: s,
    identity=0.0,
    is_plus=True,
)

MIN_PLUS = Semiring(
    name="min_plus",
    reduce=jnp.minimum,
    combine=lambda w, s: w + s,
    identity=float("inf"),
)

MIN_SRC = Semiring(
    name="min_src",
    reduce=jnp.minimum,
    combine=lambda w, s: s,
    identity=float("inf"),
)

# Label propagation pulls the neighbor's label and keeps the largest; -inf is
# the identity so sentinel ELL slots (and vertices with no in-edges) never win.
MAX_SRC = Semiring(
    name="max_src",
    reduce=jnp.maximum,
    combine=lambda w, s: s,
    identity=float("-inf"),
    is_max=True,
)

SEMIRINGS = {s.name: s for s in (PLUS_TIMES, PLUS_SRC, MIN_PLUS, MIN_SRC, MAX_SRC)}
