"""ShardPipeline: overlap shard fetch/decompress/staging with compute.

GraphMP's thesis is hiding disk behind compute (paper §2.3; NXgraph and
GraphH stream shards the same way).  The engine used to fetch every shard
synchronously inside the iteration loop, serializing disk reads, npz
parsing, cache decompression, and host->device staging with the Pallas
SpMV.  The pipeline moves all of that onto ONE background thread feeding a
bounded queue:

    worker:  fetch(p) -> stage(shard) -> queue.put        (depth items ahead)
    main  :  queue.get -> SpMV on the previous result

``prefetch_depth`` is the queue bound — 1 is classic double buffering, 0 is
the old synchronous path (same code path, no thread).  A SINGLE worker
fetching in schedule order is deliberate: cache accesses happen in exactly
the order the synchronous path would issue them, so hit/miss/eviction
sequences — and therefore the Table-3 disk-byte accounting — are bit-for-bit
identical at every depth.  (Multi-device engines keep that property per
device: ``ShardedVSWEngine`` runs one pipeline instance — a prefetch LANE —
per device over that device's slice of the schedule, each feeding its own
cache partition, with per-lane ``stats`` summing to the engine aggregates.)

``stats`` separates the two sides of the overlap: ``stall_seconds`` is time
the consumer spent blocked waiting on the queue (what prefetch is supposed
to drive to zero) and ``fetch_seconds`` is background time spent producing
shards (what it hides).

Memory interplay with the two-tier cache (core/cache.py): the worker's
``fetch`` is ``cache.get``, which may promote/demote/evict — every such
transition and its byte accounting happens inside the cache's lock, so
staging never races a promotion and the cache budget holds at every depth.
The pipeline itself holds up to ``depth`` staged shards in flight on top of
the cache; that host memory is charged to ``stats.staged_bytes`` (current)
and ``stats.staged_peak_bytes`` (high-water), bounded by
``depth × max shard bytes``.  It is deliberately NOT charged against the
cache budget: doing so would make eviction sequences — and therefore the
Table-3 disk-byte accounting — depend on prefetch depth, breaking the
bit-for-bit invariance contract above.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterator, Sequence

from repro.core.shards import ELLShard

_DONE = object()


@dataclasses.dataclass
class PipelineStats:
    """Producer/consumer accounting.

    ``shards``/``stall_seconds``/``fetch_seconds`` are lifetime
    accumulators; ``staged_bytes`` is the host bytes of shards currently
    staged but not yet consumed (bounded by depth × max shard bytes) and
    ``staged_peak_bytes`` its lifetime high-water mark.
    """

    shards: int = 0           # shards delivered to the consumer
    stall_seconds: float = 0.0  # consumer time blocked on the queue
    fetch_seconds: float = 0.0  # producer time fetching + staging
    staged_bytes: int = 0       # staged-but-unconsumed host bytes (in flight)
    staged_peak_bytes: int = 0  # lifetime high-water mark of staged_bytes


@dataclasses.dataclass
class _Failure:
    exc: BaseException


class ShardPipeline:
    """Streams ``(shard_id, shard, staged)`` for a schedule, ``depth`` ahead.

    ``fetch``: shard_id -> ELLShard (typically ``cache.get``; must be safe to
    call from one background thread — the CompressedShardCache does every
    tier transition, including promotions, under its own lock).
    ``stage``: optional ELLShard -> anything; runs on the worker too, so
    host->device transfers land off the critical path.  With ``depth == 0``
    both run inline on the consumer thread (the synchronous path).
    ``nbytes``: optional ELLShard -> int used to charge staged-but-unconsumed
    shards to ``stats.staged_bytes`` (the pipeline's own memory footprint on
    top of the cache budget).
    """

    def __init__(self, fetch: Callable[[int], ELLShard], depth: int = 0,
                 stage: Callable[[ELLShard], Any] | None = None,
                 nbytes: Callable[[ELLShard], int] | None = None):
        if depth < 0:
            raise ValueError(f"prefetch depth must be >= 0, got {depth}")
        self.fetch = fetch
        self.stage = stage
        self.nbytes = nbytes
        self.depth = int(depth)
        self.stats = PipelineStats()
        self._stats_lock = threading.Lock()  # producer + consumer both charge

    def _charge(self, n: int) -> None:
        with self._stats_lock:
            self.stats.staged_bytes += n
            self.stats.staged_peak_bytes = max(self.stats.staged_peak_bytes,
                                               self.stats.staged_bytes)

    def _produce(self, p: int,
                 check: Callable[[int], None] | None) -> tuple[int, ELLShard, Any, int]:
        t0 = time.perf_counter()
        if check is not None:
            check(p)  # epoch pin: refuse to stage a shard from a newer epoch
        shard = self.fetch(p)
        staged = self.stage(shard) if self.stage is not None else None
        held = self.nbytes(shard) if self.nbytes is not None else 0
        self._charge(held)
        self.stats.fetch_seconds += time.perf_counter() - t0
        return p, shard, staged, held

    def stream(self, schedule: Sequence[int],
               check: Callable[[int], None] | None = None,
               ) -> Iterator[tuple[int, ELLShard, Any]]:
        """Yield every shard of ``schedule`` in order, prefetching ahead.

        ``check`` (optional) runs on the producer immediately before each
        fetch; the engine passes its epoch-pin assertion so a mid-run graph
        mutation raises ``ConcurrentMutationError`` instead of silently
        staging a shard from a newer epoch into an older run.
        """
        # a single-shard schedule has nothing to overlap with — skip the
        # worker thread (same order, same accounting, no spawn cost)
        if self.depth == 0 or len(schedule) < 2:
            for p in schedule:
                t0 = time.perf_counter()
                pid, shard, staged, held = self._produce(p, check)
                # synchronous path: the consumer IS stalled for the whole fetch
                self.stats.stall_seconds += time.perf_counter() - t0
                self.stats.shards += 1
                self._charge(-held)  # delivered: no longer in flight
                yield pid, shard, staged
            return

        q: queue.Queue = queue.Queue(maxsize=self.depth)
        cancel = threading.Event()

        def worker() -> None:
            try:
                for p in schedule:
                    if cancel.is_set():
                        return
                    q.put(self._produce(p, check))
                q.put(_DONE)
            except BaseException as exc:  # noqa: BLE001 — forwarded, re-raised
                q.put(_Failure(exc))

        t = threading.Thread(target=worker, name="shard-prefetch", daemon=True)
        t.start()
        try:
            while True:
                t0 = time.perf_counter()
                item = q.get()
                self.stats.stall_seconds += time.perf_counter() - t0
                if item is _DONE:
                    return
                if isinstance(item, _Failure):
                    raise item.exc
                pid, shard, staged, held = item
                self.stats.shards += 1
                self._charge(-held)  # delivered: no longer in flight
                yield pid, shard, staged
        finally:
            cancel.set()
            # unblock a worker parked on q.put, then reap it; de-charge
            # drained items so staged_bytes never counts abandoned shards
            while t.is_alive():
                try:
                    item = q.get_nowait()
                    if isinstance(item, tuple):
                        self._charge(-item[3])
                except queue.Empty:
                    pass
                t.join(timeout=0.05)
            # the worker may have completed one last q.put between the drain
            # and its cancel check — sweep whatever is still queued
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, tuple):
                    self._charge(-item[3])
