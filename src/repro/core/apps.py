"""Graph applications as Init/Update vertex programs (paper Algorithm 3).

Each program is the vectorized form of the paper's per-vertex ``Init`` /
``Update`` pair, factored as (semiring, gather_transform, post, changed) —
see core/semiring.py.  All callables are jnp-pure so the engine can close a
jitted shard step over them.

Programs register themselves with ``@register_app`` so ``GraphSession.run``
(and anything else) can dispatch by name; downstream packages add workloads
the same way without touching this module.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# name -> factory(**kwargs) -> VertexProgram.  Exposed read-only through
# get_app()/available_apps(); APPS below is the same dict kept as a
# backward-compatible alias.
_REGISTRY: dict[str, Callable[..., "VertexProgram"]] = {}
# names whose fixpoints survive monotone graph growth (see is_incremental)
_INCREMENTAL: set[str] = set()


def register_app(name_or_factory=None, *, name: str | None = None,
                 incremental: bool = False):
    """Register a VertexProgram factory under a name.

    Usable bare (``@register_app``, name taken from the function) or with an
    explicit name (``@register_app("pr")``/``@register_app(name="pr")``).
    Re-registering a name overwrites it (latest wins), so tests can shadow.

    The factory's keyword arguments become the application's dispatch
    arguments: after ::

        @register_app("my_walk")
        def my_walk(source: int = 0) -> VertexProgram: ...

    ``GraphSession.run("my_walk", source=3)`` instantiates and runs it; it
    also shows up in ``available_apps()`` and works with ``run_many``.
    Factories returning a ``BatchedVertexProgram`` are dispatched the same
    way through ``GraphSession.run_batch``.

    ``incremental=True`` declares the app safe for incremental recompute
    after a *monotone* delta (insert-only / weight-non-increasing): its
    update is a min-propagation whose previous fixpoint stays a valid upper
    bound, so ``session.run_incremental`` may seed from it instead of
    rerunning cold.  Apps whose values can move in either direction
    (PageRank) must leave it False — they always fall back to a full run.
    """
    if isinstance(name_or_factory, str):
        name = name_or_factory

    def deco(factory):
        final = name or factory.__name__
        _REGISTRY[final] = factory
        if incremental:
            _INCREMENTAL.add(final)
        else:
            _INCREMENTAL.discard(final)  # an overwrite drops the old claim
        return factory

    if callable(name_or_factory):
        return deco(name_or_factory)
    return deco


def is_incremental(name: str) -> bool:
    """True iff ``name`` was registered with ``incremental=True``."""
    return name in _INCREMENTAL


def get_app(name: str, **kwargs) -> "VertexProgram":
    """Instantiate a registered program; kwargs go to its factory."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown graph application {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None
    return factory(**kwargs)


def available_apps() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    semiring: str
    value_dtype: np.dtype
    # (n, in_deg, out_deg) -> (values [n], active [n] bool)   (host-side, Algorithm 3 Init)
    init: Callable[[int, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]
    # (values, out_deg) -> x pulled along in-edges               (device)
    gather_transform: Callable[[Array, Array], Array]
    # (partial, old, num_vertices) -> new                         (device)
    post: Callable[[Array, Array, int], Array]
    # (new, old) -> bool mask of updated vertices                 (device)
    changed: Callable[[Array, Array], Array]
    # identity the engine substitutes for intervals with no processed edges
    needs_all_edges: bool = False  # True => every vertex recomputed each iter (PR)
    # frontier vertex ids this program was built for (() if source-free);
    # checkpoints record them so resume can reject a different run's state
    sources: tuple = ()
    # batch-compatibility token: two programs with EQUAL jit_signature are
    # guaranteed to have identical device callables (gather_transform / post /
    # changed and semiring), differing only in host-side init/sources.  The
    # engine cache keys on it, so e.g. sssp(source=5) and sssp(source=7)
    # share one engine and its jitted shard steps instead of recompiling per
    # source — the property the serving layer's dynamic batching relies on.
    # None => no sharing claim (engines keyed by program identity/name).
    # CONTRACT for dataclasses.replace(): the signature is inherited, so
    # overriding any device callable (gather_transform/post/changed) MUST
    # also replace jit_signature (or set it to None) — keeping the old one
    # silently serves the old compiled functions.  Renaming alone is fine
    # (bfs = sssp renamed shares sssp's engine deliberately).
    jit_signature: tuple | None = None


@register_app
def pagerank(damping: float = 0.85, tol: float = 1e-6) -> VertexProgram:
    """tol is RELATIVE (|Δ| > tol·|old|): the paper's Fig 7a shows PR active
    ratio under 0.1% by ~iteration 110 — absolute epsilons can't reproduce
    that across graph sizes, a relative one does."""
    def init(n, in_deg, out_deg):
        v = np.full(n, 1.0 / n, dtype=np.float32)
        return v, np.ones(n, dtype=bool)  # all vertices active (Alg 3 l.5)

    def gather(values, out_deg):
        return values / jnp.maximum(out_deg, 1).astype(values.dtype)

    def post(partial, old, n):
        return (1.0 - damping) / n + damping * partial

    return VertexProgram(
        name="pagerank",
        semiring="plus_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=gather,
        post=post,
        changed=lambda new, old: jnp.abs(new - old) > tol * jnp.abs(old) + 1e-30,
        needs_all_edges=True,
        jit_signature=("pagerank", float(damping), float(tol)),
    )


_INF = np.float32(np.inf)


@register_app(incremental=True)
def sssp(source: int = 0) -> VertexProgram:
    def init(n, in_deg, out_deg):
        v = np.full(n, _INF, dtype=np.float32)
        v[source] = 0.0
        active = np.zeros(n, dtype=bool)
        active[source] = True  # only the source starts active (Alg 3 l.19)
        return v, active

    return VertexProgram(
        name="sssp",
        semiring="min_plus",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, n: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        sources=(source,),
        # source only affects init: every SSSP/BFS query shares one engine
        jit_signature=("sssp",),
    )


@register_app(incremental=True)
def bfs(source: int = 0) -> VertexProgram:
    """Hop distance = SSSP with unit edge weights (vals are 1.0 in ELL)."""
    p = sssp(source)
    return dataclasses.replace(p, name="bfs")


@register_app(incremental=True)
def cc() -> VertexProgram:
    def init(n, in_deg, out_deg):
        v = np.arange(n, dtype=np.float32)  # subgraph id := vertex id (Alg 3 l.29)
        return v, np.ones(n, dtype=bool)

    return VertexProgram(
        name="cc",
        semiring="min_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, n: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        jit_signature=("cc",),
    )


# ---------------------------------------------------------------------------
# Batched multi-source programs: one VSW sweep serves K frontiers
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BatchedVertexProgram:
    """K independent frontiers sharing one edge sweep (paper §2.2 economics,
    amortized across *queries* instead of applications).

    Values are [n, K] matrices; column k is exactly the single-source program
    for source k.  ``post`` additionally receives the *global* destination
    row ids of its slice, plus a slice of the optional ``make_aux`` matrix.

    ``make_aux`` carries per-column CONSTANTS (personalized PageRank's
    scaled seed one-hot) into the jitted shard step as a runtime [n, K]
    array rather than a baked-in closure constant: the compiled step is
    then identical across source/seed sets, so ``jit_signature`` need not
    include them and a serving workload streaming distinct seed sets at the
    same K reuses ONE compiled engine instead of recompiling per request.
    """

    name: str
    semiring: str
    value_dtype: np.dtype
    columns: int  # K, static: the jitted shard step specializes per K
    # (n, in_deg, out_deg) -> (values [n, K], active [n, K] bool)
    init: Callable[[int, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]
    # (values [n_pad, K], out_deg [n_pad]) -> x pulled along in-edges
    gather_transform: Callable[[Array, Array], Array]
    # (partial [R, K], old [R, K], rows [R] global ids, num_vertices,
    #  aux [R, K] slice of make_aux(n) or None) -> new
    post: Callable[[Array, Array, Array, int, Array | None], Array]
    # (new [n, K], old [n, K]) -> bool mask of updated (vertex, column) pairs
    changed: Callable[[Array, Array], Array]
    # the K frontier vertex ids, column order; checkpoints record them so
    # resume rejects state from a different landmark/seed set
    sources: tuple = ()
    # batch-compatibility token — see VertexProgram.jit_signature.  Batched
    # signatures include K (the jitted [n, K] shard step specializes on it)
    # but usually NOT the sources, so a serving layer answering a stream of
    # distinct landmark sets at the same K reuses one compiled engine.
    jit_signature: tuple | None = None
    # optional n -> [n, K] float32 constants delivered to post as a runtime
    # argument (sliced per shard); None => post receives aux=None
    make_aux: Callable[[int], np.ndarray] | None = None


def _check_sources(sources) -> tuple[int, ...]:
    sources = tuple(int(s) for s in sources)
    if not sources:
        raise ValueError("need at least one source vertex")
    if any(s < 0 for s in sources):
        # negative ids would wrap under numpy indexing and silently compute
        # a plausible-looking column for vertex n+s
        raise ValueError(f"source vertex ids must be >= 0, got {sources}")
    return sources


@register_app
def sssp_multi(sources=(0,)) -> BatchedVertexProgram:
    """K single-source shortest-path queries in one engine run."""
    sources = _check_sources(sources)
    K = len(sources)

    def init(n, in_deg, out_deg):
        v = np.full((n, K), _INF, dtype=np.float32)
        active = np.zeros((n, K), dtype=bool)
        for k, s in enumerate(sources):
            v[s, k] = 0.0
            active[s, k] = True  # each column starts at its own source
        return v, active

    return BatchedVertexProgram(
        name="sssp_multi",
        semiring="min_plus",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, rows, n, aux: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        sources=sources,
        # only K shapes the jitted [n, K] step — landmark sets share engines
        jit_signature=("sssp_multi", K),
    )


@register_app
def bfs_multi(sources=(0,)) -> BatchedVertexProgram:
    """K hop-distance queries (SSSP over unit edge weights)."""
    p = sssp_multi(sources)
    return dataclasses.replace(p, name="bfs_multi")


@register_app
def personalized_pagerank(seeds=(0,), damping: float = 0.85,
                          tol: float = 1e-6) -> BatchedVertexProgram:
    """K personalized-PageRank columns: pr_k = (1-d)·e_seed_k + d·Aᵀpr_k.

    The reset vector differs per column; it rides into the jitted shard
    step as the ``make_aux`` runtime constant (the [n, K] scaled seed
    one-hot), NOT as a closure constant — so every seed set of the same K
    shares one compiled engine (see ``BatchedVertexProgram.make_aux``).
    Same relative-tol convergence rule as the global ``pagerank``.
    """
    seeds = _check_sources(seeds)
    K = len(seeds)
    seeds_np = np.asarray(seeds, dtype=np.int64)

    def init(n, in_deg, out_deg):
        v = np.zeros((n, K), dtype=np.float32)
        v[seeds_np, np.arange(K)] = 1.0  # all mass starts on the seed
        return v, np.ones((n, K), dtype=bool)

    def gather(values, out_deg):
        return values / jnp.maximum(out_deg, 1).astype(values.dtype)[:, None]

    def make_aux(n):
        reset = np.zeros((n, K), dtype=np.float32)
        reset[seeds_np, np.arange(K)] = 1.0 - damping
        return reset

    return BatchedVertexProgram(
        name="personalized_pagerank",
        semiring="plus_src",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=gather,
        post=lambda partial, old, rows, n, aux: aux + damping * partial,
        changed=lambda new, old: jnp.abs(new - old) > tol * jnp.abs(old) + 1e-30,
        sources=seeds,
        jit_signature=("personalized_pagerank", K, float(damping), float(tol)),
        make_aux=make_aux,
    )


# ---------------------------------------------------------------------------
# Batch-compatibility metadata: which single-query apps coalesce, and how
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """How K independent single-source queries of one app become one
    ``run_batch`` call.  The serving layer (repro/serve/graph_service.py)
    coalesces pending requests whose ``BatchSpec`` AND non-source parameters
    agree into one [n, K] micro-batch; ``family`` names the compatibility
    class (same batched factory + same semiring => same sweep can serve
    them)."""

    family: str        # compatibility class, e.g. "min_plus/sssp_multi"
    batched_app: str   # registered factory answering K queries at once
    source_param: str  # the single-query frontier kwarg ("source" / "seed")
    batch_param: str   # the batched factory's K-tuple kwarg ("sources"/"seeds")
    semiring: str      # shared semiring (informational; part of the family)
    exact: bool = True  # column k bitwise-equals the solo run (min-propagation
    #                     semirings; False for float-accumulating ones)


_BATCH_SPECS: dict[str, BatchSpec] = {}


def register_batchable(name: str, spec: BatchSpec) -> None:
    """Declare that single-query app ``name`` coalesces per ``spec``."""
    _BATCH_SPECS[name] = spec


def batch_spec(name: str) -> BatchSpec | None:
    """The BatchSpec for a single-query app name (None = not batchable)."""
    return _BATCH_SPECS.get(name)


register_batchable("sssp", BatchSpec(
    family="min_plus/sssp_multi", batched_app="sssp_multi",
    source_param="source", batch_param="sources", semiring="min_plus"))
register_batchable("bfs", BatchSpec(
    family="min_plus/bfs_multi", batched_app="bfs_multi",
    source_param="source", batch_param="sources", semiring="min_plus"))
# "ppr" has no solo VertexProgram (the seed reset needs the batched post's
# row ids) — a K=1 micro-batch IS its solo form.  plus_src accumulates
# floats, so coalesced columns match solo K=1 runs to tolerance, not bitwise.
register_batchable("ppr", BatchSpec(
    family="plus_src/personalized_pagerank", batched_app="personalized_pagerank",
    source_param="seed", batch_param="seeds", semiring="plus_src", exact=False))


# Deprecated alias: the live registry itself (mutations via register_app
# are visible here and vice versa).  Prefer get_app()/register_app.
APPS = _REGISTRY
