"""Graph applications as Init/Update vertex programs (paper Algorithm 3).

Each program is the vectorized form of the paper's per-vertex ``Init`` /
``Update`` pair, factored as (semiring, gather_transform, post, changed) —
see core/semiring.py.  All callables are jnp-pure so the engine can close a
jitted shard step over them.

Programs register themselves with ``@register_app`` so ``GraphSession.run``
(and anything else) can dispatch by name; downstream packages add workloads
the same way without touching this module.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# name -> factory(**kwargs) -> VertexProgram.  Exposed read-only through
# get_app()/available_apps(); APPS below is the same dict kept as a
# backward-compatible alias.
_REGISTRY: dict[str, Callable[..., "VertexProgram"]] = {}
# names whose fixpoints survive monotone graph growth (see is_incremental)
_INCREMENTAL: set[str] = set()


def register_app(name_or_factory=None, *, name: str | None = None,
                 incremental: bool = False):
    """Register a VertexProgram factory under a name.

    Usable bare (``@register_app``, name taken from the function) or with an
    explicit name (``@register_app("pr")``/``@register_app(name="pr")``).
    Re-registering a name overwrites it (latest wins), so tests can shadow.

    The factory's keyword arguments become the application's dispatch
    arguments: after ::

        @register_app("my_walk")
        def my_walk(source: int = 0) -> VertexProgram: ...

    ``GraphSession.run("my_walk", source=3)`` instantiates and runs it; it
    also shows up in ``available_apps()`` and works with ``run_many``.
    Factories returning a ``BatchedVertexProgram`` are dispatched the same
    way through ``GraphSession.run_batch``.

    ``incremental=True`` declares the app safe for incremental recompute
    after a *monotone* delta (insert-only / weight-non-increasing): its
    update is a min-propagation whose previous fixpoint stays a valid upper
    bound, so ``session.run_incremental`` may seed from it instead of
    rerunning cold.  Apps whose values can move in either direction
    (PageRank) must leave it False — they always fall back to a full run.
    """
    if isinstance(name_or_factory, str):
        name = name_or_factory

    def deco(factory):
        final = name or factory.__name__
        _REGISTRY[final] = factory
        if incremental:
            _INCREMENTAL.add(final)
        else:
            _INCREMENTAL.discard(final)  # an overwrite drops the old claim
        return factory

    if callable(name_or_factory):
        return deco(name_or_factory)
    return deco


def is_incremental(name: str) -> bool:
    """True iff ``name`` was registered with ``incremental=True``."""
    return name in _INCREMENTAL


def get_app(name: str, **kwargs) -> "VertexProgram":
    """Instantiate a registered program; kwargs go to its factory."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown graph application {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None
    return factory(**kwargs)


def available_apps() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    semiring: str
    value_dtype: np.dtype
    # (n, in_deg, out_deg) -> (values [n], active [n] bool)   (host-side, Algorithm 3 Init)
    init: Callable[[int, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]
    # (values, out_deg) -> x pulled along in-edges               (device)
    gather_transform: Callable[[Array, Array], Array]
    # (partial, old, num_vertices) -> new                         (device)
    post: Callable[[Array, Array, int], Array]
    # (new, old) -> bool mask of updated vertices                 (device)
    changed: Callable[[Array, Array], Array]
    # identity the engine substitutes for intervals with no processed edges
    needs_all_edges: bool = False  # True => every vertex recomputed each iter (PR)
    # frontier vertex ids this program was built for (() if source-free);
    # checkpoints record them so resume can reject a different run's state
    sources: tuple = ()
    # batch-compatibility token: two programs with EQUAL jit_signature are
    # guaranteed to have identical device callables (gather_transform / post /
    # changed and semiring), differing only in host-side init/sources.  The
    # engine cache keys on it, so e.g. sssp(source=5) and sssp(source=7)
    # share one engine and its jitted shard steps instead of recompiling per
    # source — the property the serving layer's dynamic batching relies on.
    # None => no sharing claim (engines keyed by program identity/name).
    # CONTRACT for dataclasses.replace(): the signature is inherited, so
    # overriding any device callable (gather_transform/post/changed) MUST
    # also replace jit_signature (or set it to None) — keeping the old one
    # silently serves the old compiled functions.  Renaming alone is fine
    # (bfs = sssp renamed shares sssp's engine deliberately).
    jit_signature: tuple | None = None


@register_app
def pagerank(damping: float = 0.85, tol: float = 1e-6) -> VertexProgram:
    """tol is RELATIVE (|Δ| > tol·|old|): the paper's Fig 7a shows PR active
    ratio under 0.1% by ~iteration 110 — absolute epsilons can't reproduce
    that across graph sizes, a relative one does."""
    def init(n, in_deg, out_deg):
        v = np.full(n, 1.0 / n, dtype=np.float32)
        return v, np.ones(n, dtype=bool)  # all vertices active (Alg 3 l.5)

    def gather(values, out_deg):
        return values / jnp.maximum(out_deg, 1).astype(values.dtype)

    def post(partial, old, n):
        return (1.0 - damping) / n + damping * partial

    return VertexProgram(
        name="pagerank",
        semiring="plus_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=gather,
        post=post,
        changed=lambda new, old: jnp.abs(new - old) > tol * jnp.abs(old) + 1e-30,
        needs_all_edges=True,
        jit_signature=("pagerank", float(damping), float(tol)),
    )


_INF = np.float32(np.inf)


@register_app(incremental=True)
def sssp(source: int = 0) -> VertexProgram:
    def init(n, in_deg, out_deg):
        v = np.full(n, _INF, dtype=np.float32)
        v[source] = 0.0
        active = np.zeros(n, dtype=bool)
        active[source] = True  # only the source starts active (Alg 3 l.19)
        return v, active

    return VertexProgram(
        name="sssp",
        semiring="min_plus",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, n: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        sources=(source,),
        # source only affects init: every SSSP/BFS query shares one engine
        jit_signature=("sssp",),
    )


@register_app(incremental=True)
def bfs(source: int = 0) -> VertexProgram:
    """Hop distance = SSSP with unit edge weights (vals are 1.0 in ELL)."""
    p = sssp(source)
    return dataclasses.replace(p, name="bfs")


@register_app(incremental=True)
def cc() -> VertexProgram:
    def init(n, in_deg, out_deg):
        v = np.arange(n, dtype=np.float32)  # subgraph id := vertex id (Alg 3 l.29)
        return v, np.ones(n, dtype=bool)

    return VertexProgram(
        name="cc",
        semiring="min_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, n: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        jit_signature=("cc",),
    )


# ---------------------------------------------------------------------------
# Batched multi-source programs: one VSW sweep serves K frontiers
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BatchedVertexProgram:
    """K independent frontiers sharing one edge sweep (paper §2.2 economics,
    amortized across *queries* instead of applications).

    Values are [n, K] matrices; column k is exactly the single-source program
    for source k.  ``post`` additionally receives the *global* destination
    row ids of its slice, plus a slice of the optional ``make_aux`` matrix.

    ``make_aux`` carries per-column CONSTANTS (personalized PageRank's
    scaled seed one-hot) into the jitted shard step as a runtime [n, K]
    array rather than a baked-in closure constant: the compiled step is
    then identical across source/seed sets, so ``jit_signature`` need not
    include them and a serving workload streaming distinct seed sets at the
    same K reuses ONE compiled engine instead of recompiling per request.
    """

    name: str
    semiring: str
    value_dtype: np.dtype
    columns: int  # K, static: the jitted shard step specializes per K
    # (n, in_deg, out_deg) -> (values [n, K], active [n, K] bool)
    init: Callable[[int, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]
    # (values [n_pad, K], out_deg [n_pad]) -> x pulled along in-edges
    gather_transform: Callable[[Array, Array], Array]
    # (partial [R, K], old [R, K], rows [R] global ids, num_vertices,
    #  aux [R, K] slice of make_aux(n) or None) -> new
    post: Callable[[Array, Array, Array, int, Array | None], Array]
    # (new [n, K], old [n, K]) -> bool mask of updated (vertex, column) pairs
    changed: Callable[[Array, Array], Array]
    # the K frontier vertex ids, column order; checkpoints record them so
    # resume rejects state from a different landmark/seed set
    sources: tuple = ()
    # batch-compatibility token — see VertexProgram.jit_signature.  Batched
    # signatures include K (the jitted [n, K] shard step specializes on it)
    # but usually NOT the sources, so a serving layer answering a stream of
    # distinct landmark sets at the same K reuses one compiled engine.
    jit_signature: tuple | None = None
    # optional n -> [n, K] float32 constants delivered to post as a runtime
    # argument (sliced per shard); None => post receives aux=None
    make_aux: Callable[[int], np.ndarray] | None = None
    # True => post takes a trailing iteration-number argument (a DEVICE int32
    # scalar, so the compiled step is shared across iterations): post(partial,
    # old, rows, n, aux, it).  Phase-dependent programs (triangle counting's
    # two-pass probe) key their update on it
    wants_iteration: bool = False


def _check_sources(sources) -> tuple[int, ...]:
    sources = tuple(int(s) for s in sources)
    if not sources:
        raise ValueError("need at least one source vertex")
    if any(s < 0 for s in sources):
        # negative ids would wrap under numpy indexing and silently compute
        # a plausible-looking column for vertex n+s
        raise ValueError(f"source vertex ids must be >= 0, got {sources}")
    return sources


@register_app
def sssp_multi(sources=(0,)) -> BatchedVertexProgram:
    """K single-source shortest-path queries in one engine run."""
    sources = _check_sources(sources)
    K = len(sources)

    def init(n, in_deg, out_deg):
        v = np.full((n, K), _INF, dtype=np.float32)
        active = np.zeros((n, K), dtype=bool)
        for k, s in enumerate(sources):
            v[s, k] = 0.0
            active[s, k] = True  # each column starts at its own source
        return v, active

    return BatchedVertexProgram(
        name="sssp_multi",
        semiring="min_plus",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, rows, n, aux: jnp.minimum(partial, old),
        changed=lambda new, old: new < old,
        sources=sources,
        # only K shapes the jitted [n, K] step — landmark sets share engines
        jit_signature=("sssp_multi", K),
    )


@register_app
def bfs_multi(sources=(0,)) -> BatchedVertexProgram:
    """K hop-distance queries (SSSP over unit edge weights)."""
    p = sssp_multi(sources)
    return dataclasses.replace(p, name="bfs_multi")


@register_app
def personalized_pagerank(seeds=(0,), damping: float = 0.85,
                          tol: float = 1e-6) -> BatchedVertexProgram:
    """K personalized-PageRank columns: pr_k = (1-d)·e_seed_k + d·Aᵀpr_k.

    The reset vector differs per column; it rides into the jitted shard
    step as the ``make_aux`` runtime constant (the [n, K] scaled seed
    one-hot), NOT as a closure constant — so every seed set of the same K
    shares one compiled engine (see ``BatchedVertexProgram.make_aux``).
    Same relative-tol convergence rule as the global ``pagerank``.
    """
    seeds = _check_sources(seeds)
    K = len(seeds)
    seeds_np = np.asarray(seeds, dtype=np.int64)

    def init(n, in_deg, out_deg):
        v = np.zeros((n, K), dtype=np.float32)
        v[seeds_np, np.arange(K)] = 1.0  # all mass starts on the seed
        return v, np.ones((n, K), dtype=bool)

    def gather(values, out_deg):
        return values / jnp.maximum(out_deg, 1).astype(values.dtype)[:, None]

    def make_aux(n):
        reset = np.zeros((n, K), dtype=np.float32)
        reset[seeds_np, np.arange(K)] = 1.0 - damping
        return reset

    return BatchedVertexProgram(
        name="personalized_pagerank",
        semiring="plus_src",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=gather,
        post=lambda partial, old, rows, n, aux: aux + damping * partial,
        changed=lambda new, old: jnp.abs(new - old) > tol * jnp.abs(old) + 1e-30,
        sources=seeds,
        jit_signature=("personalized_pagerank", K, float(damping), float(tol)),
        make_aux=make_aux,
    )


# ---------------------------------------------------------------------------
# App zoo: label propagation, k-core, triangle counting, random walks
# ---------------------------------------------------------------------------
@register_app(incremental=True)
def label_propagation() -> VertexProgram:
    """Max-label broadcast: every vertex starts labeled with its own id and
    repeatedly adopts the largest label among itself and its in-neighbors
    (a dense-frontier max-propagation — the mirror image of ``cc``).  On a
    symmetric graph the fixpoint labels each component with its largest
    member.  Labels only grow, so the previous fixpoint stays a valid lower
    bound under insert-only deltas => ``incremental=True``."""
    def init(n, in_deg, out_deg):
        v = np.arange(n, dtype=np.float32)
        return v, np.ones(n, dtype=bool)

    return VertexProgram(
        name="label_propagation",
        semiring="max_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, n: jnp.maximum(partial, old),
        changed=lambda new, old: new > old,
        jit_signature=("label_propagation",),
    )


@register_app
def lp_multi(sources=(0,)) -> BatchedVertexProgram:
    """K seeded label broadcasts in one sweep: column k starts with label
    ``source_k`` on its seed and -1 ("unreached") everywhere else, so the
    fixpoint marks exactly the vertices the seed's label can reach (along
    in-edges; reachability from the seed on symmetric graphs).  -1 stays
    below every real label AND above the segment-fold identity, keeping
    unreached rows stable however the empty-segment fill is spelled."""
    sources = _check_sources(sources)
    K = len(sources)

    def init(n, in_deg, out_deg):
        v = np.full((n, K), -1.0, dtype=np.float32)
        active = np.zeros((n, K), dtype=bool)
        for k, s in enumerate(sources):
            v[s, k] = float(s)
            active[s, k] = True
        return v, active

    return BatchedVertexProgram(
        name="lp_multi",
        semiring="max_src",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=lambda partial, old, rows, n, aux: jnp.maximum(partial, old),
        changed=lambda new, old: new > old,
        sources=sources,
        jit_signature=("lp_multi", K),
    )


def _check_thresholds(ks) -> tuple[int, ...]:
    ks = tuple(int(k) for k in ks)
    if not ks:
        raise ValueError("need at least one k threshold")
    if any(k < 0 for k in ks):
        raise ValueError(f"k-core thresholds must be >= 0, got {ks}")
    return ks


@register_app
def kcore(k: int = 2) -> VertexProgram:
    """k-core decomposition membership: iterated peeling of vertices with
    fewer than k live in-neighbors (degree, on symmetric graphs).

    values are alive flags in {0, 1}; each sweep pulls the live-neighbor
    count through plus_src and kills vertices below the threshold.  This is
    the standard Knaster-Tarski greatest-fixpoint iteration: starting from
    "everyone alive" and only ever deleting converges to the LARGEST set
    where every member keeps >= k live neighbors — exactly the k-core.
    Deletions are absorbing (changed = new < old), so the frontier is the
    vertices that just died and selective scheduling only revisits their
    out-neighborhoods.  NOT incremental: edge inserts can resurrect a
    peeled vertex, which a frontier seeded from the old (alive=0) fixpoint
    can never do — ``run_incremental`` falls back to a cold run."""
    k = int(k)
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")

    def init(n, in_deg, out_deg):
        return np.ones(n, dtype=np.float32), np.ones(n, dtype=bool)

    def post(partial, old, n):
        return jnp.where((old > 0) & (partial >= k), 1.0, 0.0).astype(old.dtype)

    return VertexProgram(
        name="kcore",
        semiring="plus_src",
        value_dtype=np.float32,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=post,
        changed=lambda new, old: new < old,
        jit_signature=("kcore", k),
    )


@register_app
def kcore_multi(ks=(2,)) -> BatchedVertexProgram:
    """K simultaneous k-core peels, one threshold per column.  The
    thresholds ride in through ``make_aux`` as a runtime [n, K] constant,
    so every threshold set of the same K shares one compiled engine."""
    ks = _check_thresholds(ks)
    K = len(ks)
    ks_np = np.asarray(ks, dtype=np.float32)

    def init(n, in_deg, out_deg):
        return (np.ones((n, K), dtype=np.float32),
                np.ones((n, K), dtype=bool))

    def make_aux(n):
        return np.broadcast_to(ks_np, (n, K)).copy()

    def post(partial, old, rows, n, aux):
        return jnp.where((old > 0) & (partial >= aux), 1.0, 0.0).astype(
            old.dtype)

    return BatchedVertexProgram(
        name="kcore_multi",
        semiring="plus_src",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=post,
        changed=lambda new, old: new < old,
        sources=ks,
        jit_signature=("kcore_multi", K),
        make_aux=make_aux,
    )


@register_app
def triangles_multi(vertices=(0,)) -> BatchedVertexProgram:
    """Per-vertex triangle counts for K probe vertices via two pull passes.

    Column k probes vertex u = vertices[k]:

      pass 0 (it == 0): from the one-hot e_u, partial[v] counts edges
        u -> v; clamping to {0, 1} leaves Z[v] = A[u, v], the in-neighbor
        indicator of u.
      pass 1 (it == 1): partial[v] = sum_w A[w, v] * Z[w] counts common
        neighbors of u and v; new[v] = Z[v] * partial[v] keeps it only on
        v in N(u).  On a symmetric simple graph, sum_v new[v] counts each
        triangle through u twice, so t(u) = sum(values[:, k]) / 2.

    ``wants_iteration`` keys the update on the sweep number; from it >= 2
    the post is the identity, so the run self-converges on the third sweep
    under any ``max_iters``.  Pass 0 starts all-active (the probe must
    reach every shard); pass 1's frontier is whatever pass 0 changed, and
    a shard skipped then is exactly one whose values pass 1 would not have
    moved (all its in-neighbor Z values equal the initial one-hot)."""
    vertices = _check_sources(vertices)
    K = len(vertices)
    verts_np = np.asarray(vertices, dtype=np.int64)

    def init(n, in_deg, out_deg):
        v = np.zeros((n, K), dtype=np.float32)
        v[verts_np, np.arange(K)] = 1.0
        return v, np.ones((n, K), dtype=bool)

    def post(partial, old, rows, n, aux, it):
        probe = (partial > 0).astype(old.dtype)   # pass 0: Z = A[u, :]
        closed = old * partial                    # pass 1: Z ∘ (A^T Z)
        return jnp.where(it == 0, probe,
                         jnp.where(it == 1, closed, old))

    return BatchedVertexProgram(
        name="triangles_multi",
        semiring="plus_src",
        value_dtype=np.float32,
        columns=K,
        init=init,
        gather_transform=lambda values, out_deg: values,
        post=post,
        changed=lambda new, old: new != old,
        sources=vertices,
        jit_signature=("triangles_multi", K),
        wants_iteration=True,
    )


# ---------------------------------------------------------------------------
# Host-driven applications: the program orchestrates the session itself
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DriverProgram:
    """An application whose outer loop runs on the HOST instead of compiling
    into the jitted VSW shard step: ``run(session, max_iters=..., config=...)``
    orchestrates engine runs (triangle counting's chunked probe sweep) or
    walks the shard cache directly (random-walk sampling), and returns a
    ``RunResult``/``BatchRunResult`` like any vertex program.  Dispatched by
    ``GraphSession.run`` / ``run_batch`` through the same registry; engine
    checkpoints/resume do not apply (drivers reject those arguments)."""

    name: str
    # (session, *, max_iters, config) -> RunResult | BatchRunResult
    run: Callable
    batched: bool = False  # True => run returns a BatchRunResult
    sources: tuple = ()


@register_app
def triangles(chunk: int = 64, lo: int = 0,
              hi: int | None = None) -> DriverProgram:
    """Per-vertex triangle counts for EVERY vertex: drives
    ``triangles_multi`` over probe-vertex chunks of a fixed width (constant
    K keeps all chunks on one jitted engine; the last chunk pads by
    repeating its final vertex and drops the duplicate columns).  Returns a
    ``RunResult`` whose values[v] is the number of triangles through v on a
    symmetric simple graph; ``sum(values) / 3`` is the global count.

    ``lo``/``hi`` restrict the probe vertices to the slab ``[lo, hi)``
    (default: all of them) — counts outside the slab stay zero.  Each
    chunk still streams every shard, so a slab run exercises the full I/O
    path at a fraction of the sweep count."""
    chunk = int(chunk)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")

    def run(session, *, max_iters: int = 200, config=None):
        from repro.core.engine import RunResult
        n = session.n
        stop = n if hi is None else min(int(hi), n)
        start = max(int(lo), 0)
        if start >= stop:
            raise ValueError(
                f"empty triangle slab [{lo}, {hi}) on {n} vertices")
        C = min(chunk, stop - start)
        counts = np.zeros(n, dtype=np.float32)
        history, iterations, epoch = [], 0, 0
        for lo_c in range(start, stop, C):
            vs = list(range(lo_c, min(lo_c + C, stop)))
            take = len(vs)
            vs += [vs[-1]] * (C - take)  # pad: constant K => one engine
            session.run_batch("triangles_multi", vertices=vs,
                              max_iters=max_iters, config=config)
            batch = session.last_batch_result
            vals = np.asarray(batch.values)
            counts[lo_c:lo_c + take] = 0.5 * vals[:, :take].sum(axis=0)
            history.extend(batch.history)
            iterations += batch.iterations
            epoch = batch.epoch
        return RunResult(values=counts, iterations=iterations,
                         history=history, converged=True, epoch=epoch,
                         tag=f"triangles:({start},{stop})")

    return DriverProgram(name="triangles", run=run)


@register_app
def random_walks(sources=(0,), length: int = 8,
                 seed: int = 0) -> DriverProgram:
    """K batched random walks, one per source, as [n, K] visit counts.

    Walks step along the pull layout's native adjacency — the IN-edges
    held by each destination interval's shard (on symmetric graphs, the
    standard uniform random walk).  Each step looks the current vertex's
    shard up through the session's shared compressed cache (``cache.get``
    — the walk IS the cache workload) and picks among its neighbors in
    canonical ELL order.

    The per-step choice uses a counter-based Philox stream keyed by
    (seed, source) with the step index as the counter block, so every
    column is a pure function of its own (seed, source) — batched walks
    are bitwise identical to solo walks regardless of batch composition,
    and a fixed seed reproduces exactly.  A walk halts at a dead end
    (vertex with no in-edges).  Visit counts include the starting
    position; ``column_iterations[k]`` is the number of steps walk k
    actually took."""
    sources = _check_sources(sources)
    length = int(length)
    seed = int(seed)
    if length < 0:
        raise ValueError(f"length must be >= 0, got {length}")
    K = len(sources)

    def run(session, *, max_iters: int = 200, config=None):
        import time
        from repro.core.engine import (BatchRunResult, IterationStats,
                                       _store_epoch)
        n = session.n
        intervals = np.asarray(session.store.intervals, dtype=np.int64)
        counts = np.zeros((n, K), dtype=np.float32)
        cur = np.asarray(sources, dtype=np.int64)
        alive = np.ones(K, dtype=bool)
        counts[cur, np.arange(K)] += 1.0  # position 0
        col_iters = np.zeros(K, dtype=np.int64)
        history = []
        steps = min(length, int(max_iters))
        epoch = _store_epoch(session.store)
        for step in range(steps):
            if not alive.any():
                break
            t0 = time.perf_counter()
            s0 = session.cache.stats
            disk0, hits0, miss0 = s0.disk_bytes, s0.hits, s0.misses
            edges = 0
            for k in range(K):  # fixed order => deterministic cache trace
                if not alive[k]:
                    continue
                v = int(cur[k])
                p = int(np.searchsorted(intervals, v, side="right")) - 1
                shard = session.cache.get(p)
                rows = np.nonzero(shard.row_map == v - shard.start_vertex)[0]
                nbrs = shard.cols[rows].ravel()
                nbrs = nbrs[nbrs >= 0]  # canonical ELL order
                edges += int(nbrs.size)
                if nbrs.size == 0:
                    alive[k] = False  # dead end: the walk halts
                    continue
                # counter-based stream: f(seed, source, step) — column k's
                # draws never depend on the other columns
                bits = np.random.Philox(
                    key=np.array([seed, sources[k]], dtype=np.uint64),
                    counter=np.array([step, 0, 0, 0], dtype=np.uint64))
                idx = np.random.Generator(bits).integers(nbrs.size)
                cur[k] = int(nbrs[idx])
                counts[cur[k], k] += 1.0
                col_iters[k] += 1
            s1 = session.cache.stats
            dh, dm = s1.hits - hits0, s1.misses - miss0
            history.append(IterationStats(
                iteration=step, seconds=time.perf_counter() - t0,
                active_ratio=float(alive.mean()),
                shards_processed=dh + dm, shards_skipped=0,
                disk_bytes=s1.disk_bytes - disk0,
                cache_hit_ratio=dh / max(dh + dm, 1),
                selective_enabled=False, edges_processed=edges))
        return BatchRunResult(
            values=counts, iterations=len(history), history=history,
            converged=True, epoch=epoch,
            tag=f"random_walks:{tuple(sources)}",
            column_iterations=col_iters,
            column_converged=np.ones(K, dtype=bool))

    return DriverProgram(name="random_walks", run=run, batched=True,
                         sources=sources)


# ---------------------------------------------------------------------------
# Batch-compatibility metadata: which single-query apps coalesce, and how
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """How K independent single-source queries of one app become one
    ``run_batch`` call.  The serving layer (repro/serve/graph_service.py)
    coalesces pending requests whose ``BatchSpec`` AND non-source parameters
    agree into one [n, K] micro-batch; ``family`` names the compatibility
    class (same batched factory + same semiring => same sweep can serve
    them)."""

    family: str        # compatibility class, e.g. "min_plus/sssp_multi"
    batched_app: str   # registered factory answering K queries at once
    source_param: str  # the single-query frontier kwarg ("source" / "seed")
    batch_param: str   # the batched factory's K-tuple kwarg ("sources"/"seeds")
    semiring: str      # shared semiring (informational; part of the family)
    exact: bool = True  # column k bitwise-equals the solo run (min-propagation
    #                     semirings; False for float-accumulating ones)


_BATCH_SPECS: dict[str, BatchSpec] = {}


def register_batchable(name: str, spec: BatchSpec) -> None:
    """Declare that single-query app ``name`` coalesces per ``spec``."""
    _BATCH_SPECS[name] = spec


def batch_spec(name: str) -> BatchSpec | None:
    """The BatchSpec for a single-query app name (None = not batchable)."""
    return _BATCH_SPECS.get(name)


register_batchable("sssp", BatchSpec(
    family="min_plus/sssp_multi", batched_app="sssp_multi",
    source_param="source", batch_param="sources", semiring="min_plus"))
register_batchable("bfs", BatchSpec(
    family="min_plus/bfs_multi", batched_app="bfs_multi",
    source_param="source", batch_param="sources", semiring="min_plus"))
# "ppr" has no solo VertexProgram (the seed reset needs the batched post's
# row ids) — a K=1 micro-batch IS its solo form.  plus_src accumulates
# floats, so coalesced columns match solo K=1 runs to tolerance, not bitwise.
register_batchable("ppr", BatchSpec(
    family="plus_src/personalized_pagerank", batched_app="personalized_pagerank",
    source_param="seed", batch_param="seeds", semiring="plus_src", exact=False))
# "lp" (seeded label broadcast from one source) has no solo VertexProgram —
# like "ppr", a K=1 micro-batch IS its solo form.  max_src propagates exact
# integral labels, so coalesced columns match solo runs bitwise.
register_batchable("lp", BatchSpec(
    family="max_src/lp_multi", batched_app="lp_multi",
    source_param="source", batch_param="sources", semiring="max_src"))
# "kcore" coalesces by THRESHOLD, not frontier: K peels with different k
# share one sweep, the thresholds riding in as the make_aux constant.
register_batchable("kcore", BatchSpec(
    family="plus_src/kcore_multi", batched_app="kcore_multi",
    source_param="k", batch_param="ks", semiring="plus_src"))
register_batchable("triangle_count", BatchSpec(
    family="plus_src/triangles_multi", batched_app="triangles_multi",
    source_param="vertex", batch_param="vertices", semiring="plus_src"))
register_batchable("random_walk", BatchSpec(
    family="walk/random_walks", batched_app="random_walks",
    source_param="source", batch_param="sources", semiring="walk"))


# ---------------------------------------------------------------------------
# Registry introspection: what exists, how it dispatches, how it coalesces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AppInfo:
    """One dispatchable application name and how it runs.

    ``kind`` is ``"vertex"`` (single-frontier ``session.run``),
    ``"batched"`` ([n, K] ``session.run_batch``), ``"driver"``
    (host-orchestrated), or ``"alias"`` (a serving-only name like ``"ppr"``
    with no factory of its own — a K=1 micro-batch of ``family`` is its
    solo form).  ``family`` is the BatchSpec compatibility class when the
    name coalesces in the serving layer, else None."""

    name: str
    kind: str
    incremental: bool
    family: str | None


def list_apps() -> tuple[AppInfo, ...]:
    """Every dispatchable application name, sorted, with its dispatch kind
    and serving metadata — so the serving layer, benchmarks and tests can
    enumerate the zoo instead of hard-coding names.  Factories are probed
    with their default arguments to classify the returned program."""
    infos = []
    for name in available_apps():
        try:
            prog = _REGISTRY[name]()
        except Exception:  # a factory without defaults stays dispatchable
            prog = None
        if isinstance(prog, DriverProgram):
            kind = "driver"
        elif isinstance(prog, BatchedVertexProgram):
            kind = "batched"
        else:
            kind = "vertex"
        spec = _BATCH_SPECS.get(name)
        infos.append(AppInfo(name=name, kind=kind,
                             incremental=is_incremental(name),
                             family=spec.family if spec else None))
    for name, spec in _BATCH_SPECS.items():
        if name not in _REGISTRY:
            infos.append(AppInfo(name=name, kind="alias", incremental=False,
                                 family=spec.family))
    return tuple(sorted(infos, key=lambda i: i.name))


# Deprecated alias: the live registry itself (mutations via register_app
# are visible here and vice versa).  Prefer get_app()/register_app.
APPS = _REGISTRY
