"""VSW engine: the paper's Algorithm 2 on a JAX device.

Faithful structure:
  * ``SrcVertexArray`` / ``DstVertexArray`` live on-device for the whole run
    (vertices never touch disk until the final checkpoint) — VSW's core claim;
  * edges stream shard-by-shard through the compressed cache (host tier) to
    the device; each shard updates exactly its destination interval, so the
    update is single-writer and lock/atomic-free.  The stream runs through a
    ``ShardPipeline``: with ``config.prefetch_depth > 0`` the next shards'
    disk reads, decompression and host->device staging happen on a background
    thread while the current shard's SpMV runs (paper §2.3's overlap;
    depth 1 = double buffering, depth 0 = the synchronous path);
  * after each iteration the active-vertex set is extracted; when
    ``active_ratio < selective_threshold`` (paper: 0.001) the per-shard Bloom
    filters gate shard loading (Algorithm 2 line 5).

Construction: engines are normally built *by* a ``repro.session.GraphSession``
which owns the store, ONE ``CompressedShardCache``, and the device-resident
degree arrays shared by every application (paper §2.2's "preprocess once,
serve many").  Tuning lives in the frozen ``EngineConfig``; the old kwarg
signature (``cache_mode=...`` etc.) still works as a deprecated shim that
builds a private cache.

Fault tolerance: the VSW invariant makes engine state tiny (2C|V| + cursor);
``checkpoint_every`` snapshots (values, iteration) with atomic rename, and
``run(resume=True)`` restarts from the latest snapshot.

Multi-device: ``config.num_devices > 1`` routes sessions to
``repro.core.distributed.ShardedVSWEngine``, a subclass that overrides the
seams below (``_fetch_shard`` / ``_make_pipeline`` / ``_sweep`` /
``_io_marks`` / ``_io_stats``) to drive N devices per iteration while
``iter_run``'s convergence/checkpoint/epoch logic stays shared.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apps import BatchedVertexProgram, VertexProgram
from repro.core.cache import CompressedShardCache
from repro.core.pipeline import ShardPipeline
from repro.core.shards import ELLShard
from repro.graph.source import ConcurrentMutationError, ShardSource
from repro.kernels.spmv.ops import ell_spmv, ell_spmv_batch

_VALID_CACHE_MODES = (0, 1, 2, 3, 4)


def _store_epoch(store) -> int:
    """Graph epoch of a store; frozen backends (no ``epoch``) sit at 0."""
    fn = getattr(store, "epoch", None)
    return int(fn()) if callable(fn) else 0


def _env(name: str, default, cast):
    raw = os.environ.get(name)
    if raw is None or raw == "":  # unset/empty (CI matrix legs) -> default
        return default
    try:
        return cast(raw)
    except (TypeError, ValueError):
        warnings.warn(f"ignoring unparseable {name}={raw!r}", RuntimeWarning)
        return default


def _cast_mode(raw: str):
    return raw if raw in ("auto", "adaptive") else int(raw)


def _cast_tristate(raw: str):
    low = raw.lower()
    if low == "auto":
        return "auto"
    return low in ("1", "true", "yes", "on")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Validated, immutable engine tuning (replaces the old kwarg soup).

    ``from_env()`` reads ``GRAPHMP_*`` environment overrides; ``replace()``
    derives per-run variants without mutating the shared default.  Fields
    (env var in parentheses; see docs/REPRODUCING.md for the full table):

    cache_mode (``GRAPHMP_CACHE_MODE``):
        ``"auto"``/``"adaptive"`` — the two-tier adaptive edge cache
        (default); an int 0-4 — the paper's static §2.4.2 modes (0 = no
        cache, 1 = raw arrays, 2-4 = zstd levels 1/3/9).
    cache_budget_bytes (``GRAPHMP_CACHE_BUDGET``, legacy alias
    ``GRAPHMP_CACHE_BUDGET_BYTES``):
        Strict host-byte budget for the edge cache, covering both tiers;
        0 means "no application cache" (degrades to mode 0).
    cache_hot_fraction (``GRAPHMP_CACHE_HOT_FRACTION``):
        Adaptive cache only: fraction of the budget the hot (decompressed)
        tier may occupy, in (0, 1].
    cache_promote_after (``GRAPHMP_CACHE_PROMOTE_AFTER``):
        Adaptive cache only: accesses (including the admitting miss) after
        which a cold shard becomes a promotion candidate (>= 1).
    selective_threshold (``GRAPHMP_SELECTIVE_THRESHOLD``):
        Active-vertex ratio below which Bloom-filter selective scheduling
        kicks in (paper: 0.001); negative disables it.
    use_pallas (``GRAPHMP_USE_PALLAS``):
        SpMV kernel backend: True/False, or ``"auto"`` to pick per platform.
    preload (``GRAPHMP_PRELOAD``):
        Pin every shard through the cache at engine construction.
    prefetch_depth (``GRAPHMP_PREFETCH``):
        Shards fetched ahead on a background thread (0 = synchronous,
        1 = double buffering).
    num_devices (``GRAPHMP_DEVICES``):
        Devices one VSW iteration drives concurrently.  1 (default) is the
        single-device engine; > 1 routes runs through the sharded engine
        (``repro.core.distributed.ShardedVSWEngine``): the shard schedule,
        edge-cache partitions and prefetch lanes split per device and the
        value matrix is partitioned over a 1-D ``jax.sharding.Mesh``.
        Requires that many local jax devices (on CPU:
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """

    cache_mode: int | str = "auto"
    cache_budget_bytes: int = 1 << 30
    cache_hot_fraction: float = 0.5
    cache_promote_after: int = 2
    selective_threshold: float = 1e-3
    use_pallas: bool | str = "auto"
    preload: bool = False
    prefetch_depth: int = 0
    num_devices: int = 1

    def __post_init__(self):
        mode = self.cache_mode
        if not (mode in ("auto", "adaptive")
                or (isinstance(mode, int)
                    and not isinstance(mode, bool)
                    and mode in _VALID_CACHE_MODES)):
            raise ValueError(
                f"cache_mode must be 'auto', 'adaptive' or one of "
                f"{_VALID_CACHE_MODES}, got {mode!r}")
        if not isinstance(self.cache_budget_bytes, int) \
                or isinstance(self.cache_budget_bytes, bool) \
                or self.cache_budget_bytes < 0:
            raise ValueError(
                f"cache_budget_bytes must be an int >= 0 (0 = no cache), "
                f"got {self.cache_budget_bytes!r}")
        if not isinstance(self.cache_hot_fraction, (int, float)) \
                or isinstance(self.cache_hot_fraction, bool) \
                or not 0.0 < self.cache_hot_fraction <= 1.0:
            raise ValueError(
                f"cache_hot_fraction must be in (0, 1], "
                f"got {self.cache_hot_fraction!r}")
        if not isinstance(self.cache_promote_after, int) \
                or isinstance(self.cache_promote_after, bool) \
                or self.cache_promote_after < 1:
            raise ValueError(
                f"cache_promote_after must be an int >= 1, "
                f"got {self.cache_promote_after!r}")
        if not np.isfinite(self.selective_threshold):
            raise ValueError(
                f"selective_threshold must be finite, "
                f"got {self.selective_threshold!r}")
        if self.use_pallas not in (True, False, "auto"):
            raise ValueError(
                f"use_pallas must be True, False or 'auto', "
                f"got {self.use_pallas!r}")
        if not isinstance(self.prefetch_depth, int) \
                or isinstance(self.prefetch_depth, bool) \
                or self.prefetch_depth < 0:
            raise ValueError(
                f"prefetch_depth must be a non-negative int, "
                f"got {self.prefetch_depth!r}")
        if not isinstance(self.num_devices, int) \
                or isinstance(self.num_devices, bool) \
                or self.num_devices < 1:
            raise ValueError(
                f"num_devices must be an int >= 1, got {self.num_devices!r}")

    @classmethod
    def from_env(cls, **overrides) -> "EngineConfig":
        """Defaults with GRAPHMP_* environment overrides applied underneath
        explicit keyword overrides."""
        budget_default = _env("GRAPHMP_CACHE_BUDGET_BYTES",  # legacy alias
                              cls.cache_budget_bytes, int)
        base = dict(
            cache_mode=_env("GRAPHMP_CACHE_MODE", cls.cache_mode, _cast_mode),
            cache_budget_bytes=_env("GRAPHMP_CACHE_BUDGET",
                                    budget_default, int),
            cache_hot_fraction=_env("GRAPHMP_CACHE_HOT_FRACTION",
                                    cls.cache_hot_fraction, float),
            cache_promote_after=_env("GRAPHMP_CACHE_PROMOTE_AFTER",
                                     cls.cache_promote_after, int),
            selective_threshold=_env("GRAPHMP_SELECTIVE_THRESHOLD",
                                     cls.selective_threshold, float),
            use_pallas=_env("GRAPHMP_USE_PALLAS", cls.use_pallas,
                            _cast_tristate),
            preload=_env("GRAPHMP_PRELOAD", cls.preload,
                         lambda r: _cast_tristate(r) is True),
            prefetch_depth=_env("GRAPHMP_PREFETCH", cls.prefetch_depth, int),
            num_devices=_env("GRAPHMP_DEVICES", cls.num_devices, int),
        )
        base.update(overrides)
        return cls(**base)

    def replace(self, **changes) -> "EngineConfig":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class IterationStats:
    iteration: int
    seconds: float
    active_ratio: float
    shards_processed: int
    shards_skipped: int
    disk_bytes: int
    cache_hit_ratio: float
    selective_enabled: bool
    edges_processed: int = 0    # sum of nnz over the shards actually run
    stall_seconds: float = 0.0  # time the compute loop waited on shard I/O
    fetch_seconds: float = 0.0  # fetch+stage time (overlapped when prefetching)
    decode_seconds_saved: float = 0.0  # decompression cost hot-tier hits skipped
    # multi-device runs only (empty tuples otherwise): per-device splits of
    # the aggregates above — one entry per device, summing (disk/fetch) or
    # totalling along the consumer's critical path (stall) to the aggregate,
    # so Table-3 accounting stays honest across cache partitions
    device_disk_bytes: tuple = ()
    device_stall_seconds: tuple = ()
    device_fetch_seconds: tuple = ()


@dataclasses.dataclass
class RunResult:
    """What one application run produced.

    ``values`` holds one float per vertex (ranks for PageRank, distances
    for SSSP/BFS, component ids for CC); ``iterations`` is how many sweeps
    ran, ``converged`` whether the frontier emptied before ``max_iters``,
    and ``history`` one ``IterationStats`` per iteration (per-iteration
    seconds, active ratio, shards processed/skipped, disk bytes, cache hit
    ratio, stall/fetch seconds).  ``total_seconds``/``edges_per_second``
    aggregate it.
    """

    values: np.ndarray
    iterations: int
    history: list[IterationStats]
    converged: bool
    # graph epoch pinned at run start (0 = frozen store) and program tag —
    # what session.run_incremental validates a `prev` result against
    epoch: int = 0
    tag: str | None = None

    @property
    def total_seconds(self) -> float:
        return sum(h.seconds for h in self.history)

    @property
    def total_edges_processed(self) -> int:
        return sum(h.edges_processed for h in self.history)

    def edges_per_second(self, num_edges: int | None = None) -> float:
        """Throughput over edges actually processed.

        Shards hold unequal edge counts, so skipped shards are weighted by
        their per-shard nnz (recorded in each IterationStats), not by shard
        count — selective-scheduling runs report honest edges/sec.
        ``num_edges`` is only a fallback for histories recorded before
        per-iteration edge counts existed (assumes no shard skipping).
        """
        processed = self.total_edges_processed
        if processed == 0 and num_edges is not None \
                and not any(h.selective_enabled for h in self.history):
            processed = num_edges * len(self.history)
        return processed / max(self.total_seconds, 1e-9)


@dataclasses.dataclass
class BatchRunResult(RunResult):
    """Result of a batched (multi-frontier) run: ``values`` is [n, K].

    ``iterations``/``history``/``converged`` describe the shared sweep;
    ``column_iterations[k]`` counts only the iterations column k entered with
    a non-empty frontier (its honest cost — a landmark that converged in 4
    hops does not get billed for the 40-hop straggler's sweeps).  The counts
    are checkpointed, so they span resume boundaries even though ``history``
    only covers the current run.
    """

    column_iterations: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    column_converged: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=bool))

    @property
    def num_columns(self) -> int:
        return self.values.shape[1]

    def column(self, k: int) -> RunResult:
        """Per-column view as a plain RunResult.

        ``iterations`` is the lifetime sweep count (spans resumes);
        ``history`` covers only this run, truncated to the iterations the
        column was live for here.  Frontiers only shrink, so a column live
        at a resume point was live for the entire pre-resume prefix —
        lifetime count minus the resume offset is its in-run live count.
        """
        iters = int(self.column_iterations[k])
        pre = self.history[0].iteration if self.history else 0
        return RunResult(values=self.values[:, k], iterations=iters,
                         history=self.history[: max(0, iters - pre)],
                         converged=bool(self.column_converged[k]),
                         epoch=self.epoch)

    def columns(self) -> list[RunResult]:
        return [self.column(k) for k in range(self.num_columns)]


_LEGACY_KWARGS = ("cache_mode", "cache_budget_bytes", "selective_threshold",
                  "use_pallas", "preload", "prefetch_depth")


class VSWEngine:
    """One vertex program bound to a graph store (Algorithm 2 executor).

    New API::

        session = GraphSession(store, config)
        result = session.run("pagerank", max_iters=30)

    or explicitly ``VSWEngine(store, program, config)``.  The old keyword
    signature (``VSWEngine(store, prog, cache_mode=2, ...)``) is kept as a
    deprecated shim and builds a private cache.
    """

    def __init__(
        self,
        store: ShardSource,
        program: VertexProgram,
        config: EngineConfig | int | str | None = None,
        *,
        cache: CompressedShardCache | None = None,
        vertex_info: tuple[np.ndarray, np.ndarray] | None = None,
        blooms: list | None = None,
        out_deg_dev: jnp.ndarray | None = None,
        n_pad: int | None = None,
        graph_epoch: int | None = None,
        observers: list | None = None,
        **legacy,
    ):
        if config is not None and not isinstance(config, EngineConfig):
            # old positional cache_mode slot
            legacy.setdefault("cache_mode", config)
            config = None
        unknown = set(legacy) - set(_LEGACY_KWARGS)
        if unknown:
            raise TypeError(f"unexpected VSWEngine arguments: {sorted(unknown)}")
        if legacy:
            warnings.warn(
                "VSWEngine(cache_mode=..., cache_budget_bytes=..., ...) is "
                "deprecated; pass an EngineConfig (or use GraphSession, which "
                "shares one compressed cache across applications)",
                DeprecationWarning, stacklevel=2)
            config = (config or EngineConfig()).replace(**legacy)
        self.config = config or EngineConfig()
        self.store = store
        self.program = program
        self.batched = isinstance(program, BatchedVertexProgram)
        self.cache = cache if cache is not None else CompressedShardCache(
            store, mode=self.config.cache_mode,
            budget_bytes=self.config.cache_budget_bytes,
            hot_fraction=self.config.cache_hot_fraction,
            promote_after=self.config.cache_promote_after)
        # telemetry taps: callables invoked with each IterationStats as it
        # is produced (GraphSession shares ONE list across all its engines,
        # so a MetricsHub attached mid-flight sees every later iteration).
        # Observer failures are swallowed — monitoring must never alter or
        # abort a computation.
        self.observers: list = observers if observers is not None else []
        self.selective_threshold = self.config.selective_threshold
        self.use_pallas = self.config.use_pallas
        self.preload = self.config.preload
        self.n = store.num_vertices
        # graph epoch the degree/bloom/meta arrays below were read at; a
        # mutable store moving past it triggers _sync_graph_state per run
        if graph_epoch is not None:
            self._graph_epoch = int(graph_epoch)
        else:
            self._graph_epoch = _store_epoch(store) if vertex_info is None else 0
        self._sync_lock = threading.Lock()
        self.in_deg, self.out_deg = (vertex_info if vertex_info is not None
                                     else store.read_vertex_info())
        self.blooms = blooms if blooms is not None else store.read_all_blooms()
        self.intervals = store.intervals
        self.P = store.num_shards
        shard_meta = store.properties["shards"]
        self._shard_nnz = [int(m.get("nnz", 0)) for m in shard_meta]
        self.max_rows = max((m["rows"] for m in shard_meta), default=8)
        # pad the vertex arrays so every dynamic_slice of length R is in-bounds
        self.n_pad = n_pad if n_pad is not None else self.n + self.max_rows
        if out_deg_dev is not None:
            self._out_deg_dev = out_deg_dev
        else:
            self._out_deg_dev = jnp.asarray(
                np.pad(self.out_deg, (0, self.n_pad - self.n)).astype(np.float32))
        self._build_steps()
        self._preloaded: dict[int, ELLShard] = {}
        if self.preload:
            for p in range(self.P):
                self._preloaded[p] = self._fetch_shard(p)
        # ALL shard consumption goes through the pipeline — depth 0 is the
        # synchronous path, depth >= 1 prefetches + stages on a worker thread
        # (the sharded engine overrides _make_pipeline with one lane per
        # device and leaves self._pipeline as None)
        self._pipeline = self._make_pipeline()
        self.last_result: RunResult | None = None
        # serializes run() calls on this engine: concurrent clients (the
        # serving layer) sharing one jitted engine run back-to-back instead
        # of interleaving pipeline stats and per-iteration disk accounting
        self._run_lock = threading.Lock()

    @classmethod
    def from_session(cls, session, program: VertexProgram,
                     config: EngineConfig | None = None) -> "VSWEngine":
        """Build an engine that shares the session's cache + degree arrays."""
        return cls(
            session.store, program, config or session.config,
            cache=session.cache,
            vertex_info=(session.in_deg, session.out_deg),
            blooms=session.blooms,
            out_deg_dev=session.out_deg_dev,
            n_pad=session.n_pad,
            graph_epoch=getattr(session, "_graph_epoch", None),
            observers=getattr(session, "iteration_observers", None),
        )

    # ------------------------------------------------------------------
    def _build_steps(self) -> None:
        program, n = self.program, self.n
        semiring, use_pallas = self.program.semiring, self.use_pallas

        # out-degrees arrive as a RUNTIME argument, never a closure constant:
        # a jit closure would bake the degree array at trace time and
        # silently keep serving stale degrees after a graph mutation
        @jax.jit
        def gather_fn(values, out_deg):
            return program.gather_transform(values, out_deg)

        if self.batched:
            # [n_pad, K] value matrix: one edge sweep advances K frontiers.
            # Per-column constants (PPR's reset vector) arrive through the
            # runtime ``aux`` argument so the compiled step — and therefore
            # the engine — is shared across source/seed sets (jit_signature).
            has_aux = getattr(program, "make_aux", None) is not None
            # phase-dependent programs (triangle counting's two-pass probe)
            # additionally receive the iteration number as a DEVICE scalar —
            # a runtime argument, so every iteration reuses one compiled step
            wants_it = getattr(program, "wants_iteration", False)

            def shard_step(dst, x, src, aux, it, cols, vals, row_map, qp,
                           start, num_rows):
                R = cols.shape[0]
                K = src.shape[1]
                seg = ell_spmv_batch(x, cols, vals, row_map, R, semiring,
                                     use_pallas=use_pallas, qparams=qp)
                old_slice = jax.lax.dynamic_slice(src, (start, 0), (R, K))
                rows = start + jnp.arange(R)
                aux_slice = (jax.lax.dynamic_slice(aux, (start, 0), (R, K))
                             if has_aux else None)
                if wants_it:
                    new_slice = program.post(seg, old_slice, rows, n,
                                             aux_slice, it)
                else:
                    new_slice = program.post(seg, old_slice, rows, n,
                                             aux_slice)
                new_slice = new_slice.astype(dst.dtype)
                keep = (jnp.arange(R) < num_rows)[:, None]
                new_slice = jnp.where(keep, new_slice, old_slice)
                return jax.lax.dynamic_update_slice(dst, new_slice, (start, 0))
        else:
            def shard_step(dst, x, src, cols, vals, row_map, qp, start,
                           num_rows):
                R = cols.shape[0]
                seg = ell_spmv(x, cols, vals, row_map, R, semiring,
                               use_pallas=use_pallas, qparams=qp)
                old_slice = jax.lax.dynamic_slice(src, (start,), (R,))
                new_slice = program.post(seg, old_slice, n).astype(dst.dtype)
                keep = jnp.arange(R) < num_rows
                new_slice = jnp.where(keep, new_slice, old_slice)
                return jax.lax.dynamic_update_slice(dst, new_slice, (start,))

        # one jit per ELL (R, W) bucket happens automatically via shape polymorphism
        self._shard_step = jax.jit(shard_step, donate_argnums=(0,))
        self._gather_fn = gather_fn

        @jax.jit
        def changed_fn(new, old):
            return program.changed(new[: self.n], old[: self.n])

        self._changed_fn = changed_fn

    # ------------------------------------------------------------------
    @property
    def _ckpt_tag(self) -> str:
        """Program identity recorded in checkpoints: name + frontier ids."""
        return self._tag_for(self.program)

    @staticmethod
    def _tag_for(program) -> str:
        return f"{program.name}:{tuple(program.sources)}"

    def _check_program(self, program):
        """A run-time program substitute must be jit-compatible: equal
        non-None ``jit_signature`` guarantees the jitted step closures built
        from ``self.program`` compute exactly its device functions (only
        host-side init / sources / checkpoint tags differ).

        The ``__code__`` comparison is a tripwire for a broken claim: fresh
        instances from the same factory (and rename-only
        ``dataclasses.replace`` derivatives like bfs) share code objects for
        their device callables, but a program that kept an inherited
        signature while overriding gather/post/changed does not — running it
        here would silently execute the OLD compiled functions."""
        if program is None or program is self.program:
            return self.program
        sig = getattr(program, "jit_signature", None)
        if sig is None or sig != self.program.jit_signature:
            raise ValueError(
                f"program {program.name!r} (jit_signature={sig!r}) is not "
                f"jit-compatible with this engine's {self.program.name!r} "
                f"(jit_signature={self.program.jit_signature!r})")
        for attr in ("gather_transform", "post", "changed"):
            mine = getattr(getattr(self.program, attr), "__code__", None)
            theirs = getattr(getattr(program, attr), "__code__", None)
            if mine is not theirs:
                raise ValueError(
                    f"program {program.name!r} claims jit_signature {sig!r} "
                    f"but its {attr} differs from this engine's compiled one "
                    f"— a dataclasses.replace() that overrides device "
                    f"callables must also replace jit_signature")
        return program

    def _fetch_shard(self, p: int) -> ELLShard:
        """Raw cache fetch (no preload shortcut) — the single overridable
        seam that decides WHICH cache a shard comes from (the sharded engine
        routes it to the owning device's cache partition)."""
        return self.cache.get(p)

    def _make_pipeline(self):
        """Build the shard stream consumed by ``_sweep``."""
        return ShardPipeline(
            self._get_shard, depth=self.config.prefetch_depth,
            stage=self._stage, nbytes=ELLShard.decoded_nbytes)

    def _get_shard(self, p: int) -> ELLShard:
        if p in self._preloaded:
            return self._preloaded[p]
        return self._fetch_shard(p)

    def _sync_graph_state(self) -> None:
        """Refresh graph-derived engine state after a store mutation.

        Cheap no-op while the store's epoch matches the one the current
        degree/bloom/shard-meta arrays were read at.  On an epoch change:
        re-read vertex info, rebuild the device out-degree array, recompute
        shard nnz/rows (``n_pad`` only ever grows, so jitted shapes stay
        stable when possible), and re-read Blooms — but ONLY for shards
        whose own epoch moved (the session shares one blooms list across
        engines; refreshing it in place keeps every engine consistent).
        """
        if _store_epoch(self.store) == self._graph_epoch:
            return
        with self._sync_lock:
            cur = _store_epoch(self.store)
            prev = self._graph_epoch
            if cur == prev:
                return
            self.in_deg, self.out_deg = self.store.read_vertex_info()
            shard_meta = self.store.properties["shards"]
            self._shard_nnz = [int(m.get("nnz", 0)) for m in shard_meta]
            self.max_rows = max((m["rows"] for m in shard_meta), default=8)
            self.n_pad = max(self.n_pad, self.n + self.max_rows)
            self._out_deg_dev = jnp.asarray(
                np.pad(self.out_deg,
                       (0, self.n_pad - self.n)).astype(np.float32))
            shard_epoch = getattr(self.store, "shard_epoch", None)
            for p in range(self.P):
                if shard_epoch is None or shard_epoch(p) > prev:
                    self.blooms[p] = self.store.read_bloom(p)
                    if p in self._preloaded:
                        self._preloaded[p] = self._fetch_shard(p)
            self._graph_epoch = cur

    @staticmethod
    def _materialize(arr: np.ndarray) -> np.ndarray:
        """Read-only arrays are mmap-backed views (packed backend): copy them
        so the page-in happens HERE — on the prefetch thread, hideable —
        instead of via jax aliasing the mapping and faulting inside the SpMV
        (which would also pin the mmap open past session close)."""
        return arr if arr.flags.writeable else np.array(arr)

    def _stage(self, shard: ELLShard):
        """Host->device staging; runs on the prefetch thread when depth > 0,
        so the transfer overlaps the previous shard's SpMV."""
        return (jnp.asarray(self._materialize(shard.cols)),
                jnp.asarray(self._materialize(shard.vals)),
                jnp.asarray(self._materialize(shard.row_map)),
                jnp.asarray(np.array([shard.val_scale, shard.val_zero],
                                     dtype=np.float32)))

    def _schedule(self, active_ids: np.ndarray | None, active_ratio: float) -> tuple[list[int], bool]:
        """Algorithm 2 line 5: all shards, unless selective scheduling kicks in."""
        if (
            active_ids is None
            or active_ratio >= self.selective_threshold
        ):
            return list(range(self.P)), False
        keep = [p for p in range(self.P) if self.blooms[p].might_contain_any(active_ids)]
        return keep, True

    # ------------------------------------------------------------------
    # iteration internals — each one an override seam for the sharded engine
    def _io_marks(self):
        """Snapshot of the cache/pipeline counters an iteration deltas
        against (opaque to iter_run; paired with ``_io_stats``)."""
        cs, ps = self.cache.stats, self._pipeline.stats
        return (cs.disk_bytes, cs.hits, cs.misses, cs.decode_seconds_saved,
                ps.stall_seconds, ps.fetch_seconds)

    def _io_stats(self, marks) -> dict:
        """IterationStats I/O fields as deltas against ``marks``."""
        disk0, hits0, misses0, saved0, stall0, fetch0 = marks
        cs, ps = self.cache.stats, self._pipeline.stats
        d_hits = cs.hits - hits0
        d_total = d_hits + cs.misses - misses0
        return dict(
            disk_bytes=cs.disk_bytes - disk0,
            cache_hit_ratio=d_hits / d_total if d_total else 0.0,
            stall_seconds=ps.stall_seconds - stall0,
            fetch_seconds=ps.fetch_seconds - fetch0,
            decode_seconds_saved=cs.decode_seconds_saved - saved0,
        )

    def _sweep(self, x, src, aux_dev, it_dev, schedule, epoch_check):
        """One edge sweep: stream the scheduled shards, fold each into the
        destination array.  Returns ``(new values [n_pad(, K)],
        changed mask [n(, K)] as a numpy bool array)``."""
        dst = src + 0.0  # materialize a copy: the shard step donates its dst
        for _p, shard, dev in self._pipeline.stream(schedule,
                                                    check=epoch_check):
            cols_dev, vals_dev, row_map_dev, qp_dev = dev
            tail = (cols_dev, vals_dev, row_map_dev, qp_dev,
                    shard.start_vertex,
                    shard.end_vertex - shard.start_vertex)
            if self.batched:
                dst = self._shard_step(dst, x, src, aux_dev, it_dev, *tail)
            else:
                dst = self._shard_step(dst, x, src, *tail)
        return dst, np.asarray(self._changed_fn(dst, src))

    # ------------------------------------------------------------------
    def iter_run(
        self,
        max_iters: int = 200,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        resume: bool = False,
        program: VertexProgram | None = None,
        init_state: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> Iterator[IterationStats]:
        """Generator form of ``run``: yields an IterationStats after every
        iteration (live monitoring), returns the RunResult on exhaustion
        (also stored in ``self.last_result``).  Batched programs return a
        ``BatchRunResult`` with [n, K] values and per-column accounting.

        ``program`` substitutes a jit-compatible program (equal
        ``jit_signature``) for this run only: the engine keeps its compiled
        shard steps while ``init``/``sources``/checkpoint tags come from the
        substitute.  This is how one engine answers e.g. SSSP from any
        source without recompiling — no engine state is mutated, so distinct
        runs with distinct programs can share the instance.

        ``init_state`` replaces ``program.init`` with explicit
        ``(values, active_mask)`` arrays — how incremental recompute seeds
        the frontier from a previous result's fixpoint.  Mutually exclusive
        with ``resume``.

        The run **pins the store's graph epoch at start**: every shard fetch
        asserts the shard has not moved past it, and a concurrent
        ``apply()`` therefore raises ``ConcurrentMutationError`` instead of
        mixing epochs into one result."""
        program = self._check_program(program)
        self._sync_graph_state()
        run_epoch = self._graph_epoch
        shard_epoch_fn = getattr(self.store, "shard_epoch", None)
        epoch_check = None
        if shard_epoch_fn is not None:
            def epoch_check(p, _fn=shard_epoch_fn, _pin=run_epoch):
                got = _fn(p)
                if got > _pin:
                    raise ConcurrentMutationError(
                        f"shard {p} is at epoch {got}, newer than the epoch "
                        f"{_pin} this run pinned at start — the graph was "
                        "mutated mid-run (drain runs before apply(), e.g. "
                        "via GraphService.apply_mutations)")
        if init_state is not None:
            if resume:
                raise ValueError("init_state and resume are mutually "
                                 "exclusive ways to seed a run")
            values, active_mask = init_state
            values = np.asarray(values)
            active_mask = np.asarray(active_mask, dtype=bool)
            if values.shape[0] != self.n or active_mask.shape != values.shape:
                raise ValueError(
                    f"init_state arrays must both be [{self.n}, ...] with "
                    f"matching shapes, got {values.shape} / "
                    f"{active_mask.shape}")
        else:
            values, active_mask = program.init(self.n, self.in_deg,
                                               self.out_deg)
        start_iter = 0
        ck_col_iters = None
        if resume and checkpoint_dir:
            ck = latest_checkpoint(checkpoint_dir)
            if ck is not None:
                if ck[0].shape != values.shape:
                    raise ValueError(
                        f"checkpoint in {checkpoint_dir!r} holds values of "
                        f"shape {ck[0].shape}, but this program expects "
                        f"{values.shape}; it belongs to a different run")
                if ck[4] is not None and ck[4] != self._tag_for(program):
                    # same shapes, different program or landmark/seed set —
                    # continuing would return the OLD frontiers labeled with
                    # the caller's sources
                    raise ValueError(
                        f"checkpoint in {checkpoint_dir!r} was written by "
                        f"{ck[4]!r}, not {self._tag_for(program)!r}; it "
                        f"belongs to a different run")
                values, active_mask, start_iter, ck_col_iters = ck[:4]
        pad = self.n_pad - self.n
        aux_dev = None
        if self.batched:
            vpad = np.pad(values.astype(np.float32), ((0, pad), (0, 0)))
            make_aux = getattr(program, "make_aux", None)
            if make_aux is not None:
                aux_np = np.asarray(make_aux(self.n), dtype=np.float32)
                aux_dev = jnp.asarray(np.pad(aux_np, ((0, pad), (0, 0))))
            else:
                # placeholder keeps the jitted call signature stable; the
                # trace-time has_aux branch never touches it
                aux_dev = jnp.zeros((1, 1), jnp.float32)
            # per-column frontiers: a shard is skipped only when NO column's
            # active set touches it, so schedule over the union of frontiers
            row_active = active_mask.any(axis=1)
            col_live = active_mask.any(axis=0)
            # batched checkpoints always carry per-column counts
            col_iters = (ck_col_iters.astype(np.int64)
                         if ck_col_iters is not None
                         else np.zeros(program.columns, dtype=np.int64))
        else:
            vpad = np.pad(values.astype(np.float32), (0, pad))
            row_active = active_mask
            col_live = col_iters = None
        src = jnp.asarray(vpad)
        active_ids = np.nonzero(row_active)[0]
        active_ratio = active_ids.size / self.n
        history: list[IterationStats] = []
        converged = False

        last_changed = active_mask
        for it in range(start_iter, max_iters):
            t0 = time.time()
            marks = self._io_marks()
            schedule, selective = self._schedule(active_ids, active_ratio)
            if not schedule:
                converged = True
                break
            if self.batched:
                # bill this sweep only to columns still holding a frontier
                col_iters += col_live
            x = self._gather_fn(src, self._out_deg_dev)
            # iteration number as a device scalar: same shape/dtype every
            # sweep, so phase-dependent batched posts never retrace
            it_dev = jnp.int32(it) if self.batched else None
            dst, changed = self._sweep(x, src, aux_dev, it_dev, schedule,
                                       epoch_check)
            last_changed = changed
            if self.batched:
                col_live = changed.any(axis=0)
                row_active = changed.any(axis=1)
            else:
                row_active = changed
            active_ids = np.nonzero(row_active)[0]
            active_ratio = active_ids.size / self.n
            src = dst
            stats = IterationStats(
                iteration=it,
                seconds=time.time() - t0,
                active_ratio=active_ratio,
                shards_processed=len(schedule),
                shards_skipped=self.P - len(schedule),
                selective_enabled=selective,
                edges_processed=sum(self._shard_nnz[p] for p in schedule),
                **self._io_stats(marks),
            )
            history.append(stats)
            for observe in tuple(self.observers):
                try:
                    observe(stats)
                except Exception:
                    pass  # telemetry must never abort a sweep
            if checkpoint_dir and checkpoint_every and (it + 1) % checkpoint_every == 0:
                save_checkpoint(checkpoint_dir, np.asarray(src[: self.n]),
                                changed, it + 1, col_iters=col_iters,
                                tag=self._tag_for(program))
            yield stats
            if active_ids.size == 0:
                converged = True
                break

        final = np.asarray(src[: self.n])
        if checkpoint_dir:
            # persist the true active mask — a resumed run must see exactly
            # the frontier the interrupted run would have used next (for
            # batched runs this is the full per-column [n, K] frontier)
            save_checkpoint(checkpoint_dir, final, last_changed,
                            len(history) + start_iter, col_iters=col_iters,
                            tag=self._tag_for(program))
        if self.batched:
            # global convergence (empty union frontier / empty schedule)
            # implies no column can ever update again
            result: RunResult = BatchRunResult(
                values=final, iterations=len(history), history=history,
                converged=converged, epoch=run_epoch,
                tag=self._tag_for(program), column_iterations=col_iters,
                column_converged=np.asarray(~col_live | converged))
        else:
            result = RunResult(values=final, iterations=len(history),
                               history=history, converged=converged,
                               epoch=run_epoch, tag=self._tag_for(program))
        self.last_result = result
        return result

    def run(
        self,
        max_iters: int = 200,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        resume: bool = False,
        program: VertexProgram | None = None,
        init_state: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> RunResult:
        # the lock serializes whole runs, so concurrent callers sharing one
        # engine (GraphService runner threads) see coherent per-iteration
        # disk/stall accounting; iter_run itself stays lock-free because a
        # generator holding a lock across yields could deadlock its consumer
        with self._run_lock:
            gen = self.iter_run(max_iters=max_iters,
                                checkpoint_dir=checkpoint_dir,
                                checkpoint_every=checkpoint_every,
                                resume=resume, program=program,
                                init_state=init_state)
            while True:
                try:
                    next(gen)
                except StopIteration as stop:
                    return stop.value


# ---------------------------------------------------------------------------
def save_checkpoint(ckpt_dir: str, values: np.ndarray, active: np.ndarray,
                    iteration: int, col_iters: np.ndarray | None = None,
                    tag: str | None = None) -> None:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_ckpt_{iteration:06d}.npz"
    payload = dict(values=values, active=active, iteration=np.int64(iteration))
    if col_iters is not None:
        # batched runs: per-column sweep counts survive the interruption so
        # resumed accounting stays honest
        payload["col_iters"] = np.asarray(col_iters, dtype=np.int64)
    if tag is not None:
        # program identity (name + frontier ids): resume refuses state from
        # a different program or landmark/seed set
        payload["tag"] = np.asarray(tag)
    np.savez(tmp, **payload)
    os.replace(tmp, d / f"ckpt_{iteration:06d}.npz")  # atomic publish
    with open(d / "latest.json.tmp", "w") as f:
        json.dump({"iteration": iteration}, f)
    os.replace(d / "latest.json.tmp", d / "latest.json")
    # keep-N garbage collection
    cks = sorted(d.glob("ckpt_*.npz"))
    for old in cks[:-3]:
        old.unlink()


def latest_checkpoint(ckpt_dir: str):
    d = Path(ckpt_dir)
    meta = d / "latest.json"
    if not meta.exists():
        return None
    with open(meta) as f:
        it = json.load(f)["iteration"]
    p = d / f"ckpt_{it:06d}.npz"
    if not p.exists():
        return None
    with np.load(p) as z:
        col_iters = z["col_iters"] if "col_iters" in z.files else None
        tag = str(z["tag"]) if "tag" in z.files else None
        return z["values"], z["active"], int(z["iteration"]), col_iters, tag
