"""VSW engine: the paper's Algorithm 2 on a JAX device.

Faithful structure:
  * ``SrcVertexArray`` / ``DstVertexArray`` live on-device for the whole run
    (vertices never touch disk until the final checkpoint) — VSW's core claim;
  * edges stream shard-by-shard through the compressed cache (host tier) to
    the device; each shard updates exactly its destination interval, so the
    update is single-writer and lock/atomic-free;
  * after each iteration the active-vertex set is extracted; when
    ``active_ratio < selective_threshold`` (paper: 0.001) the per-shard Bloom
    filters gate shard loading (Algorithm 2 line 5).

Construction: engines are normally built *by* a ``repro.session.GraphSession``
which owns the store, ONE ``CompressedShardCache``, and the device-resident
degree arrays shared by every application (paper §2.2's "preprocess once,
serve many").  Tuning lives in the frozen ``EngineConfig``; the old kwarg
signature (``cache_mode=...`` etc.) still works as a deprecated shim that
builds a private cache.

Fault tolerance: the VSW invariant makes engine state tiny (2C|V| + cursor);
``checkpoint_every`` snapshots (values, iteration) with atomic rename, and
``run(resume=True)`` restarts from the latest snapshot.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from pathlib import Path
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apps import VertexProgram
from repro.core.cache import CompressedShardCache
from repro.core.shards import ELLShard
from repro.graph.storage import GraphStore
from repro.kernels.spmv.ops import ell_spmv

_VALID_CACHE_MODES = (0, 1, 2, 3, 4)


def _env(name: str, default, cast):
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        return cast(raw)
    except (TypeError, ValueError):
        warnings.warn(f"ignoring unparseable {name}={raw!r}", RuntimeWarning)
        return default


def _cast_mode(raw: str):
    return raw if raw == "auto" else int(raw)


def _cast_tristate(raw: str):
    low = raw.lower()
    if low == "auto":
        return "auto"
    return low in ("1", "true", "yes", "on")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Validated, immutable engine tuning (replaces the old kwarg soup).

    ``from_env()`` reads ``GRAPHMP_*`` environment overrides; ``replace()``
    derives per-run variants without mutating the shared default.
    """

    cache_mode: int | str = "auto"          # 'auto' | 0..4 (paper §2.4.2)
    cache_budget_bytes: int = 1 << 30       # host bytes for the edge cache
    selective_threshold: float = 1e-3       # active ratio below which Bloom
    #                                         scheduling kicks in; <0 disables
    use_pallas: bool | str = "auto"         # SpMV kernel backend selection
    preload: bool = False                   # pin every shard at construction

    def __post_init__(self):
        mode = self.cache_mode
        if not (mode == "auto" or (isinstance(mode, int)
                                   and not isinstance(mode, bool)
                                   and mode in _VALID_CACHE_MODES)):
            raise ValueError(
                f"cache_mode must be 'auto' or one of {_VALID_CACHE_MODES}, "
                f"got {mode!r}")
        if not isinstance(self.cache_budget_bytes, int) \
                or isinstance(self.cache_budget_bytes, bool) \
                or self.cache_budget_bytes <= 0:
            raise ValueError(
                f"cache_budget_bytes must be a positive int, "
                f"got {self.cache_budget_bytes!r}")
        if not np.isfinite(self.selective_threshold):
            raise ValueError(
                f"selective_threshold must be finite, "
                f"got {self.selective_threshold!r}")
        if self.use_pallas not in (True, False, "auto"):
            raise ValueError(
                f"use_pallas must be True, False or 'auto', "
                f"got {self.use_pallas!r}")

    @classmethod
    def from_env(cls, **overrides) -> "EngineConfig":
        """Defaults with GRAPHMP_* environment overrides applied underneath
        explicit keyword overrides."""
        base = dict(
            cache_mode=_env("GRAPHMP_CACHE_MODE", cls.cache_mode, _cast_mode),
            cache_budget_bytes=_env("GRAPHMP_CACHE_BUDGET_BYTES",
                                    cls.cache_budget_bytes, int),
            selective_threshold=_env("GRAPHMP_SELECTIVE_THRESHOLD",
                                     cls.selective_threshold, float),
            use_pallas=_env("GRAPHMP_USE_PALLAS", cls.use_pallas,
                            _cast_tristate),
            preload=_env("GRAPHMP_PRELOAD", cls.preload,
                         lambda r: _cast_tristate(r) is True),
        )
        base.update(overrides)
        return cls(**base)

    def replace(self, **changes) -> "EngineConfig":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class IterationStats:
    iteration: int
    seconds: float
    active_ratio: float
    shards_processed: int
    shards_skipped: int
    disk_bytes: int
    cache_hit_ratio: float
    selective_enabled: bool
    edges_processed: int = 0    # sum of nnz over the shards actually run


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    iterations: int
    history: list[IterationStats]
    converged: bool

    @property
    def total_seconds(self) -> float:
        return sum(h.seconds for h in self.history)

    @property
    def total_edges_processed(self) -> int:
        return sum(h.edges_processed for h in self.history)

    def edges_per_second(self, num_edges: int | None = None) -> float:
        """Throughput over edges actually processed.

        Shards hold unequal edge counts, so skipped shards are weighted by
        their per-shard nnz (recorded in each IterationStats), not by shard
        count — selective-scheduling runs report honest edges/sec.
        ``num_edges`` is only a fallback for histories recorded before
        per-iteration edge counts existed (assumes no shard skipping).
        """
        processed = self.total_edges_processed
        if processed == 0 and num_edges is not None \
                and not any(h.selective_enabled for h in self.history):
            processed = num_edges * len(self.history)
        return processed / max(self.total_seconds, 1e-9)


_LEGACY_KWARGS = ("cache_mode", "cache_budget_bytes", "selective_threshold",
                  "use_pallas", "preload")


class VSWEngine:
    """One vertex program bound to a graph store (Algorithm 2 executor).

    New API::

        session = GraphSession(store, config)
        result = session.run("pagerank", max_iters=30)

    or explicitly ``VSWEngine(store, program, config)``.  The old keyword
    signature (``VSWEngine(store, prog, cache_mode=2, ...)``) is kept as a
    deprecated shim and builds a private cache.
    """

    def __init__(
        self,
        store: GraphStore,
        program: VertexProgram,
        config: EngineConfig | int | str | None = None,
        *,
        cache: CompressedShardCache | None = None,
        vertex_info: tuple[np.ndarray, np.ndarray] | None = None,
        blooms: list | None = None,
        out_deg_dev: jnp.ndarray | None = None,
        n_pad: int | None = None,
        **legacy,
    ):
        if config is not None and not isinstance(config, EngineConfig):
            # old positional cache_mode slot
            legacy.setdefault("cache_mode", config)
            config = None
        unknown = set(legacy) - set(_LEGACY_KWARGS)
        if unknown:
            raise TypeError(f"unexpected VSWEngine arguments: {sorted(unknown)}")
        if legacy:
            warnings.warn(
                "VSWEngine(cache_mode=..., cache_budget_bytes=..., ...) is "
                "deprecated; pass an EngineConfig (or use GraphSession, which "
                "shares one compressed cache across applications)",
                DeprecationWarning, stacklevel=2)
            config = (config or EngineConfig()).replace(**legacy)
        self.config = config or EngineConfig()
        self.store = store
        self.program = program
        self.cache = cache if cache is not None else CompressedShardCache(
            store, mode=self.config.cache_mode,
            budget_bytes=self.config.cache_budget_bytes)
        self.selective_threshold = self.config.selective_threshold
        self.use_pallas = self.config.use_pallas
        self.preload = self.config.preload
        self.n = store.num_vertices
        self.in_deg, self.out_deg = (vertex_info if vertex_info is not None
                                     else store.read_vertex_info())
        self.blooms = blooms if blooms is not None else store.read_all_blooms()
        self.intervals = store.intervals
        self.P = store.num_shards
        shard_meta = store.properties["shards"]
        self._shard_nnz = [int(m.get("nnz", 0)) for m in shard_meta]
        self.max_rows = max((m["rows"] for m in shard_meta), default=8)
        # pad the vertex arrays so every dynamic_slice of length R is in-bounds
        self.n_pad = n_pad if n_pad is not None else self.n + self.max_rows
        if out_deg_dev is not None:
            self._out_deg_dev = out_deg_dev
        else:
            self._out_deg_dev = jnp.asarray(
                np.pad(self.out_deg, (0, self.n_pad - self.n)).astype(np.float32))
        self._build_steps()
        self._preloaded: dict[int, ELLShard] = {}
        if self.preload:
            for p in range(self.P):
                self._preloaded[p] = self.cache.get(p)
        self.last_result: RunResult | None = None

    @classmethod
    def from_session(cls, session, program: VertexProgram,
                     config: EngineConfig | None = None) -> "VSWEngine":
        """Build an engine that shares the session's cache + degree arrays."""
        return cls(
            session.store, program, config or session.config,
            cache=session.cache,
            vertex_info=(session.in_deg, session.out_deg),
            blooms=session.blooms,
            out_deg_dev=session.out_deg_dev,
            n_pad=session.n_pad,
        )

    # ------------------------------------------------------------------
    def _build_steps(self) -> None:
        program, n = self.program, self.n
        semiring, use_pallas = self.program.semiring, self.use_pallas

        @jax.jit
        def gather_fn(values):
            return program.gather_transform(values, self._out_deg_dev)

        def shard_step(dst, x, src, cols, vals, row_map, start, num_rows):
            R = cols.shape[0]
            seg = ell_spmv(x, cols, vals, row_map, R, semiring, use_pallas=use_pallas)
            old_slice = jax.lax.dynamic_slice(src, (start,), (R,))
            new_slice = program.post(seg, old_slice, n).astype(dst.dtype)
            keep = jnp.arange(R) < num_rows
            new_slice = jnp.where(keep, new_slice, old_slice)
            return jax.lax.dynamic_update_slice(dst, new_slice, (start,))

        # one jit per ELL (R, W) bucket happens automatically via shape polymorphism
        self._shard_step = jax.jit(shard_step, donate_argnums=(0,))
        self._gather_fn = gather_fn

        @jax.jit
        def changed_fn(new, old):
            return program.changed(new[: self.n], old[: self.n])

        self._changed_fn = changed_fn

    # ------------------------------------------------------------------
    def _get_shard(self, p: int) -> ELLShard:
        if p in self._preloaded:
            return self._preloaded[p]
        return self.cache.get(p)

    def _schedule(self, active_ids: np.ndarray | None, active_ratio: float) -> tuple[list[int], bool]:
        """Algorithm 2 line 5: all shards, unless selective scheduling kicks in."""
        if (
            active_ids is None
            or active_ratio >= self.selective_threshold
        ):
            return list(range(self.P)), False
        keep = [p for p in range(self.P) if self.blooms[p].might_contain_any(active_ids)]
        return keep, True

    # ------------------------------------------------------------------
    def iter_run(
        self,
        max_iters: int = 200,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        resume: bool = False,
    ) -> Iterator[IterationStats]:
        """Generator form of ``run``: yields an IterationStats after every
        iteration (live monitoring), returns the RunResult on exhaustion
        (also stored in ``self.last_result``)."""
        values, active_mask = self.program.init(self.n, self.in_deg, self.out_deg)
        start_iter = 0
        if resume and checkpoint_dir:
            ck = latest_checkpoint(checkpoint_dir)
            if ck is not None:
                values, active_mask, start_iter = ck
        vpad = np.pad(values.astype(np.float32), (0, self.n_pad - self.n))
        src = jnp.asarray(vpad)
        active_ids = np.nonzero(active_mask)[0]
        active_ratio = active_ids.size / self.n
        history: list[IterationStats] = []
        converged = False

        last_changed = active_mask
        for it in range(start_iter, max_iters):
            t0 = time.time()
            disk0 = self.cache.stats.disk_bytes
            schedule, selective = self._schedule(active_ids, active_ratio)
            if not schedule:
                converged = True
                break
            x = self._gather_fn(src)
            dst = src  # donated into shard steps; untouched intervals keep old values
            dst = dst + 0.0  # materialize a copy so src survives for `changed`
            for p in schedule:
                shard = self._get_shard(p)
                dst = self._shard_step(
                    dst, x, src,
                    jnp.asarray(shard.cols), jnp.asarray(shard.vals),
                    jnp.asarray(shard.row_map),
                    shard.start_vertex, shard.end_vertex - shard.start_vertex,
                )
            changed = np.asarray(self._changed_fn(dst, src))
            last_changed = changed
            active_ids = np.nonzero(changed)[0]
            active_ratio = active_ids.size / self.n
            src = dst
            stats = IterationStats(
                iteration=it,
                seconds=time.time() - t0,
                active_ratio=active_ratio,
                shards_processed=len(schedule),
                shards_skipped=self.P - len(schedule),
                disk_bytes=self.cache.stats.disk_bytes - disk0,
                cache_hit_ratio=self.cache.stats.hit_ratio,
                selective_enabled=selective,
                edges_processed=sum(self._shard_nnz[p] for p in schedule),
            )
            history.append(stats)
            if checkpoint_dir and checkpoint_every and (it + 1) % checkpoint_every == 0:
                save_checkpoint(checkpoint_dir, np.asarray(src[: self.n]), changed, it + 1)
            yield stats
            if active_ids.size == 0:
                converged = True
                break

        final = np.asarray(src[: self.n])
        if checkpoint_dir:
            # persist the true active mask — a resumed run must see exactly
            # the frontier the interrupted run would have used next
            save_checkpoint(checkpoint_dir, final, last_changed,
                            len(history) + start_iter)
        result = RunResult(values=final, iterations=len(history),
                           history=history, converged=converged)
        self.last_result = result
        return result

    def run(
        self,
        max_iters: int = 200,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        resume: bool = False,
    ) -> RunResult:
        gen = self.iter_run(max_iters=max_iters, checkpoint_dir=checkpoint_dir,
                            checkpoint_every=checkpoint_every, resume=resume)
        while True:
            try:
                next(gen)
            except StopIteration as stop:
                return stop.value


# ---------------------------------------------------------------------------
def save_checkpoint(ckpt_dir: str, values: np.ndarray, active: np.ndarray, iteration: int) -> None:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_ckpt_{iteration:06d}.npz"
    np.savez(tmp, values=values, active=active, iteration=np.int64(iteration))
    os.replace(tmp, d / f"ckpt_{iteration:06d}.npz")  # atomic publish
    with open(d / "latest.json.tmp", "w") as f:
        json.dump({"iteration": iteration}, f)
    os.replace(d / "latest.json.tmp", d / "latest.json")
    # keep-N garbage collection
    cks = sorted(d.glob("ckpt_*.npz"))
    for old in cks[:-3]:
        old.unlink()


def latest_checkpoint(ckpt_dir: str):
    d = Path(ckpt_dir)
    meta = d / "latest.json"
    if not meta.exists():
        return None
    with open(meta) as f:
        it = json.load(f)["iteration"]
    p = d / f"ckpt_{it:06d}.npz"
    if not p.exists():
        return None
    with np.load(p) as z:
        return z["values"], z["active"], int(z["iteration"])
