"""VSW engine: the paper's Algorithm 2 on a JAX device.

Faithful structure:
  * ``SrcVertexArray`` / ``DstVertexArray`` live on-device for the whole run
    (vertices never touch disk until the final checkpoint) — VSW's core claim;
  * edges stream shard-by-shard through the compressed cache (host tier) to
    the device; each shard updates exactly its destination interval, so the
    update is single-writer and lock/atomic-free;
  * after each iteration the active-vertex set is extracted; when
    ``active_ratio < selective_threshold`` (paper: 0.001) the per-shard Bloom
    filters gate shard loading (Algorithm 2 line 5).

Fault tolerance: the VSW invariant makes engine state tiny (2C|V| + cursor);
``checkpoint_every`` snapshots (values, iteration) with atomic rename, and
``run(resume=True)`` restarts from the latest snapshot.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apps import VertexProgram
from repro.core.cache import CompressedShardCache
from repro.core.shards import ELLShard
from repro.graph.storage import GraphStore
from repro.kernels.spmv.ops import ell_spmv


@dataclasses.dataclass
class IterationStats:
    iteration: int
    seconds: float
    active_ratio: float
    shards_processed: int
    shards_skipped: int
    disk_bytes: int
    cache_hit_ratio: float
    selective_enabled: bool


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    iterations: int
    history: list[IterationStats]
    converged: bool

    @property
    def total_seconds(self) -> float:
        return sum(h.seconds for h in self.history)

    def edges_per_second(self, num_edges: int) -> float:
        proc = sum(h.shards_processed for h in self.history)
        total = max(len(self.history), 1)
        # average over processed fraction of shards
        return num_edges * (proc / max(proc + sum(h.shards_skipped for h in self.history), 1)) \
            * total / max(self.total_seconds, 1e-9)


class VSWEngine:
    def __init__(
        self,
        store: GraphStore,
        program: VertexProgram,
        cache_mode: int | str = "auto",
        cache_budget_bytes: int = 1 << 30,
        selective_threshold: float = 1e-3,
        use_pallas: bool | str = "auto",
        preload: bool = False,
    ):
        self.store = store
        self.program = program
        self.cache = CompressedShardCache(store, mode=cache_mode, budget_bytes=cache_budget_bytes)
        self.selective_threshold = selective_threshold
        self.use_pallas = use_pallas
        self.preload = preload
        self.n = store.num_vertices
        self.in_deg, self.out_deg = store.read_vertex_info()
        self.blooms = store.read_all_blooms()
        self.intervals = store.intervals
        self.P = store.num_shards
        shard_meta = store.properties["shards"]
        self.max_rows = max((m["rows"] for m in shard_meta), default=8)
        # pad the vertex arrays so every dynamic_slice of length R is in-bounds
        self.n_pad = self.n + self.max_rows
        self._out_deg_dev = jnp.asarray(
            np.pad(self.out_deg, (0, self.n_pad - self.n)).astype(np.float32))
        self._build_steps()
        self._preloaded: dict[int, ELLShard] = {}
        if preload:
            for p in range(self.P):
                self._preloaded[p] = self.cache.get(p)

    # ------------------------------------------------------------------
    def _build_steps(self) -> None:
        program, n = self.program, self.n
        semiring, use_pallas = self.program.semiring, self.use_pallas

        @jax.jit
        def gather_fn(values):
            return program.gather_transform(values, self._out_deg_dev)

        def shard_step(dst, x, src, cols, vals, row_map, start, num_rows):
            R = cols.shape[0]
            seg = ell_spmv(x, cols, vals, row_map, R, semiring, use_pallas=use_pallas)
            old_slice = jax.lax.dynamic_slice(src, (start,), (R,))
            new_slice = program.post(seg, old_slice, n).astype(dst.dtype)
            keep = jnp.arange(R) < num_rows
            new_slice = jnp.where(keep, new_slice, old_slice)
            return jax.lax.dynamic_update_slice(dst, new_slice, (start,))

        # one jit per ELL (R, W) bucket happens automatically via shape polymorphism
        self._shard_step = jax.jit(shard_step, donate_argnums=(0,))
        self._gather_fn = gather_fn

        @jax.jit
        def changed_fn(new, old):
            return program.changed(new[: self.n], old[: self.n])

        self._changed_fn = changed_fn

    # ------------------------------------------------------------------
    def _get_shard(self, p: int) -> ELLShard:
        if p in self._preloaded:
            return self._preloaded[p]
        return self.cache.get(p)

    def _schedule(self, active_ids: np.ndarray | None, active_ratio: float) -> tuple[list[int], bool]:
        """Algorithm 2 line 5: all shards, unless selective scheduling kicks in."""
        if (
            active_ids is None
            or active_ratio >= self.selective_threshold
        ):
            return list(range(self.P)), False
        keep = [p for p in range(self.P) if self.blooms[p].might_contain_any(active_ids)]
        return keep, True

    # ------------------------------------------------------------------
    def run(
        self,
        max_iters: int = 200,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        resume: bool = False,
    ) -> RunResult:
        values, active_mask = self.program.init(self.n, self.in_deg, self.out_deg)
        start_iter = 0
        if resume and checkpoint_dir:
            ck = latest_checkpoint(checkpoint_dir)
            if ck is not None:
                values, active_mask, start_iter = ck
        vpad = np.pad(values.astype(np.float32), (0, self.n_pad - self.n))
        src = jnp.asarray(vpad)
        active_ids = np.nonzero(active_mask)[0]
        active_ratio = active_ids.size / self.n
        history: list[IterationStats] = []
        converged = False

        last_changed = active_mask
        for it in range(start_iter, max_iters):
            t0 = time.time()
            disk0 = self.cache.stats.disk_bytes
            schedule, selective = self._schedule(active_ids, active_ratio)
            if not schedule:
                converged = True
                break
            x = self._gather_fn(src)
            dst = src  # donated into shard steps; untouched intervals keep old values
            dst = dst + 0.0  # materialize a copy so src survives for `changed`
            for p in schedule:
                shard = self._get_shard(p)
                dst = self._shard_step(
                    dst, x, src,
                    jnp.asarray(shard.cols), jnp.asarray(shard.vals),
                    jnp.asarray(shard.row_map),
                    shard.start_vertex, shard.end_vertex - shard.start_vertex,
                )
            changed = np.asarray(self._changed_fn(dst, src))
            last_changed = changed
            active_ids = np.nonzero(changed)[0]
            active_ratio = active_ids.size / self.n
            src = dst
            history.append(
                IterationStats(
                    iteration=it,
                    seconds=time.time() - t0,
                    active_ratio=active_ratio,
                    shards_processed=len(schedule),
                    shards_skipped=self.P - len(schedule),
                    disk_bytes=self.cache.stats.disk_bytes - disk0,
                    cache_hit_ratio=self.cache.stats.hit_ratio,
                    selective_enabled=selective,
                )
            )
            if checkpoint_dir and checkpoint_every and (it + 1) % checkpoint_every == 0:
                save_checkpoint(checkpoint_dir, np.asarray(src[: self.n]), changed, it + 1)
            if active_ids.size == 0:
                converged = True
                break

        final = np.asarray(src[: self.n])
        if checkpoint_dir:
            # persist the true active mask — a resumed run must see exactly
            # the frontier the interrupted run would have used next
            save_checkpoint(checkpoint_dir, final, last_changed,
                            len(history) + start_iter)
        return RunResult(values=final, iterations=len(history), history=history, converged=converged)


# ---------------------------------------------------------------------------
def save_checkpoint(ckpt_dir: str, values: np.ndarray, active: np.ndarray, iteration: int) -> None:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_ckpt_{iteration:06d}.npz"
    np.savez(tmp, values=values, active=active, iteration=np.int64(iteration))
    os.replace(tmp, d / f"ckpt_{iteration:06d}.npz")  # atomic publish
    with open(d / "latest.json.tmp", "w") as f:
        json.dump({"iteration": iteration}, f)
    os.replace(d / "latest.json.tmp", d / "latest.json")
    # keep-N garbage collection
    cks = sorted(d.glob("ckpt_*.npz"))
    for old in cks[:-3]:
        old.unlink()


def latest_checkpoint(ckpt_dir: str):
    d = Path(ckpt_dir)
    meta = d / "latest.json"
    if not meta.exists():
        return None
    with open(meta) as f:
        it = json.load(f)["iteration"]
    p = d / f"ckpt_{it:06d}.npz"
    if not p.exists():
        return None
    with np.load(p) as z:
        return z["values"], z["active"], int(z["iteration"])
