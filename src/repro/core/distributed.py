"""Distributed VSW (beyond-paper): GraphMP's single-writer invariant on a mesh.

GraphMP is single-machine; its no-atomics property — every in-edge of a vertex
lives in exactly one shard — extends directly to a device mesh: partition
destination intervals over the ``data`` axis (one writer device per interval)
and keep the source array device-resident, refreshed once per iteration by an
``all_gather`` (the only collective; C|V| per iteration, the same volume the
paper writes to DRAM).

Per iteration, per device (under shard_map):

    x        = gather_transform(src_full)            # local, no comm
    partial  = ell_spmv(x, local shards)             # local SpMV (Pallas)
    new_own  = post(partial, src_own)                # local interval update
    src_full = all_gather(new_own, 'data')           # frontier exchange

Active-vertex tracking is a psum of changed counts, so the Bloom-filter
schedule stays identical on every host without coordination (the filters are
replicated — they are KBs).

The 2-D (src × dst) partition from DESIGN.md §2 maps the ``model`` axis over
source ranges with a psum over partials; implemented in `spmv_2d` below and
used by the graph-engine dry-run config.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.apps import VertexProgram, get_app
from repro.core.shards import SUBLANE, ELLShard, build_csr_shards, csr_to_ell
from repro.kernels.spmv.ops import ell_spmv


@dataclasses.dataclass
class DeviceShardedGraph:
    """Edges repartitioned so device d owns destination interval d (1-D)."""

    num_vertices: int          # padded to a multiple of num_devices
    num_edges: int
    cols: np.ndarray           # [D, R, W] int32 (per-device ELL, common shape)
    vals: np.ndarray           # [D, R, W] float32
    row_map: np.ndarray        # [D, R] int32 (local row within the device interval)
    out_deg: np.ndarray        # [num_vertices] int64
    rows_per_device: int       # interval length n/D


def partition_for_mesh(
    src: np.ndarray, dst: np.ndarray, num_vertices: int, num_devices: int,
    val: np.ndarray | None = None, ell_max_width: int = 256,
) -> DeviceShardedGraph:
    n_pad = ((num_vertices + num_devices - 1) // num_devices) * num_devices
    per = n_pad // num_devices
    shards = build_csr_shards(src, dst, n_pad, threshold_edge_num=1 << 62, val=val)
    # build_csr_shards with huge threshold yields one shard; re-cut at device bounds
    csr = shards[0]
    ells: list[ELLShard] = []
    for d in range(num_devices):
        lo, hi = d * per, (d + 1) * per
        sub = dataclasses.replace(
            csr,
            shard_id=d,
            start_vertex=lo,
            end_vertex=hi,
            row=csr.row[lo : hi + 1] - csr.row[lo],
            col=csr.col[csr.row[lo] : csr.row[hi]],
            val=None if csr.val is None else csr.val[csr.row[lo] : csr.row[hi]],
        )
        ells.append(csr_to_ell(sub, max_width=ell_max_width))
    R = max(((e.shape[0] + SUBLANE - 1) // SUBLANE) * SUBLANE for e in ells)
    W = max(e.shape[1] for e in ells)
    cols = np.full((num_devices, R, W), -1, dtype=np.int32)
    vals = np.zeros((num_devices, R, W), dtype=np.float32)
    row_map = np.zeros((num_devices, R), dtype=np.int32)
    for d, e in enumerate(ells):
        r, w = e.shape
        cols[d, :r, :w] = e.cols
        vals[d, :r, :w] = e.vals
        row_map[d, :r] = e.row_map
    out_deg = np.bincount(src, minlength=n_pad).astype(np.int64)
    return DeviceShardedGraph(
        num_vertices=n_pad, num_edges=len(src), cols=cols, vals=vals,
        row_map=row_map, out_deg=out_deg, rows_per_device=per,
    )


class DistributedVSW:
    """1-D distributed VSW engine over a mesh axis (default 'data')."""

    def __init__(self, graph: DeviceShardedGraph,
                 program: VertexProgram | str,
                 mesh: Mesh, axis: str = "data",
                 use_pallas: bool | str = "auto", config=None):
        if isinstance(program, str):
            program = get_app(program)
        if config is not None:  # share EngineConfig tuning with the session API
            use_pallas = config.use_pallas
        self.g = graph
        self.program = program
        self.mesh = mesh
        self.axis = axis
        self.use_pallas = use_pallas
        self.n = graph.num_vertices
        edge_spec = P(axis)
        self._cols = jax.device_put(graph.cols, NamedSharding(mesh, edge_spec))
        self._vals = jax.device_put(graph.vals, NamedSharding(mesh, edge_spec))
        self._rmap = jax.device_put(graph.row_map, NamedSharding(mesh, edge_spec))
        self._out_deg = jnp.asarray(graph.out_deg.astype(np.float32))
        self._iter_fn = self._build_iter()

    def _build_iter(self):
        program, n, per = self.program, self.n, self.g.rows_per_device
        semiring, use_pallas, axis = program.semiring, self.use_pallas, self.axis
        other_axes = tuple(a for a in self.mesh.axis_names if a != axis)

        def device_iter(src_full, out_deg, cols, vals, row_map):
            # shard_map gives per-device blocks with a leading length-1 axis
            cols, vals, row_map = cols[0], vals[0], row_map[0]
            x = program.gather_transform(src_full, out_deg)
            R = cols.shape[0]
            seg = ell_spmv(x, cols, vals, row_map, R, semiring, use_pallas=use_pallas)
            d = jax.lax.axis_index(axis)
            old_own = jax.lax.dynamic_slice(src_full, (d * per,), (per,))
            new_own = program.post(seg[:per], old_own, n).astype(src_full.dtype)
            changed = jnp.sum(program.changed(new_own, old_own).astype(jnp.int32))
            new_full = jax.lax.all_gather(new_own, axis, tiled=True)
            changed_total = jax.lax.psum(changed, axis)
            return new_full, changed_total

        spec_rep = P()
        fn = jax.shard_map(
            device_iter,
            mesh=self.mesh,
            in_specs=(spec_rep, spec_rep, P(self.axis), P(self.axis), P(self.axis)),
            out_specs=(spec_rep, spec_rep),
            check_vma=False,
        )
        return jax.jit(fn)

    def run(self, max_iters: int = 100) -> tuple[np.ndarray, int]:
        values, _ = self.program.init(self.n, None, self.g.out_deg)
        src = jnp.asarray(values.astype(np.float32))
        it = 0
        for it in range(1, max_iters + 1):
            src, changed = self._iter_fn(src, self._out_deg, self._cols, self._vals, self._rmap)
            if int(changed) == 0:
                break
        return np.asarray(src), it


def spmv_2d(x: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
            row_map: jnp.ndarray, semiring: str, mesh: Mesh,
            dst_axis: str = "data", src_axis: str = "model",
            use_pallas: bool | str = "auto") -> jnp.ndarray:
    """2-D partitioned SpMV: dst intervals over `dst_axis`, source ranges over
    `src_axis`.  Each device folds its (dst-block × src-range) ELL tile; a
    psum over `src_axis` combines partials (min-semirings use pmin via
    all_gather+fold).  x is sharded by source range; cols are *local* source
    indices.  Returns per-dst-interval partials sharded over `dst_axis`."""

    def local(x_blk, cols_b, vals_b, row_map_b):
        # x: [n] split over src_axis -> [n/S]; edge tensors: [D, S, R, W] -> [1, 1, R, W]
        cols_b, vals_b, row_map_b = cols_b[0, 0], vals_b[0, 0], row_map_b[0, 0]
        from repro.kernels.spmv.ops import ell_gather_fold
        partial_rows = ell_gather_fold(x_blk, cols_b, vals_b, semiring,
                                       use_pallas=use_pallas).reshape(-1)
        from repro.kernels.spmv.ref import segment_combine
        seg = segment_combine(partial_rows, row_map_b, cols_b.shape[0], semiring)
        if semiring.startswith("plus"):
            seg = jax.lax.psum(seg, src_axis)
        else:
            allseg = jax.lax.all_gather(seg, src_axis)  # [S, R]
            seg = jnp.min(allseg, axis=0)
        return seg[None]

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(src_axis), P(dst_axis, src_axis), P(dst_axis, src_axis), P(dst_axis, src_axis)),
        out_specs=P(dst_axis),
        check_vma=False,
    )
    return fn(x, cols, vals, row_map)
