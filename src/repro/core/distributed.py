"""Multi-device VSW: GraphMP's single-writer invariant on a device mesh.

GraphMP is single-machine; its no-atomics property — every in-edge of a
vertex lives in exactly one shard — extends directly to a device mesh:
partition destination intervals over the ``data`` axis (one writer device
per interval) and keep the source array device-resident, refreshed once per
iteration by an ``all_gather`` (the only collective; C|V| per iteration, the
same volume the paper writes to DRAM).  That is how GraphH (arxiv
1705.05595, same authors) scales the model to small clusters.

Two engines live here:

``ShardedVSWEngine`` — the production path (``EngineConfig.num_devices``,
env ``GRAPHMP_DEVICES``; ``GraphSession`` routes to it transparently).  It
subclasses ``VSWEngine`` and keeps the whole I/O story: shards stream from
the store through per-device ``CompressedShardCache`` partitions (one global
byte budget, split exactly — core/cache.py ``PartitionedShardCache``) and
per-device ``ShardPipeline`` prefetch lanes, with epoch pinning /
``ConcurrentMutationError`` intact.  Each iteration:

    x     = gather_transform(src)                  # replicated, no comm
    waves : device d folds its w-th scheduled shard (shard_map'ped
            gather -> SpMV -> post, single-writer per interval)
    merge : each device slices its own interval, a psum combines the
            changed-count, an all_gather exchanges the frontier blocks

Selective scheduling stays host-side: the per-shard Bloom filters are KBs
and REPLICATED, so every host computes the identical skip schedule with no
coordination (core/bloom.py).  Results are bitwise-identical to the
single-device engine at any device count — the same per-shard kernels run
with identity padding that cannot perturb f32 reductions (pow2 zero-pad on
the fold axis, masked rows routed to a discarded segment).

``DistributedVSW`` — the all-resident prototype kept for mesh-semantics
tests and as the minimal reference: the WHOLE edge set is partitioned onto
the mesh up front (``partition_for_mesh``), so there is no disk, cache or
prefetch path.  It honors ``EngineConfig.use_pallas`` and
``selective_threshold`` (replicated-Bloom device skipping) and documents the
I/O knobs as inapplicable rather than accepting-and-ignoring them.

The 2-D (src × dst) partition from DESIGN.md §2 maps a second mesh axis over
source ranges with a psum (min-fold for min-semirings) over partials;
implemented in ``spmv_2d`` and used by the graph-engine dry-run config.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.apps import VertexProgram, get_app
from repro.core.bloom import BloomFilter
from repro.core.cache import PartitionedShardCache
from repro.core.engine import EngineConfig, VSWEngine
from repro.core.pipeline import ShardPipeline
from repro.core.shards import (LANE, SUBLANE, ELLShard, build_csr_shards,
                               csr_to_ell, dequantize_edge_vals)
from repro.dist.context import make_data_mesh
from repro.kernels.spmv.ops import ell_spmv, ell_spmv_batch


# ---------------------------------------------------------------------------
def assign_shards(intervals: np.ndarray, shard_nnz, num_devices: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Contiguous, nnz-balanced shard -> device assignment.

    Returns ``(owner [P], bounds [D+1])``: device ``d`` owns the shards
    ``p`` with ``owner[p] == d``, whose destination intervals tile exactly
    ``[bounds[d], bounds[d+1])``.  Contiguity keeps every device's write
    region ONE interval — the single-writer invariant survives the mesh and
    the merge step needs only static slices; greedy nnz balancing keeps
    per-device SpMV work even.  A device may own zero shards (more devices
    than shards, or one giant shard): its bounds collapse and it runs dummy
    waves.
    """
    intervals = np.asarray(intervals, dtype=np.int64)
    P_ = len(intervals) - 1
    D = int(num_devices)
    if D < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    weights = np.asarray(shard_nnz, dtype=np.float64)
    if len(weights) != P_:
        raise ValueError(
            f"shard_nnz has {len(weights)} entries for {P_} shards")
    if weights.sum() <= 0:
        weights = np.ones(P_, dtype=np.float64)
    total = float(weights.sum())
    owner = np.zeros(P_, dtype=np.int64)
    cum, d = 0.0, 0
    for p in range(P_):
        owner[p] = d
        cum += weights[p]
        while d < D - 1 and cum >= total * (d + 1) / D:
            d += 1
    bounds = np.empty(D + 1, dtype=np.int64)
    bounds[D] = intervals[-1]
    for dd in range(D - 1, -1, -1):
        owned = np.nonzero(owner == dd)[0]
        bounds[dd] = intervals[owned[0]] if owned.size else bounds[dd + 1]
    bounds[0] = intervals[0]
    return owner, bounds


# ---------------------------------------------------------------------------
class ShardedVSWEngine(VSWEngine):
    """VSWEngine whose edge sweep drives ``config.num_devices`` devices.

    The base class owns everything host-side (convergence, checkpoints,
    selective scheduling, epoch pinning); this subclass swaps the per-
    iteration internals through the documented seams:

    * ``_fetch_shard`` routes each shard to its owning device's cache
      partition (``PartitionedShardCache`` — one global budget, split);
    * ``_make_pipeline`` builds one prefetch lane per device
      (``ShardPipeline`` each, staging host-side on the worker thread);
    * ``_sweep`` splits the Bloom-scheduled shard list by owner and runs it
      in WAVES: wave ``w`` stacks each device's ``w``-th shard into one
      ``[D, R, W]`` batch, a ``shard_map``'ped step folds all D shards
      concurrently (single-writer: device ``d`` only writes its interval),
      then a merge step psums the changed-count and ``all_gather``s the
      per-device frontier blocks back into the replicated value array;
    * ``_io_marks`` / ``_io_stats`` account disk/stall/fetch per device and
      as honest sums (``IterationStats.device_*`` tuples).

    Bitwise identity with the single-device engine holds by construction:
    the same ELL kernels run on the same shards; wave padding appends only
    reduce-identity material (pow2 zero-padding on the fold axis, padded
    ELL rows routed to a masked or dropped segment) and the merge takes
    each row from exactly its owner device.
    """

    def __init__(self, store, program, config=None, *, cache=None, **kw):
        cfg = config if isinstance(config, EngineConfig) else EngineConfig()
        D = cfg.num_devices
        self._num_devices = D
        self._axis = "data"
        self._mesh = make_data_mesh(D, self._axis)
        shard_meta = store.properties["shards"]
        nnz = [int(m.get("nnz", 0)) for m in shard_meta]
        self._owner, self._bounds = assign_shards(
            np.asarray(store.intervals), nnz, D)
        self._block_lens = [int(self._bounds[d + 1] - self._bounds[d])
                            for d in range(D)]
        self._per_max = max(self._block_lens, default=1) or 1
        if not (isinstance(cache, PartitionedShardCache)
                and cache.num_partitions == D
                and np.array_equal(cache.owner, self._owner)):
            # sessions configured with num_devices build the partitioned
            # cache up front and share it; a per-run config override (or
            # direct construction) gets a private partitioned cache instead
            cache = PartitionedShardCache(
                store, self._owner, D, mode=cfg.cache_mode,
                budget_bytes=cfg.cache_budget_bytes,
                hot_fraction=cfg.cache_hot_fraction,
                promote_after=cfg.cache_promote_after)
        super().__init__(store, program, config, cache=cache, **kw)
        # the merge step slices [bounds[d], bounds[d] + per_max) and dummy
        # waves write into [n, n + R); grow the vertex padding to cover both
        need = self.n + self._per_max
        if need > self.n_pad:
            self.n_pad = need
            self._out_deg_dev = jnp.asarray(
                np.pad(self.out_deg,
                       (0, self.n_pad - self.n)).astype(np.float32))

    # -- construction seams ---------------------------------------------
    def _fetch_shard(self, p: int) -> ELLShard:
        # self.cache is the PartitionedShardCache: owner-routed
        return self.cache.get(p)

    def _make_pipeline(self):
        # one prefetch lane per device; lane d streams only device d's
        # shards, each fetch landing in that device's cache partition
        self._lanes = [
            ShardPipeline(self._get_shard, depth=self.config.prefetch_depth,
                          stage=self._stage, nbytes=ELLShard.decoded_nbytes)
            for _ in range(self._num_devices)
        ]
        return None  # per-lane stats replace the single self._pipeline

    def _stage(self, shard: ELLShard):
        """Host-side staging only (mmap page-in + copy on the worker
        thread); the device transfer happens at wave assembly, where the
        wave's common [D, R, W] layout is known."""
        return (self._materialize(shard.cols), self._materialize(shard.vals),
                self._materialize(shard.row_map),
                np.array([shard.val_scale, shard.val_zero], dtype=np.float32))

    # -- compiled steps ---------------------------------------------------
    def _build_steps(self) -> None:
        super()._build_steps()
        program, n, D = self.program, self.n, self._num_devices
        semiring, use_pallas = program.semiring, self.use_pallas
        ax, mesh = self._axis, self._mesh
        rep, shd = P(), P(ax)
        B, lens, per_max = self._bounds, self._block_lens, self._per_max
        starts_c = jnp.asarray(B[:D].astype(np.int32))
        ends_c = jnp.asarray(B[1:].astype(np.int32))

        # replicated src broadcast into the per-device [D, n_pad(, K)] dst
        self._dst_init = jax.jit(
            lambda s: jnp.broadcast_to(s[None], (D,) + s.shape),
            out_shardings=NamedSharding(mesh, shd))

        if self.batched:
            has_aux = getattr(program, "make_aux", None) is not None
            wants_it = getattr(program, "wants_iteration", False)

            def wave(dst, x, src, aux, it, cols, vals, row_map, qp, start,
                     num_rows):
                dst, cols, vals, row_map = dst[0], cols[0], vals[0], row_map[0]
                qp, start, num_rows = qp[0], start[0], num_rows[0]
                R, K = cols.shape[0], src.shape[1]
                seg = ell_spmv_batch(x, cols, vals, row_map, R, semiring,
                                     use_pallas=use_pallas, qparams=qp)
                old_slice = jax.lax.dynamic_slice(src, (start, 0), (R, K))
                rows = start + jnp.arange(R)
                aux_slice = (jax.lax.dynamic_slice(aux, (start, 0), (R, K))
                             if has_aux else None)
                if wants_it:
                    new_slice = program.post(seg, old_slice, rows, n,
                                             aux_slice, it)
                else:
                    new_slice = program.post(seg, old_slice, rows, n,
                                             aux_slice)
                new_slice = new_slice.astype(dst.dtype)
                keep = (jnp.arange(R) < num_rows)[:, None]
                new_slice = jnp.where(keep, new_slice, old_slice)
                return jax.lax.dynamic_update_slice(dst, new_slice,
                                                    (start, 0))[None]

            wave_in = (shd, rep, rep, rep, rep, shd, shd, shd, shd, shd, shd)

            def merge(dst, src):
                dstl = dst[0]
                d = jax.lax.axis_index(ax)
                b = starts_c[d]
                K = src.shape[1]
                own = jax.lax.dynamic_slice(dstl, (b, 0), (per_max, K))
                old = jax.lax.dynamic_slice(src, (b, 0), (per_max, K))
                real = (b + jnp.arange(per_max) < ends_c[d])[:, None]
                chm = program.changed(own, old) & real
                cnt = jax.lax.psum(jnp.sum(chm.astype(jnp.int32)), ax)
                gathered = jax.lax.all_gather(own, ax)  # [D, per_max, K]
                new_full = src
                for dd in range(D):
                    if lens[dd]:
                        new_full = jax.lax.dynamic_update_slice(
                            new_full, gathered[dd, : lens[dd]],
                            (int(B[dd]), 0))
                return new_full, cnt
        else:
            def wave(dst, x, src, cols, vals, row_map, qp, start, num_rows):
                dst, cols, vals, row_map = dst[0], cols[0], vals[0], row_map[0]
                qp, start, num_rows = qp[0], start[0], num_rows[0]
                R = cols.shape[0]
                seg = ell_spmv(x, cols, vals, row_map, R, semiring,
                               use_pallas=use_pallas, qparams=qp)
                old_slice = jax.lax.dynamic_slice(src, (start,), (R,))
                new_slice = program.post(seg, old_slice, n).astype(dst.dtype)
                keep = jnp.arange(R) < num_rows
                new_slice = jnp.where(keep, new_slice, old_slice)
                return jax.lax.dynamic_update_slice(dst, new_slice,
                                                    (start,))[None]

            wave_in = (shd, rep, rep, shd, shd, shd, shd, shd, shd)

            def merge(dst, src):
                dstl = dst[0]
                d = jax.lax.axis_index(ax)
                b = starts_c[d]
                own = jax.lax.dynamic_slice(dstl, (b,), (per_max,))
                old = jax.lax.dynamic_slice(src, (b,), (per_max,))
                real = b + jnp.arange(per_max) < ends_c[d]
                chm = program.changed(own, old) & real
                cnt = jax.lax.psum(jnp.sum(chm.astype(jnp.int32)), ax)
                gathered = jax.lax.all_gather(own, ax)  # [D, per_max]
                new_full = src
                for dd in range(D):
                    if lens[dd]:
                        new_full = jax.lax.dynamic_update_slice(
                            new_full, gathered[dd, : lens[dd]], (int(B[dd]),))
                return new_full, cnt

        self._wave_step = jax.jit(
            jax.shard_map(wave, mesh=mesh, in_specs=wave_in, out_specs=shd,
                          check_vma=False),
            donate_argnums=(0,))
        self._merge_step = jax.jit(
            jax.shard_map(merge, mesh=mesh, in_specs=(shd, rep),
                          out_specs=(rep, rep), check_vma=False),
            donate_argnums=(0,))

    # -- per-iteration seams ----------------------------------------------
    def _assemble_wave(self, entries):
        """Stack one shard per device (or a dummy) into the wave's common
        [D, R, W] layout and place it sharded over the mesh.

        Padding is reduce-identity by construction, so results stay bitwise
        equal to running each shard at its own bucketed shape: cols -1
        (masked out of the fold; zero-padding a pow2-lane f32 reduction
        adds +0.0 per lane accumulator), padded ELL rows routed to segment
        min(num_rows, R) — a keep-masked destination row when it exists,
        otherwise out of range and dropped by the segment combine.  Dummies
        (a device with no shard this wave) write their restored old values
        at ``start = n``, i.e. into the padding region, so they cannot
        revert a real row updated by an earlier wave.
        """
        D = self._num_devices
        shards = [e[1] for e in entries if e is not None]
        R = max((s.cols.shape[0] for s in shards), default=SUBLANE)
        W = max((s.cols.shape[1] for s in shards), default=LANE)
        # one vals dtype per wave (the shard_map step compiles per dtype); a
        # mixed wave — possible mid-migration of a store — dequantizes to
        # float32 on the host and ships identity qparams instead
        vdts = {e[2][1].dtype for e in entries if e is not None}
        mixed = len(vdts) > 1
        vdt = np.float32 if (mixed or not vdts) else vdts.pop()
        cols = np.full((D, R, W), -1, dtype=np.int32)
        vals = np.zeros((D, R, W), dtype=vdt)
        rmap = np.zeros((D, R), dtype=np.int32)
        qp = np.tile(np.array([1.0, 0.0], dtype=np.float32), (D, 1))
        start = np.full(D, self.n, dtype=np.int32)
        nrows = np.zeros(D, dtype=np.int32)
        for d, e in enumerate(entries):
            if e is None:
                continue
            _p, shard, staged = e
            c, v, rm, q = staged
            if mixed and v.dtype != np.float32:
                v = dequantize_edge_vals(v, float(q[0]), float(q[1]))
                q = np.array([1.0, 0.0], dtype=np.float32)
            r, w = c.shape
            nr = int(shard.end_vertex - shard.start_vertex)
            cols[d, :r, :w] = c
            vals[d, :r, :w] = v
            rmap[d, :r] = rm
            rmap[d, r:] = min(nr, R)
            qp[d] = q
            start[d] = shard.start_vertex
            nrows[d] = nr
        sharding = NamedSharding(self._mesh, P(self._axis))
        return tuple(jax.device_put(a, sharding)
                     for a in (cols, vals, rmap, qp, start, nrows))

    def _sweep(self, x, src, aux_dev, it_dev, schedule, epoch_check):
        D = self._num_devices
        scheds = [[p for p in schedule if self._owner[p] == d]
                  for d in range(D)]
        waves = max(len(s) for s in scheds)
        dst = self._dst_init(src)
        streams = [self._lanes[d].stream(scheds[d], check=epoch_check)
                   for d in range(D)]
        try:
            for w in range(waves):
                entries = [next(streams[d]) if w < len(scheds[d]) else None
                           for d in range(D)]
                tail = self._assemble_wave(entries)
                if self.batched:
                    dst = self._wave_step(dst, x, src, aux_dev, it_dev, *tail)
                else:
                    dst = self._wave_step(dst, x, src, *tail)
        finally:
            for s in streams:
                s.close()  # run pipeline cleanup (reap prefetch workers)
        new_src, changed_count = self._merge_step(dst, src)
        if int(changed_count) == 0:
            # the psum'd count short-circuits the full mask pull
            shape = ((self.n, src.shape[1]) if self.batched else (self.n,))
            changed = np.zeros(shape, dtype=bool)
        else:
            changed = np.asarray(self._changed_fn(new_src, src))
        return new_src, changed

    def _io_marks(self):
        return ([(c.stats.disk_bytes, c.stats.hits, c.stats.misses,
                  c.stats.decode_seconds_saved) for c in self.cache.parts],
                [(l.stats.stall_seconds, l.stats.fetch_seconds)
                 for l in self._lanes])

    def _io_stats(self, marks) -> dict:
        cache_marks, lane_marks = marks
        d_disk, d_saved, hits, total = [], [], 0, 0
        for part, (disk0, hits0, misses0, saved0) in zip(self.cache.parts,
                                                         cache_marks):
            s = part.stats
            d_disk.append(s.disk_bytes - disk0)
            d_saved.append(s.decode_seconds_saved - saved0)
            hits += s.hits - hits0
            total += (s.hits - hits0) + (s.misses - misses0)
        d_stall = [l.stats.stall_seconds - s0
                   for l, (s0, _f0) in zip(self._lanes, lane_marks)]
        d_fetch = [l.stats.fetch_seconds - f0
                   for l, (_s0, f0) in zip(self._lanes, lane_marks)]
        return dict(
            disk_bytes=sum(d_disk),
            cache_hit_ratio=hits / total if total else 0.0,
            # lanes are drained on the one consumer thread, so its total
            # blocked time is the SUM of per-lane stalls; fetch work happens
            # per worker and also sums
            stall_seconds=sum(d_stall),
            fetch_seconds=sum(d_fetch),
            decode_seconds_saved=sum(d_saved),
            device_disk_bytes=tuple(d_disk),
            device_stall_seconds=tuple(d_stall),
            device_fetch_seconds=tuple(d_fetch),
        )


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DeviceShardedGraph:
    """Edges repartitioned so device d owns destination interval d (1-D).

    ``num_vertices`` is the TRUE vertex count; the device intervals tile
    ``padded_num_vertices`` (the next multiple of the device count), and
    every consumer masks the padding rows out of init/post/changed.
    """

    num_vertices: int          # true |V|
    padded_num_vertices: int   # |V| rounded up to a multiple of num_devices
    num_edges: int
    cols: np.ndarray           # [D, R, W] int32 (per-device ELL, common shape)
    vals: np.ndarray           # [D, R, W] float32
    row_map: np.ndarray        # [D, R] int32 (local row within the device interval)
    out_deg: np.ndarray        # [padded_num_vertices] int64 (0 on padding)
    rows_per_device: int       # interval length padded_num_vertices / D
    blooms: list               # per-device source-vertex BloomFilters (replicated)


def partition_for_mesh(
    src: np.ndarray, dst: np.ndarray, num_vertices: int, num_devices: int,
    val: np.ndarray | None = None, ell_max_width: int = 256,
) -> DeviceShardedGraph:
    n_pad = ((num_vertices + num_devices - 1) // num_devices) * num_devices
    per = n_pad // num_devices
    shards = build_csr_shards(src, dst, n_pad, threshold_edge_num=1 << 62, val=val)
    # build_csr_shards with huge threshold yields one shard; re-cut at device bounds
    csr = shards[0]
    ells: list[ELLShard] = []
    blooms: list[BloomFilter] = []
    for d in range(num_devices):
        lo, hi = d * per, (d + 1) * per
        sub = dataclasses.replace(
            csr,
            shard_id=d,
            start_vertex=lo,
            end_vertex=hi,
            row=csr.row[lo : hi + 1] - csr.row[lo],
            col=csr.col[csr.row[lo] : csr.row[hi]],
            val=None if csr.val is None else csr.val[csr.row[lo] : csr.row[hi]],
        )
        ells.append(csr_to_ell(sub, max_width=ell_max_width))
        sources = np.unique(sub.col)
        blooms.append(BloomFilter.build(
            sources, num_bits=BloomFilter.sized_for(sources.size)))
    R = max(((e.shape[0] + SUBLANE - 1) // SUBLANE) * SUBLANE for e in ells)
    W = max(e.shape[1] for e in ells)
    cols = np.full((num_devices, R, W), -1, dtype=np.int32)
    vals = np.zeros((num_devices, R, W), dtype=np.float32)
    row_map = np.zeros((num_devices, R), dtype=np.int32)
    for d, e in enumerate(ells):
        r, w = e.shape
        cols[d, :r, :w] = e.cols
        vals[d, :r, :w] = e.vals
        row_map[d, :r] = e.row_map
    out_deg = np.bincount(src, minlength=n_pad).astype(np.int64)
    return DeviceShardedGraph(
        num_vertices=int(num_vertices), padded_num_vertices=n_pad,
        num_edges=len(src), cols=cols, vals=vals,
        row_map=row_map, out_deg=out_deg, rows_per_device=per, blooms=blooms,
    )


class DistributedVSW:
    """1-D distributed VSW prototype: the WHOLE graph resident on the mesh.

    The minimal mesh-semantics reference (and oracle target for
    ``ShardedVSWEngine``): ``partition_for_mesh`` places every edge on its
    owner device up front, so an iteration is one ``shard_map``'ped
    gather -> SpMV -> post with an ``all_gather`` frontier exchange and a
    psum'd changed-count — no disk, no cache, no prefetch.

    ``config`` (an ``EngineConfig``) shares the session-level tuning
    surface.  Honored fields: ``use_pallas`` (SpMV backend) and
    ``selective_threshold`` — below it, the replicated per-device Bloom
    filters (``DeviceShardedGraph.blooms``) gate which devices compute at
    all (a skipped device keeps its interval unchanged); every host probes
    the same filters, so the schedule needs no coordination.  The I/O
    fields (``cache_*``, ``prefetch_depth``, ``preload``) do not apply —
    there is no storage path here to tune; use ``ShardedVSWEngine`` (via
    ``GraphSession`` with ``num_devices > 1``) for the streaming engine.

    Padding correctness: vertex ids in ``[num_vertices,
    padded_num_vertices)`` exist only to even the device intervals.  They
    are initialized to zero (never by ``program.init``, which sees the TRUE
    ``n``), masked out of the changed-count, and sliced off the returned
    values, so they can neither absorb PageRank mass nor join the CC label
    space.
    """

    def __init__(self, graph: DeviceShardedGraph,
                 program: VertexProgram | str,
                 mesh: Mesh, axis: str = "data",
                 use_pallas: bool | str = "auto",
                 config: EngineConfig | None = None):
        if isinstance(program, str):
            program = get_app(program)
        self.g = graph
        self.program = program
        self.mesh = mesh
        self.axis = axis
        self.num_devices = graph.cols.shape[0]
        self.selective_threshold = EngineConfig.selective_threshold
        if config is not None:
            use_pallas = config.use_pallas
            self.selective_threshold = config.selective_threshold
        self.use_pallas = use_pallas
        self.n = graph.num_vertices
        self.n_pad = graph.padded_num_vertices
        edge_spec = P(axis)
        self._cols = jax.device_put(graph.cols, NamedSharding(mesh, edge_spec))
        self._vals = jax.device_put(graph.vals, NamedSharding(mesh, edge_spec))
        self._rmap = jax.device_put(graph.row_map, NamedSharding(mesh, edge_spec))
        self._out_deg = jnp.asarray(graph.out_deg.astype(np.float32))
        self._iter_fn = self._build_iter()

    def _build_iter(self):
        program, n, per = self.program, self.n, self.g.rows_per_device
        semiring, use_pallas, axis = program.semiring, self.use_pallas, self.axis

        def device_iter(src_full, out_deg, cols, vals, row_map, flags):
            # shard_map gives per-device blocks with a leading length-1 axis
            cols, vals, row_map, flag = cols[0], vals[0], row_map[0], flags[0]
            x = program.gather_transform(src_full, out_deg)
            R = cols.shape[0]
            seg = ell_spmv(x, cols, vals, row_map, R, semiring, use_pallas=use_pallas)
            d = jax.lax.axis_index(axis)
            old_own = jax.lax.dynamic_slice(src_full, (d * per,), (per,))
            new_own = program.post(seg[:per], old_own, n).astype(src_full.dtype)
            # Bloom-skipped device: keep the old interval verbatim
            new_own = jnp.where(flag != 0, new_own, old_own)
            # padding rows (ids >= n) never count as changed
            real = d * per + jnp.arange(per) < n
            changed_own = program.changed(new_own, old_own) & real
            changed = jnp.sum(changed_own.astype(jnp.int32))
            new_full = jax.lax.all_gather(new_own, axis, tiled=True)
            changed_full = jax.lax.all_gather(changed_own, axis, tiled=True)
            changed_total = jax.lax.psum(changed, axis)
            return new_full, changed_full, changed_total

        spec_rep = P()
        fn = jax.shard_map(
            device_iter,
            mesh=self.mesh,
            in_specs=(spec_rep, spec_rep, P(self.axis), P(self.axis),
                      P(self.axis), P(self.axis)),
            out_specs=(spec_rep, spec_rep, spec_rep),
            check_vma=False,
        )
        return jax.jit(fn)

    def _schedule_flags(self, active_ids: np.ndarray | None,
                        active_ratio: float) -> np.ndarray:
        """Replicated-Bloom device schedule (host-side, deterministic)."""
        if active_ids is None or active_ratio >= self.selective_threshold:
            return np.ones(self.num_devices, dtype=bool)
        return np.array([b.might_contain_any(active_ids)
                         for b in self.g.blooms], dtype=bool)

    def run(self, max_iters: int = 100) -> tuple[np.ndarray, int]:
        n = self.n
        values, active = self.program.init(n, None, self.g.out_deg[:n])
        src = jnp.asarray(
            np.pad(values.astype(np.float32), (0, self.n_pad - n)))
        active_ids = np.nonzero(np.asarray(active, dtype=bool))[0]
        active_ratio = active_ids.size / max(n, 1)
        flag_sharding = NamedSharding(self.mesh, P(self.axis))
        it_done = 0
        for it in range(1, max_iters + 1):
            flags = self._schedule_flags(active_ids, active_ratio)
            if not flags.any():
                break  # every device Bloom-skipped: nothing can change
            flags_dev = jax.device_put(flags.astype(np.int32), flag_sharding)
            src, changed_full, changed_total = self._iter_fn(
                src, self._out_deg, self._cols, self._vals, self._rmap,
                flags_dev)
            it_done = it
            if int(changed_total) == 0:
                break
            mask = np.asarray(changed_full)[:n]
            active_ids = np.nonzero(mask)[0]
            active_ratio = active_ids.size / max(n, 1)
        return np.asarray(src)[:n], it_done


def spmv_2d(x: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
            row_map: jnp.ndarray, semiring: str, mesh: Mesh,
            dst_axis: str = "data", src_axis: str = "model",
            use_pallas: bool | str = "auto") -> jnp.ndarray:
    """2-D partitioned SpMV: dst intervals over `dst_axis`, source ranges over
    `src_axis`.  Each device folds its (dst-block × src-range) ELL tile; a
    psum over `src_axis` combines partials (min-semirings use pmin via
    all_gather+fold).  x is sharded by source range; cols are *local* source
    indices.  Returns per-dst-interval partials sharded over `dst_axis`."""

    def local(x_blk, cols_b, vals_b, row_map_b):
        # x: [n] split over src_axis -> [n/S]; edge tensors: [D, S, R, W] -> [1, 1, R, W]
        cols_b, vals_b, row_map_b = cols_b[0, 0], vals_b[0, 0], row_map_b[0, 0]
        from repro.kernels.spmv.ops import ell_gather_fold
        partial_rows = ell_gather_fold(x_blk, cols_b, vals_b, semiring,
                                       use_pallas=use_pallas).reshape(-1)
        from repro.kernels.spmv.ref import segment_combine
        seg = segment_combine(partial_rows, row_map_b, cols_b.shape[0], semiring)
        from repro.core.semiring import SEMIRINGS
        sem = SEMIRINGS[semiring]
        if sem.is_plus:
            seg = jax.lax.psum(seg, src_axis)
        else:
            allseg = jax.lax.all_gather(seg, src_axis)  # [S, R]
            seg = (jnp.max if sem.is_max else jnp.min)(allseg, axis=0)
        return seg[None]

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(src_axis), P(dst_axis, src_axis), P(dst_axis, src_axis), P(dst_axis, src_axis)),
        out_specs=P(dst_axis),
        check_vma=False,
    )
    return fn(x, cols, vals, row_map)
