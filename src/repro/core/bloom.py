"""Bloom filters for selective scheduling (paper §2.4.1).

One filter per shard, built over the shard's *source* vertices.  At the start
of an iteration with active-vertex ratio < threshold, a shard is loaded and
processed only if its filter might contain an active vertex.

Bloom filters never produce false negatives, so skipping is always safe
(an inactive shard by filter evidence is truly unable to produce updates);
false positives only cost an unnecessary load — exactly the paper's contract.
Property-tested in tests/test_bloom.py.

Probing is host-side numpy everywhere, including the multi-device engines:
the filters are KBs, so they are simply REPLICATED — every host probes the
same filters against the same frontier and derives the identical skip
schedule without any cross-device coordination (see core/distributed.py).
There is deliberately no on-device (jnp) probe path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# multiply-shift hash constants (odd, 64-bit), one per hash function
_HASH_MULTS = np.array(
    [0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9, 0x27D4EB2F165667C5],
    dtype=np.uint64,
)


def _hash(ids: np.ndarray, k: int, num_bits: int) -> np.ndarray:
    """[k, n] bit positions for each id under k multiply-shift hashes."""
    x = ids.astype(np.uint64)[None, :] * _HASH_MULTS[:k, None]
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    return (x % np.uint64(num_bits)).astype(np.int64)


@dataclasses.dataclass
class BloomFilter:
    bits: np.ndarray  # uint8 bitmask array, length num_bits/8
    num_bits: int
    num_hashes: int

    @classmethod
    def build(cls, ids: np.ndarray, num_bits: int = 1 << 16, num_hashes: int = 3) -> "BloomFilter":
        num_bits = max(64, int(num_bits))
        bits = np.zeros(num_bits // 8, dtype=np.uint8)
        if ids.size:
            pos = _hash(np.asarray(ids), num_hashes, num_bits).ravel()
            np.bitwise_or.at(bits, pos // 8, (1 << (pos % 8)).astype(np.uint8))
        return cls(bits=bits, num_bits=num_bits, num_hashes=num_hashes)

    @classmethod
    def sized_for(cls, n_items: int, fp_rate: float = 0.01, num_hashes: int = 3) -> int:
        """Bits needed for ~fp_rate with num_hashes hashes (rounded to pow2)."""
        if n_items <= 0:
            return 64
        # m = -k*n / ln(1 - p^{1/k})
        m = -num_hashes * n_items / np.log(1.0 - fp_rate ** (1.0 / num_hashes))
        return 1 << int(np.ceil(np.log2(max(m, 64))))

    def might_contain(self, ids: np.ndarray) -> np.ndarray:
        """[n] bool — per-id membership test (no false negatives)."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return np.zeros(0, dtype=bool)
        pos = _hash(ids, self.num_hashes, self.num_bits)  # [k, n]
        hit = (self.bits[pos // 8] >> (pos % 8).astype(np.uint8)) & 1
        return hit.all(axis=0).astype(bool)

    def might_contain_any(self, ids: np.ndarray) -> bool:
        """True iff any id might be in the set (the shard-skip predicate)."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return False
        # chunk to bound memory on big frontiers
        for lo in range(0, ids.size, 1 << 20):
            if self.might_contain(ids[lo : lo + (1 << 20)]).any():
                return True
        return False

    def nbytes(self) -> int:
        return int(self.bits.nbytes)
