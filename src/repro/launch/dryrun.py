import os

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"
_existing_xla_flags = os.environ.get("XLA_FLAGS", "")
if _DEVICE_COUNT_FLAG not in _existing_xla_flags:
    os.environ["XLA_FLAGS"] = (
        (_existing_xla_flags + " " if _existing_xla_flags else "")
        + f"{_DEVICE_COUNT_FLAG}=512")
# The lines above MUST run before any other import (jax locks the device count
# on first init).  This module is the ONLY place that forces 512 placeholder
# devices — tests and benches see the real device count.  User- or CI-provided
# XLA_FLAGS are APPENDED to, never overwritten, and an existing device-count
# flag (e.g. a multi-device CI leg) always wins.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * proof of compilation on the production meshes (16×16 single-pod and
    2×16×16 multi-pod) — sharding mismatches / unsupported collectives fail
    here;
  * memory_analysis() of the real (scan-over-layers) program;
  * roofline terms via the delta method: the same program is lowered with
    repeat counts r=1 and r=2 and ALL scans unrolled; per-layer-group cost =
    cost(r2) - cost(r1); totals extrapolate to the full depth.  This corrects
    XLA's cost model counting loop bodies once (EXPERIMENTS.md
    §Roofline-method; verified in tests/test_roofline_method.py).
  * collective bytes parsed from the unrolled HLO (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute result-shape bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, ARCH_IDS
from repro.configs.base import ArchConfig
from repro.dist.context import make_rules, ShardCtx
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, ShapeSpec, batch_shardings, batch_specs,
                                 cache_shardings, cell_applicable,
                                 decode_input_specs)
from repro.models.model import Model, build_model, layer_groups
from repro.models.nn import Param
from repro.models.xlstm import slstm_step_flops
from repro.train import OptConfig, make_init_state, make_train_step

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1,
}

_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind (per-device program)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out


# --------------------------------------------------------------------------
# layer-count manipulation for the delta method
# --------------------------------------------------------------------------
def cfg_with_repeat(cfg: ArchConfig, r: int) -> ArchConfig:
    if cfg.xlstm is not None:
        return dataclasses.replace(cfg, num_layers=r * cfg.xlstm.slstm_every)
    if cfg.attn_every:
        return dataclasses.replace(cfg, num_layers=r * cfg.attn_every)
    kw = {"num_layers": (cfg.moe.first_k_dense + r) if (cfg.moe and cfg.moe.first_k_dense)
          else r}
    if cfg.encoder_layers:
        kw["encoder_layers"] = r
    return dataclasses.replace(cfg, **kw)


def full_repeat(cfg: ArchConfig) -> int:
    if cfg.xlstm is not None:
        return cfg.num_layers // cfg.xlstm.slstm_every
    if cfg.attn_every:
        return cfg.num_layers // cfg.attn_every
    if cfg.moe and cfg.moe.first_k_dense:
        return cfg.num_layers - cfg.moe.first_k_dense
    return cfg.num_layers


# --------------------------------------------------------------------------
# parameter accounting
# --------------------------------------------------------------------------
def param_counts(model: Model) -> dict[str, float]:
    cfg = model.cfg
    params = model.abstract_params()
    vals = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda p: p.value, params,
                               is_leaf=lambda x: isinstance(x, Param)))
    total = sum(int(np.prod(v.shape)) for v in vals)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]  # leaves are values
    embed = sum(int(np.prod(l.shape)) for p, l in flat
                if "embed" in str(p) or "unembed" in str(p))
    expert = sum(int(np.prod(l.shape)) for p, l in flat
                 if re.search(r"w_(up|down|gate)", str(p)) and "moe" in str(p)
                 and "shared" not in str(p))
    active = total - embed
    if cfg.moe is not None and expert:
        active -= expert * (1.0 - cfg.moe.top_k / cfg.moe.num_experts)
    return {"total": total, "embedding": embed, "expert": expert,
            "active_nonembed": active}


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------
def state_shardings(state_abs, ctx: ShardCtx):
    def leaf(x):
        if isinstance(x, Param):
            return ctx.param_sharding(x)
        return ctx.logical_sharding(())

    return jax.tree_util.tree_map(leaf, state_abs,
                                  is_leaf=lambda x: isinstance(x, Param))


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *, unroll: bool,
               opt_name: str, ep_mode: str = "a2a", serve_fsdp: bool = True,
               remat_policy: str = "nothing", ssm_dtype: str = "float32",
               capacity_factor: float = 0.0):
    """Returns (lowered, compiled_fn_or_None_deferred) for one cell."""
    if capacity_factor and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor))
    ctx = make_rules(mesh, cfg, long_context=shape.long, ep_mode=ep_mode,
                     serve_fsdp=(serve_fsdp or shape.kind == "train"))
    model = build_model(cfg, ctx, unroll=unroll, remat=(shape.kind == "train"),
                        long_context=shape.long, remat_policy=remat_policy,
                        ssm_dtype=ssm_dtype)
    key = jax.random.PRNGKey(0)
    params_abs = model.abstract_params(key)
    params_sh = state_shardings(params_abs, ctx)
    if shape.kind == "train":
        opt_cfg = OptConfig(name=opt_name)
        init = make_init_state(model, opt_cfg)
        state_abs = jax.eval_shape(init, key)
        st_sh = state_shardings(state_abs, ctx)
        step = make_train_step(model, opt_cfg)
        b_abs = batch_specs(cfg, shape)
        b_sh = batch_shardings(cfg, shape, ctx)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
        lowered = fn.lower(state_abs, b_abs)
    elif shape.kind == "prefill":
        b_abs = batch_specs(cfg, shape)
        b_sh = batch_shardings(cfg, shape, ctx)

        def prefill_fn(params, batch):
            logits, caches, enc = model.prefill(params, batch, shape.seq_len)
            return logits, caches

        fn = jax.jit(prefill_fn, in_shardings=(params_sh, b_sh))
        lowered = fn.lower(params_abs, b_abs)
    else:  # decode
        caches_abs, toks_abs, pos_abs, enc_abs = decode_input_specs(model, cfg, shape)
        c_sh = cache_shardings(caches_abs, cfg, ctx)
        t_sh = ctx.logical_sharding(("batch", None))
        rep = ctx.logical_sharding(())

        def decode_fn(params, caches, tokens, pos, enc_out):
            return model.decode_step(params, caches, tokens, pos, enc_out=enc_out)

        enc_sh = ctx.logical_sharding(("batch", None, None)) if enc_abs is not None else None
        fn = jax.jit(decode_fn,
                     in_shardings=(params_sh, c_sh, t_sh, rep, enc_sh),
                     donate_argnums=(1,))
        lowered = fn.lower(params_abs, caches_abs, toks_abs, pos_abs, enc_abs)
    return model, lowered


def analyze_compiled(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception:
        pass
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": mem,
        "collectives": collective_bytes(compiled.as_text()),
    }


def slstm_flops_correction(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """sLSTM recurrence FLOPs are invisible to the unrolled delta (sequential
    loop); add step-FLOPs × steps × layers analytically."""
    if cfg.xlstm is None or shape.kind == "decode":
        return 0.0
    n_slstm = cfg.num_layers // cfg.xlstm.slstm_every
    steps = shape.seq_len * shape.global_batch
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd ≈ 3× fwd
    return mult * n_slstm * steps * slstm_step_flops(cfg.d_model, cfg.num_heads)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, force: bool = False, skip_delta: bool = False,
             ep_mode: str = "a2a", serve_fsdp: bool = True,
             remat_policy: str = "nothing", ssm_dtype: str = "float32",
             capacity_factor: float = 0.0, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    if tag:
        mesh_tag = f"{mesh_tag}__{tag}"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        cached = json.loads(out_path.read_text())
        if cached.get("ok") or not cached.get("applicable", True):
            return cached  # only reuse successful/skip cells; retry failures
    cfg = get_config(arch)
    ok, reason = cell_applicable(cfg, shape)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                    "applicable": ok, "skip_reason": reason}
    if not ok:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(record, indent=2))
        return record
    opt_name = "adafactor" if cfg.moe and cfg.moe.num_experts >= 64 else "adamw"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    record["variant"] = {"ep_mode": ep_mode, "serve_fsdp": serve_fsdp,
                         "remat_policy": remat_policy, "ssm_dtype": ssm_dtype,
                         "capacity_factor": capacity_factor}
    t0 = time.time()
    kw = dict(opt_name=opt_name, ep_mode=ep_mode, serve_fsdp=serve_fsdp,
              remat_policy=remat_policy, ssm_dtype=ssm_dtype,
              capacity_factor=capacity_factor)
    try:
        # 1) the real scanned program: compile proof + memory analysis
        model, lowered = lower_cell(cfg, shape, mesh, unroll=False, **kw)
        compiled = lowered.compile()
        full = analyze_compiled(compiled)
        record["compile_seconds"] = time.time() - t0
        record["full_program"] = full
        record["param_counts"] = param_counts(model)
        # 2) delta method on unrolled r=1 / r=2 programs
        if not skip_delta:
            # xlstm long-sequence cells: unrolling S/chunk mLSTM chunks is
            # compile-prohibitive; every per-layer term is linear in S at
            # fixed chunk (intra-chunk work is S·Q, projections are S·d), so
            # lower the deltas at S=4096 and scale linearly.  Verified linear
            # in tests/test_roofline_method.py-style checks at small S.
            seq_scale = 1.0
            d_shape = shape
            if cfg.xlstm is not None and shape.kind != "decode" \
                    and shape.seq_len > 1024:
                seq_scale = shape.seq_len / 1024
                d_shape = dataclasses.replace(shape, seq_len=1024)
            deltas = {}
            for r in (1, 2):
                c_r = cfg_with_repeat(cfg, r)
                _, low_r = lower_cell(c_r, d_shape, mesh, unroll=True, **kw)
                deltas[r] = analyze_compiled(low_r.compile())
            R = full_repeat(cfg)

            def extrap(key):
                d1, d2 = deltas[1][key], deltas[2][key]
                return (d1 + (R - 1) * (d2 - d1)) * seq_scale

            flops = extrap("flops") + slstm_flops_correction(cfg, shape)
            bytes_acc = extrap("bytes_accessed")
            colls = {}
            for kind in set(deltas[1]["collectives"]) | set(deltas[2]["collectives"]):
                c1 = deltas[1]["collectives"].get(kind, 0)
                c2 = deltas[2]["collectives"].get(kind, 0)
                colls[kind] = int((c1 + (R - 1) * (c2 - c1)) * seq_scale)
            record["roofline_inputs"] = {
                "hlo_flops_per_device": flops,
                "hlo_bytes_per_device": bytes_acc,
                "collective_bytes_per_device": colls,
                "delta_r1": deltas[1], "delta_r2": deltas[2], "repeat": R,
            }
            # 3) roofline terms (per spec: per-chip peak rates)
            coll_total = sum(colls.values())
            tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
            n_active = record["param_counts"]["active_nonembed"]
            model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
            record["roofline"] = {
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": bytes_acc / HBM_BW,
                "collective_s": coll_total / ICI_BW,
                "model_flops": model_flops,
                "hlo_flops_global": flops * n_chips,
                "useful_flops_ratio": model_flops / max(flops * n_chips, 1.0),
                "tokens": tokens,
                "chips": n_chips,
            }
            terms = {k: record["roofline"][k] for k in ("compute_s", "memory_s",
                                                        "collective_s")}
            record["roofline"]["bottleneck"] = max(terms, key=terms.get)
        record["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_seconds"] = time.time() - t0
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-delta", action="store_true")
    ap.add_argument("--ep-mode", default="a2a", choices=["a2a", "replicated"])
    ap.add_argument("--no-serve-fsdp", action="store_true")
    ap.add_argument("--remat-policy", default="nothing", choices=["nothing", "dots"])
    ap.add_argument("--ssm-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--tag", default="", help="artifact suffix for perf variants")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force,
                               skip_delta=args.skip_delta, ep_mode=args.ep_mode,
                               serve_fsdp=not args.no_serve_fsdp,
                               remat_policy=args.remat_policy,
                               ssm_dtype=args.ssm_dtype,
                               capacity_factor=args.capacity_factor,
                               tag=args.tag)
                tag = "SKIP" if not rec["applicable"] else (
                    "OK" if rec.get("ok") else "FAIL")
                failures += tag == "FAIL"
                extra = ""
                if rec.get("roofline"):
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" useful={r['useful_flops_ratio']:.2f}")
                print(f"[{tag}] {arch} × {shape} × "
                      f"{'2x16x16' if mp else '16x16'}"
                      f" ({rec.get('total_seconds', 0):.0f}s){extra}", flush=True)
                if tag == "FAIL":
                    print("      ", rec.get("error"), flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
