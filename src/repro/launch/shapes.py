"""Assigned input shapes, per-cell applicability, and ShapeDtypeStruct specs.

All four shapes come from the assignment table; ``decode_*``/``long_*`` lower
``serve_step`` (one token against a seq_len KV cache), NOT ``train_step``.
``long_500k`` runs only for sub-quadratic archs (DESIGN.md §5); modality
frontends are stubs (precomputed frame/patch embeddings in input_specs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist.context import ShardCtx


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    long: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, long=True),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.long and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md §5)"
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Token batch stand-ins (weak-type-correct, shardable, no allocation)."""
    B = shape.global_batch
    if shape.kind == "train":
        S = shape.seq_len
        out = {"tokens": _i32(B, S), "targets": _i32(B, S)}
    elif shape.kind == "prefill":
        S = shape.seq_len
        out = {"tokens": _i32(B, S)}
    else:  # decode: one new token; the cache covers seq_len
        out = {"tokens": _i32(B, 1)}
        return _add_modality(cfg, out, B, 1, decode=True)
    return _add_modality(cfg, out, B, S, decode=False)


def _add_modality(cfg: ArchConfig, out: dict, B: int, S: int, *, decode: bool) -> dict:
    if cfg.modality_stub == "audio_frames" and not decode:
        out["frames"] = _f32(B, cfg.stub_frames, cfg.d_model)
    if cfg.modality_stub == "image_patches" and not decode:
        # patches are part of the sequence budget: text tokens = S - patches
        pp = min(cfg.img_patches, S // 2)
        out["tokens"] = _i32(B, S - pp)
        out["patches"] = _f32(B, pp, cfg.d_model)
        out["positions"] = _i32(B, S, 3)
    return out


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec, ctx: ShardCtx) -> dict:
    def spec(leaf_name):
        if leaf_name in ("frames", "patches"):
            return ctx.logical_sharding(("batch", "seq", None))
        if leaf_name == "positions":
            return ctx.logical_sharding(("batch", "seq", None))
        return ctx.logical_sharding(("batch", "seq"))

    return {k: (spec(k) if v.ndim > 1 else ctx.logical_sharding(("batch",)))
            for k, v in batch_specs(cfg, shape).items()}


# --------------------------------------------------------------------------
# cache shardings (path-matched: robust across heterogeneous arch families)
# --------------------------------------------------------------------------
def cache_shardings(cache_abstract, cfg: ArchConfig, ctx: ShardCtx):
    """Abstract cache tree -> NamedSharding tree, by leaf path."""
    mesh = ctx.mesh

    def rule(path_str: str, leaf) -> NamedSharding:
        ndim = len(leaf.shape)
        dp = ctx.rules.get("batch")
        tp = ctx.rules.get("q_heads")
        kvseq = ctx.rules.get("kv_seq")
        axes: list = [None] * ndim
        if "attn" in path_str and "pos" in path_str.rsplit("/", 1)[-1]:
            pass  # replicated ring positions
        elif "attn" in path_str:  # [L, B, S, K, hd]
            axes[1] = dp
            if kvseq is not None and not cfg.sliding_window:
                axes[2] = kvseq
            if tp is not None and leaf.shape[3] % ctx.axis_size("q_heads") == 0:
                axes[3] = tp
        elif "mamba" in path_str:  # conv [L,B,dc,di] | ssm [L,B,di,N]
            axes[1] = dp
            di_axis = 3 if path_str.endswith("conv") else 2
            if tp is not None and leaf.shape[di_axis] % ctx.axis_size("q_heads") == 0:
                axes[di_axis] = tp
        elif "mlstm" in path_str or "slstm" in path_str:
            axes[1] = dp  # [L, B, ...]: batch-shard recurrent states
        return NamedSharding(mesh, P(*axes))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        out.append(rule(pstr, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_input_specs(model, cfg: ArchConfig, shape: ShapeSpec):
    """(caches, tokens, pos, enc_out) abstract inputs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: model.init_cache(B, S))
    toks = _i32(B, 1)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc_out = jax.ShapeDtypeStruct((B, cfg.stub_frames, cfg.d_model),
                                       jnp.bfloat16 if cfg.dtype == "bfloat16"
                                       else jnp.float32)
    return caches, toks, pos, enc_out


def make_concrete(spec_tree, rng: np.random.Generator, vocab: int):
    """Instantiate SDS trees with real values (smoke tests / examples)."""

    def one(s):
        if s.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, vocab, s.shape), jnp.int32)
        return jnp.asarray(rng.standard_normal(s.shape), s.dtype)

    return jax.tree_util.tree_map(one, spec_tree)
