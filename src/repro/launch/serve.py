"""Serving driver: ``python -m repro.launch.serve --arch <id> [--reduced]``.

Prefills a batch of prompts and decodes with the batched ServeEngine —
the runnable form of what the decode_* dry-run shapes lower.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.stub_frames, cfg.d_model)),
            jnp.float32)
    if cfg.modality_stub == "image_patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.img_patches, cfg.d_model)),
            jnp.float32)
        S = args.prompt_len + cfg.img_patches
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (args.batch, S, 3)).astype(jnp.int32)
    engine = ServeEngine(model, params)
    toks, stats = engine.generate(batch, num_tokens=args.tokens,
                                  temperature=args.temperature, seed=args.seed)
    print(f"generated {toks.shape} tokens; prefill {stats.prefill_seconds:.2f}s; "
          f"decode {stats.decode_seconds:.2f}s; "
          f"{stats.tokens_per_second:.1f} tok/s")
    print("first sequence:", toks[0][:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
