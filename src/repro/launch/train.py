"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Production behaviours wired in (DESIGN.md §6):
  * checkpoint/restart — atomic keep-N checkpoints, ``--resume`` picks up the
    latest (tested by killing the process mid-run; see tests/test_train.py
    and tests/test_fault_tolerance.py);
  * emergency checkpoint on SIGTERM/SIGINT;
  * deterministic host-local data (restart-safe, straggler-free);
  * optional int8 error-feedback gradient compression (--grad-compression);
  * mesh selection: single device (default, CPU), or --mesh dxm for testing
    sharded runs under forced host devices.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.context import make_rules
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.train import OptConfig, make_init_state, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticLM


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 => (data, model)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    ctx = make_rules(mesh, cfg)
    model = build_model(cfg, ctx)
    opt = OptConfig(name=args.optimizer, peak_lr=args.lr,
                    warmup_steps=max(args.steps // 20, 1),
                    decay_steps=args.steps)
    state = make_init_state(model, opt, grad_compression=args.grad_compression)(
        jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(make_train_step(model, opt,
                                      grad_compression=args.grad_compression),
                      donate_argnums=(0,))

    ck = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and args.resume:
        restored = ck.restore(jax.eval_shape(lambda: state))
        if restored is not None:
            state, start = restored
            print(f"resumed from step {start}")
    if start >= args.steps:  # interrupted after the final step: nothing to do
        print(f"done: {args.steps} steps (already complete at resume)")
        return 0

    stop = {"flag": False}

    def _sig(_s, _f):  # emergency checkpoint, then exit cleanly
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed,
                       host_id=jax.process_index())
    pf = Prefetcher(data, start_step=start)
    t0 = time.time()
    tokens = 0
    try:
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in pf.next().items()}
            if cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.stub_frames, cfg.d_model), jnp.float32)
            state, metrics = step_fn(state, batch)
            tokens += args.batch * args.seq
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                print(f"step {step+1} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"tok/s {tokens/dt:.0f}", flush=True)
            if ck and ((step + 1) % args.ckpt_every == 0 or stop["flag"]):
                ck.save(step + 1, state, sync=stop["flag"])
            if stop["flag"]:
                print(f"signal received: emergency checkpoint at {step+1}")
                return 0
    finally:
        pf.close()
        if ck:
            ck.wait()
    if ck:
        ck.save(args.steps, state, sync=True)
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
