"""Batched serving engine: prefill once, decode greedily/with temperature.

The KV caches / recurrent states are the resident "vertex arrays" of the VSW
mapping (DESIGN.md §5): they live on-device for the whole request batch, and
each decode step is a pull-mode update against them.  serve_step (= one
decode step) is what the decode_* / long_* dry-run shapes lower.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeStats:
    prefill_seconds: float
    decode_seconds: float
    tokens_generated: int

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_generated / max(self.decode_seconds, 1e-9)


class ServeEngine:
    def __init__(self, model: Model, params, *, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step)

    def generate(self, batch: dict, *, num_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> tuple[np.ndarray, ServeStats]:
        t0 = time.time()
        prompt_len = batch["tokens"].shape[1]
        extra = batch["patches"].shape[1] if "patches" in batch else 0
        logits, caches, enc_out = self.model.prefill(
            self.params, batch, cache_len=prompt_len + extra + num_tokens)
        jax.block_until_ready(logits)
        t1 = time.time()
        B = batch["tokens"].shape[0]
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, 0], temperature, key)
        out.append(tok)
        pos = prompt_len + extra
        for i in range(num_tokens - 1):
            logits, caches = self._decode(self.params, caches, tok[:, None],
                                          jnp.asarray(pos + i, jnp.int32),
                                          enc_out=enc_out)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits[:, 0], temperature, key)
            out.append(tok)
        toks = np.stack([np.asarray(t) for t in out], axis=1)
        t2 = time.time()
        return toks, ServeStats(prefill_seconds=t1 - t0, decode_seconds=t2 - t1,
                                tokens_generated=B * num_tokens)

    @staticmethod
    def _sample(logits, temperature: float, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)
