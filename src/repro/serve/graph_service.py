"""GraphService: concurrent graph-query serving with dynamic micro-batching.

The ROADMAP's north star is "serve heavy traffic from millions of users";
query-centric systems (Yan et al.'s "quegel" point-query model, NXgraph)
show that workload is many concurrent POINT queries, not one batch job.
``GraphSession.run_batch`` (PR 2) already answers K compatible queries for
roughly ONE sweep of disk I/O — this module turns an arbitrary stream of
independent client requests into those K-column sweeps:

    client threads --submit()--> pending queue --coalesce--> run_batch
         ^                                                      |
         +-- future.result()  <--- per-column RunResult --------+

* ``submit("sssp", source=7)`` returns a ``concurrent.futures.Future``
  immediately; many client threads may submit concurrently.
* A dispatcher thread groups compatible pending requests — same
  ``BatchSpec.family`` (app family + semiring) and identical non-source
  parameters — into micro-batches of up to ``max_batch`` columns, waiting
  at most ``max_wait_ms`` for stragglers (classic dynamic batching).
* Batches execute on a runner pool (``max_inflight`` concurrent sweeps)
  against ONE shared ``GraphSession`` — one compressed cache, one prefetch
  pipeline, engines shared by ``jit_signature`` so a stream of distinct
  source sets never recompiles.  The session's ``num_devices`` setting is
  transparent here: a multi-device session serves the same API with each
  sweep sharded over the mesh (engine routing happens in the session).
* Non-batchable apps (global pagerank, cc) coalesce by exact identity:
  duplicate in-flight requests share a single engine run.
* A small memo layer keyed on (app, params, graph token — the store's
  epoch for mutable graphs, mtime for frozen ones) serves repeated hot
  queries (popular PPR seeds) without any sweep at all.
  ``apply_mutations`` commits edge edits between sweeps (pause + drain),
  then refreshes incremental-capable memo entries under the new epoch.

Batch padding: groups are padded up to the next power of two (duplicating
the last source) so the jitted [n, K] shard steps specialize on
O(log max_batch) distinct K values instead of every group size the traffic
happens to produce; padded columns are dropped before resolution.

Exactness: min-propagation families (sssp/bfs) resolve futures bitwise
identical to a solo ``session.run`` of the same query regardless of
batching (the semiring ops are exact and column-independent).  plus_src
(ppr) matches its solo K=1 form to float tolerance (``BatchSpec.exact``).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import Counter, OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from math import ceil

from repro.core.apps import batch_spec, is_incremental, list_apps
from repro.graph.source import graph_token
from repro.obs.metrics import Reservoir


class ServiceClosed(RuntimeError):
    """submit() after close(): the service no longer accepts work."""


@dataclasses.dataclass(frozen=True)
class MutationReport:
    """What ``GraphService.apply_mutations`` did to the serving state."""

    epoch: int           # graph epoch after the commit
    memo_refreshed: int  # memo entries recomputed incrementally and re-keyed
    memo_dropped: int    # memo entries invalidated outright


class AdmissionError(RuntimeError):
    """Request refused by admission control (queue full / app not served)."""


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching / admission policy for a GraphService.

    max_batch:
        Column cap per micro-batch (K of the underlying ``run_batch``).
    max_wait_ms:
        How long the dispatcher holds a partially-filled batch open for
        stragglers, measured from the OLDEST pending request.  0 disables
        waiting: every dispatch takes whatever is queued right now
        (latency-optimal, occupancy-pessimal).
    max_inflight:
        Concurrent sweeps on the runner pool.  1 serializes all engine work
        (often right on small machines — sweeps are already parallel
        internally); >1 lets independent families overlap.
    max_queue:
        Admission bound on pending (not yet dispatched) requests; submit()
        raises AdmissionError beyond it instead of growing an unbounded
        backlog.
    apps:
        Per-app admission allowlist; None serves every registered app plus
        the batch-only names ("ppr").
    memoize / memo_capacity / memo_budget_bytes:
        Result memoization keyed on (app, params, graph token): repeated hot
        queries skip the sweep entirely.  LRU-bounded at ``memo_capacity``
        entries AND ``memo_budget_bytes`` of result values (each entry holds
        a full length-n vector, so the byte bound is the one that matters on
        big graphs; a result larger than the whole budget is simply not
        memoized).  Results are shared objects — callers must treat them as
        read-only.
    pad_batches:
        Pad groups to the next power of two (see module docstring); disable
        only to measure the recompile cost it avoids.
    max_iters:
        Default iteration cap applied when a request does not pass its own
        ``max_iters``.
    fair_weights:
        Per-app weights for the dispatcher's stride fair-share scheduler
        (dict or pair-iterable; normalized to a sorted tuple).  Each
        dispatched request charges its app ``1/weight`` of virtual time and
        the dispatcher serves the READY group whose app is furthest behind
        — so a flood of cheap BFS queries cannot starve a pending PPR
        group past its wait deadline.  Unlisted apps weigh 1.0; None means
        everyone weighs 1.0 (pure round-robin among ready groups).

    ``max_batch``, ``max_wait_ms``, ``max_queue``, ``max_iters`` and
    ``fair_weights`` are live-tunable via ``GraphService.reconfigure``
    (the adaptive controller's write path); the rest are fixed at
    construction (``max_inflight`` sizes a real thread pool).
    """

    max_batch: int = 16
    max_wait_ms: float = 2.0
    max_inflight: int = 2
    max_queue: int = 1024
    apps: tuple | None = None
    memoize: bool = True
    memo_capacity: int = 256
    memo_budget_bytes: int = 1 << 28
    pad_batches: bool = True
    max_iters: int = 200
    fair_weights: tuple | None = None

    def __post_init__(self):
        if not isinstance(self.max_batch, int) or self.max_batch < 1:
            raise ValueError(f"max_batch must be an int >= 1, got "
                             f"{self.max_batch!r}")
        if not isinstance(self.max_wait_ms, (int, float)) \
                or self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got "
                             f"{self.max_wait_ms!r}")
        if not isinstance(self.max_inflight, int) or self.max_inflight < 1:
            raise ValueError(f"max_inflight must be an int >= 1, got "
                             f"{self.max_inflight!r}")
        if not isinstance(self.max_queue, int) or self.max_queue < 1:
            raise ValueError(f"max_queue must be an int >= 1, got "
                             f"{self.max_queue!r}")
        if self.apps is not None:
            object.__setattr__(self, "apps", tuple(self.apps))
        if not isinstance(self.memo_capacity, int) or self.memo_capacity < 0:
            raise ValueError(f"memo_capacity must be an int >= 0, got "
                             f"{self.memo_capacity!r}")
        if not isinstance(self.memo_budget_bytes, int) \
                or self.memo_budget_bytes < 0:
            raise ValueError(f"memo_budget_bytes must be an int >= 0, got "
                             f"{self.memo_budget_bytes!r}")
        if not isinstance(self.max_iters, int) or self.max_iters < 1:
            raise ValueError(f"max_iters must be an int >= 1, got "
                             f"{self.max_iters!r}")
        if self.fair_weights is not None:
            items = (self.fair_weights.items()
                     if isinstance(self.fair_weights, dict)
                     else self.fair_weights)
            norm = tuple(sorted((str(app), float(w)) for app, w in items))
            if any(w <= 0 for _, w in norm):
                raise ValueError(f"fair_weights must be > 0, got "
                                 f"{self.fair_weights!r}")
            object.__setattr__(self, "fair_weights", norm)

    def weight_for(self, app: str) -> float:
        """Fair-share weight of ``app`` (1.0 unless listed)."""
        if self.fair_weights is not None:
            for name, w in self.fair_weights:
                if name == app:
                    return w
        return 1.0

    def replace(self, **changes) -> "ServiceConfig":
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# statistics
# ---------------------------------------------------------------------------
def _nearest_rank(ordered, q: float) -> float:
    """The ceil(q/100 * N)-th smallest of an ALREADY-SORTED sequence."""
    if not ordered:
        return 0.0
    return float(ordered[ceil(q / 100.0 * len(ordered)) - 1])


def percentile(values, q: float) -> float:
    """Nearest-rank percentile: the ceil(q/100 * N)-th smallest value.

    Deliberately NOT an interpolating estimator — every reported latency is
    a latency some request actually saw, and the regression test in
    tests/test_serve_service.py pins this definition so the math cannot
    silently drift (snapshot() reports through the same ``_nearest_rank``).
    """
    if not 0 < q <= 100:
        raise ValueError(f"percentile q must be in (0, 100], got {q!r}")
    return _nearest_rank(sorted(values), q)


class ServiceStats:
    """Thread-safe serving counters + latency/occupancy distributions.

    ``snapshot()`` returns one self-consistent dict: request counts
    (submitted/completed/memo_hits/rejected/failed), current and peak queue
    depth, p50/p95/p99/mean latency in milliseconds, the batch-occupancy
    histogram {K: batches executed with K live columns}, and
    ``cache_served_fraction`` (memo hits over completed requests).

    Latencies live in bounded log-binned reservoirs
    (``repro.obs.metrics.Reservoir``) — one overall (``latency_hist``) plus
    one per app, created lazily — NOT an ordered list: memory is O(#bins)
    however long the service runs, percentile reads are O(#bins) however
    much traffic arrived (a polling controller reads them every few hundred
    ms), and bin-count snapshots subtract, giving rolling-window
    percentiles for free.  The cost is a documented ~1% relative error on
    quantiles (see ``Reservoir``; mean stays exact via sum/count, and the
    regression test in tests/test_obs.py pins the error bound against the
    exact nearest-rank ``percentile``).  Counters are lifetime totals.

    ``attach_hub`` shares these same reservoirs with a ``MetricsHub`` (no
    double recording) and registers a poller exporting the counters, so
    every snapshot the hub emits carries the serving state.
    """

    def __init__(self):
        self._lock = threading.Lock()
        # seconds per completed request: one overall + one per app, all
        # bounded reservoirs shared with any attached MetricsHub
        self.latency_hist = Reservoir()
        self._app_hists: dict[str, Reservoir] = {}
        self._hub = None
        self._hub_prefix = "serve"
        self.batch_occupancy: Counter = Counter()
        self.submitted = 0
        self.completed = 0
        self.memo_hits = 0
        self.rejected = 0
        self.failed = 0
        self.queue_depth = 0
        self.queue_peak = 0

    # -- recording hooks (service-internal) -----------------------------
    def record_submitted(self, queue_depth: int) -> None:
        with self._lock:
            self.submitted += 1
            self.queue_depth = queue_depth
            self.queue_peak = max(self.queue_peak, queue_depth)

    def record_dequeued(self, queue_depth: int) -> None:
        with self._lock:
            self.queue_depth = queue_depth

    def record_rejected(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_batch(self, occupancy: int) -> None:
        with self._lock:
            self.batch_occupancy[occupancy] += 1

    def record_latency(self, seconds: float, memo_hit: bool = False,
                       app: str | None = None) -> None:
        self.latency_hist.observe(seconds)
        if app is not None:
            self._app_hist(app).observe(seconds)
        with self._lock:
            self.completed += 1
            self.memo_hits += int(memo_hit)

    def record_failed(self, count: int = 1) -> None:
        with self._lock:
            self.failed += count

    def _app_hist(self, app: str) -> Reservoir:
        with self._lock:
            h = self._app_hists.get(app)
            if h is None:
                h = self._app_hists[app] = Reservoir()
                if self._hub is not None:
                    self._hub.adopt_histogram(
                        f"{self._hub_prefix}.latency_s.{app}", h)
            return h

    # -- telemetry wiring -------------------------------------------------
    def attach_hub(self, hub, prefix: str = "serve") -> None:
        """Share the latency reservoirs with ``hub`` (adopted, not copied)
        and export the counters as a poller named ``prefix``."""
        with self._lock:
            self._hub = hub
            self._hub_prefix = prefix
            hub.adopt_histogram(f"{prefix}.latency_s", self.latency_hist)
            for app, h in self._app_hists.items():
                hub.adopt_histogram(f"{prefix}.latency_s.{app}", h)
        hub.register_poller(prefix, self._poll)

    def _poll(self) -> dict:
        with self._lock:
            occ = dict(self.batch_occupancy)
            out = dict(
                submitted=self.submitted, completed=self.completed,
                memo_hits=self.memo_hits, rejected=self.rejected,
                failed=self.failed, queue_depth=self.queue_depth,
                queue_peak=self.queue_peak,
            )
        batches = sum(occ.values())
        out["batches"] = batches
        out["mean_occupancy"] = (sum(k * v for k, v in occ.items()) / batches
                                 if batches else 0.0)
        return out

    # -- reading ---------------------------------------------------------
    def occupancy(self) -> dict:
        """Copy of the {K: batch count} occupancy histogram (the adaptive
        controller diffs successive copies for per-window occupancy)."""
        with self._lock:
            return dict(self.batch_occupancy)

    def latency_ms(self, q: float) -> float:
        return self.latency_hist.quantile(q) * 1e3

    def snapshot(self) -> dict:
        with self._lock:
            occ = dict(sorted(self.batch_occupancy.items()))
            completed, memo = self.completed, self.memo_hits
            snap = dict(
                submitted=self.submitted, completed=completed,
                memo_hits=memo, rejected=self.rejected, failed=self.failed,
                queue_depth=self.queue_depth, queue_peak=self.queue_peak,
            )
        hist = self.latency_hist.to_dict(scale=1e3)
        snap.update(
            p50_ms=hist["p50"], p95_ms=hist["p95"], p99_ms=hist["p99"],
            mean_ms=hist["mean"],
            batch_occupancy=occ,
            cache_served_fraction=memo / completed if completed else 0.0,
        )
        return snap


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Request:
    app: str
    params: dict            # full request params minus the source (if batched)
    source: int | None      # frontier vertex for batchable apps
    group_key: tuple        # requests with equal keys may share one execution
    memo_key: tuple | None
    future: Future
    t_submit: float         # time.perf_counter() at admission


def _params_key(params: dict) -> tuple:
    return tuple(sorted(params.items()))


def _next_pow2(k: int) -> int:
    return 1 << (k - 1).bit_length()


class GraphService:
    """Thread-safe concurrent query service over ONE shared GraphSession.

    See the module docstring for the architecture.  Lifecycle::

        svc = session.service(max_batch=16)      # started on construction
        futs = [svc.submit("sssp", source=s) for s in sources]
        dists = [f.result().values for f in futs]
        svc.close()                              # drains pending work

    or as a context manager (``with session.service() as svc:``).
    """

    def __init__(self, session, config: ServiceConfig | None = None,
                 **overrides):
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        self.session = session
        self.config = config
        self.stats = ServiceStats()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: deque[_Request] = deque()
        # per-group pending counts, maintained on every append/pop: the
        # dispatcher's wait loop and full-group lookup stay O(#groups),
        # not O(queue length), under the lock submit() contends on
        self._pending_counts: Counter = Counter()
        # stride fair-share state (dispatcher-side, guarded by _cond): per-
        # app pass values + the virtual time new apps join at
        self._app_pass: dict[str, float] = {}
        self._vtime = 0.0
        self._closing = False
        self._closed = False
        # mutation barrier: while True the dispatcher launches no new
        # batches (apply_mutations also holds every inflight permit, so the
        # graph only changes between sweeps, never under one)
        self._paused = False
        self._mutate_lock = threading.Lock()  # serializes apply_mutations
        self._memo: OrderedDict = OrderedDict()  # key -> (result, nbytes)
        self._memo_bytes = 0
        self._graph_token = self._compute_graph_token(session.store)
        self._inflight = threading.Semaphore(config.max_inflight)
        self._runners = ThreadPoolExecutor(
            max_workers=config.max_inflight, thread_name_prefix="graphserve")
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="graphserve-dispatch", daemon=True)
        self._dispatcher.start()

    # ------------------------------------------------------------------
    @staticmethod
    def _compute_graph_token(store) -> tuple:
        """Identity of the graph snapshot for memo keys: a mutated, re-packed
        or re-preprocessed graph at the same path must not serve stale
        results.  Mutable stores version themselves with their epoch; frozen
        stores keep the historical mtime probe (see ``graph_token``)."""
        return graph_token(store)

    def _served_apps(self) -> tuple:
        if self.config.apps is not None:
            return self.config.apps
        # registry-derived (no hard-coded names): every registered factory
        # plus the batch-only serving aliases ("ppr", "lp", ...) list_apps
        # reports from the BatchSpec table
        return tuple(info.name for info in list_apps())

    # ------------------------------------------------------------------
    def submit(self, app: str, **params) -> Future:
        """Queue one query; returns a future resolving to its RunResult.

        ``app`` is a registered single-query name (``"sssp"``, ``"bfs"``,
        ``"cc"``, ``"pagerank"``) or a batch-only name (``"ppr"``);
        ``params`` are its factory arguments (``source=``, ``seed=``,
        ``damping=``...) plus an optional ``max_iters``.  Raises
        ``ServiceClosed`` after ``close()`` and ``AdmissionError`` when the
        pending queue is at ``max_queue`` or ``app`` is not served.
        """
        t0 = time.perf_counter()
        spec = batch_spec(app)
        if app not in self._served_apps():
            self.stats.record_rejected()
            raise AdmissionError(
                f"app {app!r} is not served here (serving "
                f"{self._served_apps()})")
        params = dict(params)
        params.setdefault("max_iters", self.config.max_iters)
        source = None
        if spec is not None:
            if spec.source_param not in params:
                raise TypeError(
                    f"{app!r} needs {spec.source_param}=<vertex id>")
            source = int(params.pop(spec.source_param))
            if source < 0:
                raise ValueError(
                    f"{spec.source_param} must be >= 0, got {source}")
            group_key = ("batch", spec.family, _params_key(params))
            memo_key = (app, source, _params_key(params), self._graph_token)
        else:
            group_key = ("solo", app, _params_key(params))
            memo_key = (app, None, _params_key(params), self._graph_token)
        if not self.config.memoize:
            memo_key = None

        future: Future = Future()
        with self._cond:
            if self._closing:
                raise ServiceClosed("GraphService is closed")
            if memo_key is not None:
                hit = self._memo.get(memo_key)
                if hit is not None:
                    self._memo.move_to_end(memo_key)
                    future.set_result(hit[0])
                    self.stats.record_submitted(len(self._pending))
                    self.stats.record_latency(time.perf_counter() - t0,
                                              memo_hit=True, app=app)
                    return future
            if len(self._pending) >= self.config.max_queue:
                self.stats.record_rejected()
                raise AdmissionError(
                    f"pending queue full ({self.config.max_queue} requests);"
                    " retry later")
            req = _Request(app=app, params=params, source=source,
                           group_key=group_key, memo_key=memo_key,
                           future=future, t_submit=t0)
            self._pending.append(req)
            self._pending_counts[group_key] += 1
            self.stats.record_submitted(len(self._pending))
            self._cond.notify_all()
        return future

    def submit_many(self, queries) -> list[Future]:
        """``submit`` for an iterable of ``(app, params_dict)`` pairs."""
        return [self.submit(app, **params) for app, params in queries]

    # ------------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        # NOTE: self.config is re-read every pass (reconfigure() swaps the
        # frozen config object and notifies) — never cached across waits
        while True:
            with self._cond:
                # a mutation barrier (_paused) parks the dispatcher even
                # while closing — apply_mutations always lifts it in finally
                while self._paused or (not self._closing
                                       and not self._pending):
                    self._cond.wait()
                if not self._pending:
                    return  # closing and drained
                cfg = self.config
                now = time.perf_counter()
                key = self._ready_group(cfg, now)
                if key is None:
                    # no group is full or past its straggler deadline: sleep
                    # until the earliest deadline (or a submit/reconfigure/
                    # close notification), then re-evaluate from scratch
                    deadline = self._earliest_deadline(cfg)
                    self._cond.wait(None if deadline is None
                                    else max(deadline - now, 0.0))
                    continue
                group = self._take_group(key, cfg)
                self.stats.record_dequeued(len(self._pending))
            if not group:
                continue
            # bounded in-flight sweeps: acquiring here (dispatcher thread)
            # applies backpressure — the queue keeps admitting up to
            # max_queue while every runner is busy
            self._inflight.acquire()
            try:
                self._runners.submit(self._run_group, group)
            except BaseException:
                self._inflight.release()
                for r in group:
                    r.future.set_exception(ServiceClosed(
                        "runner pool rejected the batch"))
                if self._closing:
                    return
                raise

    def _group_heads(self) -> dict:
        """{group_key: oldest pending request} in one queue scan (the queue
        is FIFO, so the first request seen per key is its oldest)."""
        heads: dict[tuple, _Request] = {}
        for r in self._pending:
            if r.group_key not in heads:
                heads[r.group_key] = r
        return heads

    def _ready_group(self, cfg: ServiceConfig, now: float) -> tuple | None:
        """The group to dispatch now, or None to keep waiting.

        A group is READY when it is full (max_batch pending), its oldest
        request has waited max_wait_ms, or the service is closing (drain).
        Among ready groups the pick is weighted fair-share, not FIFO: each
        app carries a stride-scheduling pass value (advanced 1/weight per
        dispatched request), and the ready group whose app is furthest
        behind wins.  A flood of cheap BFS queries therefore keeps filling
        batches — but every time it dispatches its pass advances, so a
        ready PPR group's older pass takes the next slot: bounded bypass
        instead of starvation (the old policy dispatched ANY full group
        ahead of an expired head, indefinitely under flood).
        """
        best_key, best_pass = None, None
        for key, head in self._group_heads().items():
            ready = (self._closing
                     or self._pending_counts[key] >= cfg.max_batch
                     or now >= head.t_submit + cfg.max_wait_ms / 1e3)
            if not ready:
                continue
            app_pass = self._app_pass.get(head.app, self._vtime)
            if best_pass is None or app_pass < best_pass:
                best_key, best_pass = key, app_pass
        if best_key is not None:
            # advance virtual time to the winner so newly-seen apps start
            # here, not at 0 (no retroactive credit for late arrivals)
            self._vtime = max(self._vtime, best_pass)
        return best_key

    def _earliest_deadline(self, cfg: ServiceConfig) -> float | None:
        heads = self._group_heads()
        if not heads:
            return None
        return min(h.t_submit for h in heads.values()) + cfg.max_wait_ms / 1e3

    def _take_group(self, key: tuple, cfg: ServiceConfig) -> list[_Request]:
        """Pop up to max_batch requests sharing ``key`` (queue order) and
        charge their apps' fair-share passes.

        Marks each taken future running (``set_running_or_notify_cancel``),
        which both drops client-cancelled requests and makes the later
        ``set_result`` race-free against ``Future.cancel``."""
        group, rest = [], deque()
        for r in self._pending:
            if r.group_key == key and len(group) < cfg.max_batch:
                self._pending_counts[key] -= 1
                if r.future.set_running_or_notify_cancel():
                    group.append(r)
            else:
                rest.append(r)
        if self._pending_counts[key] <= 0:
            del self._pending_counts[key]
        self._pending = rest
        for r in group:
            # stride accounting: 1/weight virtual time per request, floored
            # at current vtime so an app idle for an hour does not bank an
            # hour of priority credit
            base = max(self._app_pass.get(r.app, self._vtime), self._vtime)
            self._app_pass[r.app] = base + 1.0 / cfg.weight_for(r.app)
        return group

    # ------------------------------------------------------------------
    def _run_group(self, group: list[_Request]) -> None:
        try:
            kind = group[0].group_key[0]
            if kind == "batch":
                self._run_batched(group)
            else:
                self._run_solo(group)
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            self.stats.record_failed(sum(1 for r in group
                                         if not r.future.done()))
            for r in group:
                if not r.future.done():
                    r.future.set_exception(exc)
        finally:
            self._inflight.release()

    def _run_batched(self, group: list[_Request]) -> None:
        spec = batch_spec(group[0].app)
        params = dict(group[0].params)
        max_iters = params.pop("max_iters")
        sources = [r.source for r in group]
        if self.config.pad_batches:
            # duplicate the tail source up to the next power of two (capped
            # at max_batch, which need not be one): the jitted [n, K] step
            # then specializes on O(log max_batch) K values, matching
            # warmup()'s ladder; duplicated columns are computed-and-dropped
            k = min(_next_pow2(len(group)), self.config.max_batch)
            sources = sources + [sources[-1]] * (k - len(group))
        results = self.session.run_batch(
            spec.batched_app, max_iters=max_iters,
            **{spec.batch_param: sources}, **params)
        self.stats.record_batch(len(group))
        self._resolve(group, results[: len(group)])

    def _run_solo(self, group: list[_Request]) -> None:
        """Identical solo requests (one group_key == one exact query)
        coalesce into a single engine run resolving every future."""
        params = dict(group[0].params)
        result = self.session.run(group[0].app, **params)
        self.stats.record_batch(len(group))
        self._resolve(group, itertools.repeat(result))

    def _resolve(self, group: list[_Request], results) -> None:
        now = time.perf_counter()
        pairs = list(zip(group, results))
        # memoize BEFORE resolving: a client that has seen result() must be
        # able to resubmit the same query and hit the memo — resolving first
        # races its next submit against this insertion
        memo_items = [(r.memo_key, res) for r, res in pairs
                      if r.memo_key is not None]
        if memo_items and self.config.memo_capacity \
                and self.config.memo_budget_bytes:
            with self._cond:
                for key, res in memo_items:
                    nbytes = getattr(res.values, "nbytes", 0)
                    if nbytes > self.config.memo_budget_bytes:
                        continue  # one result outweighs the whole budget
                    old = self._memo.pop(key, None)
                    if old is not None:
                        self._memo_bytes -= old[1]
                    self._memo[key] = (res, nbytes)
                    self._memo_bytes += nbytes
                while len(self._memo) > self.config.memo_capacity \
                        or self._memo_bytes > self.config.memo_budget_bytes:
                    _, (_, dropped) = self._memo.popitem(last=False)
                    self._memo_bytes -= dropped
        for r, res in pairs:
            # stats before set_result: a client that has seen result() must
            # also see its completion counted in the very next snapshot
            self.stats.record_latency(now - r.t_submit, app=r.app)
            r.future.set_result(res)

    # ------------------------------------------------------------------
    def apply_mutations(self, inserts=None, deletes=None, updates=None, *,
                        refresh_memo: bool = True) -> MutationReport:
        """Commit edge mutations against the shared session, safely.

        Pauses dispatch, drains every in-flight sweep (by taking all
        ``max_inflight`` permits), commits through
        ``session.apply_mutations`` (the session must be ``mutable=True``),
        re-keys the memo under the new graph token, then resumes.  Pending
        requests admitted before the call simply execute after it, at the
        new epoch; in-flight sweeps finish at the old epoch before the
        commit lands, so no sweep ever mixes epochs.

        ``refresh_memo=True`` recomputes memoized results whose application
        is registered ``incremental=True`` via ``session.run_incremental``
        — for monotone deltas that costs the few frontier-local iterations
        the change propagates, per entry, instead of a cold sweep — and
        re-inserts them under the new token.  Everything else (PageRank
        entries, results predating the epoch log) is dropped and will be
        recomputed on next request.
        """
        with self._mutate_lock:
            with self._cond:
                if self._closing:
                    raise ServiceClosed("GraphService is closed")
                self._paused = True
            acquired = 0
            try:
                for _ in range(self.config.max_inflight):
                    self._inflight.acquire()
                    acquired += 1
                epoch = self.session.apply_mutations(
                    inserts=inserts, deletes=deletes, updates=updates)
                with self._cond:
                    stale = list(self._memo.items())
                    self._memo.clear()
                    self._memo_bytes = 0
                    self._graph_token = self._compute_graph_token(
                        self.session.store)
                    token = self._graph_token
                refreshed = []
                dropped = 0
                for (app, source, pkey, _old), (res, _nb) in stale:
                    new = (self._refresh_memo_entry(app, source, pkey, res)
                           if refresh_memo else None)
                    if new is None:
                        dropped += 1
                    else:
                        refreshed.append(((app, source, pkey, token), new))
                if refreshed:
                    with self._cond:
                        for key, res in refreshed:
                            nbytes = getattr(res.values, "nbytes", 0)
                            if nbytes > self.config.memo_budget_bytes:
                                continue
                            self._memo[key] = (res, nbytes)
                            self._memo_bytes += nbytes
                        while len(self._memo) > self.config.memo_capacity \
                                or self._memo_bytes \
                                > self.config.memo_budget_bytes:
                            _, (_, nb) = self._memo.popitem(last=False)
                            self._memo_bytes -= nb
                return MutationReport(epoch=epoch,
                                      memo_refreshed=len(refreshed),
                                      memo_dropped=dropped)
            finally:
                for _ in range(acquired):
                    self._inflight.release()
                with self._cond:
                    self._paused = False
                    self._cond.notify_all()

    def _refresh_memo_entry(self, app, source, pkey, prev):
        """Incrementally recompute one memo entry, or None to drop it.

        Only entries where ``run_incremental`` is guaranteed to take its
        seeded shortcut are refreshed — a fallback cold sweep per entry
        would turn one mutation into a full-memo recompute storm."""
        if not (is_incremental(app) and prev.converged):
            return None
        store = self.session.store
        monotone_since = getattr(store, "monotone_since", None)
        if monotone_since is None or not monotone_since(prev.epoch):
            return None
        if store.affected_sources_since(prev.epoch) is None:
            return None  # epoch log truncated past prev: would run cold
        params = dict(pkey)
        max_iters = params.pop("max_iters", self.config.max_iters)
        spec = batch_spec(app)
        if source is not None and spec is not None:
            params[spec.source_param] = source
        try:
            return self.session.run_incremental(app, prev=prev,
                                                max_iters=max_iters, **params)
        except Exception:
            return None  # a broken refresh drops the entry, never the commit

    # ------------------------------------------------------------------
    def warmup(self, apps=("sssp",)) -> None:
        """Pre-compile the jitted shard steps the batching policy can hit:
        one ``max_iters=1`` run per (app, padded batch size).  Optional —
        first requests pay the compiles otherwise."""
        sizes = {1}
        if self.config.pad_batches:
            k = 1
            while k < self.config.max_batch:
                k = min(k * 2, self.config.max_batch)
                sizes.add(k)
        else:
            sizes = set(range(1, self.config.max_batch + 1))
        for app in apps:
            spec = batch_spec(app)
            if spec is None:
                self.session.run(app, max_iters=1)
                continue
            for k in sorted(sizes):
                self.session.run_batch(spec.batched_app, max_iters=1,
                                       **{spec.batch_param: list(range(k))})

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def is_closed(self) -> bool:
        """True once close() has begun — submit/reconfigure will raise."""
        with self._lock:
            return self._closing

    # ------------------------------------------------------------------
    RECONFIGURABLE = frozenset(
        {"max_batch", "max_wait_ms", "max_queue", "max_iters",
         "fair_weights"})

    def reconfigure(self, **changes) -> ServiceConfig:
        """Atomically retune the live batching policy; returns the new
        config.  This is ``AdaptiveServeController``'s write path, and it
        is safe mid-traffic: the dispatcher re-reads ``self.config`` on
        every pass, pending requests simply see the new limits on their
        next evaluation, and in-flight sweeps are untouched.

        Only ``RECONFIGURABLE`` fields may change (``max_inflight`` sizes
        a real thread pool, the memo knobs shape already-held state —
        restart for those); values are validated exactly like construction
        (``ServiceConfig.__post_init__``).  Raises ``ServiceClosed`` on a
        closed/closing service so a racing controller loop stops cleanly
        instead of resurrecting knobs on a corpse.
        """
        unknown = set(changes) - self.RECONFIGURABLE
        if unknown:
            raise ValueError(
                f"not reconfigurable at runtime: {sorted(unknown)} "
                f"(allowed: {sorted(self.RECONFIGURABLE)})")
        with self._cond:
            if self._closing:
                raise ServiceClosed("cannot reconfigure a closed "
                                    "GraphService")
            self.config = self.config.replace(**changes)
            # wake the dispatcher: a shorter max_wait_ms or smaller
            # max_batch can make a parked group ready right now
            self._cond.notify_all()
            return self.config

    def attach_hub(self, hub, prefix: str = "serve"):
        """Wire this service's stats into a ``MetricsHub``: the latency
        reservoirs are shared (adopted) and the counters exported as a
        poller, so every emitted snapshot carries serving state.  Returns
        ``hub`` for chaining."""
        self.stats.attach_hub(hub, prefix)
        return hub

    def close(self, drain: bool = True, timeout: float | None = None) -> None:
        """Stop accepting work and shut down.

        ``drain=True`` (default) runs every pending request to completion
        first; ``drain=False`` fails pending futures with ``ServiceClosed``
        (requests already executing still complete).  ``timeout`` bounds the
        drain (seconds); on expiry the remaining UNDISPATCHED requests are
        failed with ``ServiceClosed`` rather than left hanging — a client
        blocked in ``future.result()`` always gets an answer.  Idempotent.
        """
        with self._cond:
            if self._closed:
                return
            self._closing = True
            if not drain:
                self._fail_pending_locked()
            self._cond.notify_all()
        self._dispatcher.join(timeout)
        if self._dispatcher.is_alive():
            # drain timed out mid-backlog: fail what was never dispatched so
            # no caller waits forever, then let the dispatcher wind down
            with self._cond:
                self._fail_pending_locked()
                self._cond.notify_all()
            self._dispatcher.join()
        self._runners.shutdown(wait=True)
        self._closed = True

    def _fail_pending_locked(self) -> None:
        while self._pending:
            r = self._pending.popleft()
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(
                    ServiceClosed("GraphService closed before this "
                                  "request was dispatched"))
        self._pending_counts.clear()

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"GraphService({self.session!r}, max_batch="
                f"{self.config.max_batch}, queue={self.queue_depth})")
