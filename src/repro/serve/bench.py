"""Load generation for GraphService (``python -m repro.serve.bench``).

Three arrival modes:

* **closed** — each of N client threads plays a user: submit one query,
  block on the future, immediately submit the next, so concurrency in
  flight equals the client count.  Throughput-oriented; latency here is
  *conditioned on* the service keeping up (a closed loop slows its own
  arrival rate when the service stalls — the coordinated-omission trap).
* **open** — arrivals follow a schedule independent of service speed:
  Poisson inter-arrivals at a target qps (``LoadTrace.synthesize``), or
  any recorded trace.  Latency is measured from the INTENDED arrival
  time, so a stalled service honestly accumulates queueing delay instead
  of silently throttling the generator.  This is the mode that can
  falsify a batching policy.
* **replay** — open-loop over a saved ``LoadTrace`` file: the same
  traffic, byte for byte, against any policy — how static configs and the
  adaptive controller are compared (``benchmarks/fig_autotune.py``).

Both generators can ``--record-trace`` what they submitted; replays of
exact app families (sssp/bfs) resolve bitwise-identically run to run
(``result_digest`` in the returned stats), so a recorded trace is a
regression oracle as well as a load profile.

Self-tuning: ``--adaptive`` attaches an ``AdaptiveServeController``
(``--slo-p99-ms`` sets the target) and ``--metrics FILE`` streams
MetricsHub JSONL snapshots for offline inspection — the CI autotune job
replays the committed mini-trace this way and schema-checks the output.

Usage::

    PYTHONPATH=src python -m repro.serve.bench --scale 14 --clients 1 4 16
    PYTHONPATH=src python -m repro.serve.bench --mode open --qps 40 \
        --duration 10 --record-trace /tmp/t.jsonl
    PYTHONPATH=src python -m repro.serve.bench --mode replay \
        --replay-trace benchmarks/traces/mini_mixed.jsonl --adaptive \
        --slo-p99-ms 60 --metrics /tmp/metrics.jsonl --require-converged
"""
from __future__ import annotations

import argparse
import hashlib
import os
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.obs import (AdaptiveServeController, LoadTrace, MetricsHub,
                       TraceRecorder)
from repro.serve.graph_service import (AdmissionError, GraphService,
                                       ServiceConfig, percentile)

SEQUENTIAL = ServiceConfig(max_batch=1, max_wait_ms=0.0, max_inflight=1,
                           memoize=False)


def prepare_store(scale: int = 14, edge_factor: int = 8,
                  base_dir: str | os.PathLike | None = None):
    """Preprocess (once, cached on disk) an RMAT graph for serving benches."""
    from repro.graph.generate import materialize, rmat_edges
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import GraphStore, write_edge_list

    base = Path(base_dir or os.environ.get(
        "BENCH_DIR", tempfile.gettempdir())) / "repro_serve_bench"
    tag = f"s{scale}_e{edge_factor}"
    out = base / f"store_{tag}"
    if (out / "property.json").exists():
        return GraphStore(out)
    src, dst = materialize(rmat_edges(scale=scale, edge_factor=edge_factor,
                                      seed=11))
    el = base / f"el_{tag}"
    if not (el / "meta.json").exists():
        write_edge_list(el, [(src, dst)], num_vertices=1 << scale)
    return preprocess_graph(str(el), str(out),
                            threshold_edge_num=1 << max(scale - 2, 10),
                            lane=16)


def run_load(session, *, clients: int, queries_per_client: int,
             config: ServiceConfig, app: str = "ppr", max_iters: int = 30,
             seed: int = 0, warmup: bool = True,
             recorder: TraceRecorder | None = None) -> dict:
    """Drive one closed-loop experiment; returns throughput + latency stats.

    Every client issues ``queries_per_client`` queries of ``app`` from
    deterministic, per-client-distinct sources (seeded), so runs are
    reproducible and memoization cannot shortcut the measurement — the
    speedup under test comes from COALESCING alone.  ``recorder`` (a
    ``TraceRecorder``) captures each submission at its actual offset, so a
    closed-loop run can be re-played open-loop later.
    """
    from repro.core.apps import batch_spec

    n = session.n
    spec = batch_spec(app)
    param = spec.source_param if spec is not None else None
    with GraphService(session, config) as svc:
        if warmup:
            svc.warmup(apps=(app,))
        errors: list[BaseException] = []
        lock = threading.Lock()

        def client(cid: int) -> None:
            for i in range(queries_per_client):
                # distinct sources per (client, query): no two in-flight
                # queries collapse to the same column or memo entry
                source = (seed + cid * queries_per_client + i) * 9973 % n
                try:
                    kw = {param: source} if param else {}
                    kw["max_iters"] = max_iters
                    if recorder is not None:
                        recorder.record(app, kw)
                    fut = svc.submit(app, **kw)
                    fut.result()
                except BaseException as exc:  # noqa: BLE001 — reported below
                    with lock:
                        errors.append(exc)
                    return

        threads = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        snap = svc.stats.snapshot()
    total = clients * queries_per_client
    occ = snap["batch_occupancy"]
    batches = sum(occ.values())
    return dict(
        clients=clients, queries=total, wall_seconds=wall,
        qps=total / max(wall, 1e-9),
        p50_ms=snap["p50_ms"], p95_ms=snap["p95_ms"], p99_ms=snap["p99_ms"],
        mean_occupancy=(sum(k * v for k, v in occ.items()) / batches
                        if batches else 0.0),
        batches=batches, disk_bytes=session.stats.disk_bytes,
    )


def replay_trace(session, trace: LoadTrace, config: ServiceConfig, *,
                 adaptive: bool = False, slo_p99_ms: float = 50.0,
                 controller_interval_s: float = 0.25,
                 controller_overrides: dict | None = None,
                 hub: MetricsHub | None = None, warmup: bool = True,
                 speed: float = 1.0, result_timeout: float = 600.0) -> dict:
    """Open-loop replay of ``trace`` against one policy; returns stats.

    A pacer thread submits each event at its recorded offset (divided by
    ``speed``); per-request latency runs from the INTENDED arrival to
    future resolution, so generator lateness and queueing both count
    (open-loop honesty).  Reported percentiles here are EXACT nearest-rank
    over the replay's own latency list — the replay is the judge of the
    serving stack's reservoirs, so it must not share their error bar.

    ``adaptive=True`` attaches an ``AdaptiveServeController`` targeting
    ``slo_p99_ms`` (clamp/gain tweaks via ``controller_overrides``); the
    returned dict then carries ``converged``/``adjustments`` and the final
    knob values.  ``hub`` wires service + session + controller telemetry.

    ``result_digest`` is a SHA-256 over every completed result's value
    bytes in event order: replaying the same trace twice on the same graph
    must produce the same digest for exact app families (sssp/bfs),
    whatever batches the policy formed — the determinism acceptance bar.
    """
    events = list(trace)
    lats: list = [None] * len(events)
    done_t: list = [None] * len(events)
    futures: list = [None] * len(events)
    with GraphService(session, config) as svc:
        if hub is not None:
            svc.attach_hub(hub)
            session.attach_hub(hub)
        ctl = None
        if adaptive:
            ctl = AdaptiveServeController(
                svc, slo_p99_ms=slo_p99_ms,
                interval_s=controller_interval_s, hub=hub,
                **(controller_overrides or {}))
        try:
            if warmup:
                svc.warmup(apps=tuple(sorted({e.app for e in events})))
            if ctl is not None:
                ctl.start()
            t0 = time.perf_counter()

            def pace() -> None:
                for i, e in enumerate(events):
                    intended = t0 + e.t / speed
                    delay = intended - time.perf_counter()
                    if delay > 0:
                        time.sleep(delay)
                    def _done(fut, i=i, intended=intended):
                        done_t[i] = time.perf_counter()
                        lats[i] = done_t[i] - intended
                    try:
                        fut = svc.submit(e.app, **e.params)
                    except AdmissionError as exc:
                        futures[i] = exc
                        continue
                    futures[i] = fut
                    fut.add_done_callback(_done)

            pacer = threading.Thread(target=pace, name="trace-pacer",
                                     daemon=True)
            pacer.start()
            pacer.join()
            digest = hashlib.sha256()
            completed = rejected = failed = 0
            for e, fut in zip(events, futures):
                if fut is None or isinstance(fut, Exception):
                    rejected += 1
                    continue
                try:
                    res = fut.result(result_timeout)
                except Exception:
                    failed += 1
                    continue
                completed += 1
                digest.update(np.ascontiguousarray(res.values).tobytes())
            wall = max((t for t in done_t if t is not None),
                       default=t0) - t0
            snap = svc.stats.snapshot()
            if ctl is not None:
                # post-drain settle: with traffic gone every window is thin,
                # each tick is a hold, and `converged` latches after
                # settle_ticks of them — bounded grace, not an open wait
                grace = (3 * ctl.config.settle_ticks
                         * max(controller_interval_s, 0.05))
                deadline = time.perf_counter() + grace
                while (not ctl.converged and ctl.error is None
                       and time.perf_counter() < deadline):
                    time.sleep(controller_interval_s / 2)
        finally:
            if ctl is not None:
                ctl.stop()
            if hub is not None:
                hub.sample()  # capture the final serving state in-ring
    got = sorted(v for v in lats if v is not None)
    occ = snap["batch_occupancy"]
    batches = sum(occ.values())
    out = dict(
        events=len(events), completed=completed, rejected=rejected,
        failed=failed, wall_seconds=wall,
        qps=completed / max(wall, 1e-9),
        p50_ms=percentile(got, 50) * 1e3, p95_ms=percentile(got, 95) * 1e3,
        p99_ms=percentile(got, 99) * 1e3,
        mean_ms=float(np.mean(got)) * 1e3 if got else 0.0,
        mean_occupancy=(sum(k * v for k, v in occ.items()) / batches
                        if batches else 0.0),
        batches=batches, result_digest=digest.hexdigest(),
        max_batch=svc.config.max_batch, max_wait_ms=svc.config.max_wait_ms,
    )
    if ctl is not None:
        out.update(converged=ctl.converged, adjustments=ctl.adjustments,
                   controller_ticks=ctl.ticks,
                   controller_error=repr(ctl.error) if ctl.error else None)
    return out


def _default_trace(n: int, *, qps: float, duration_s: float,
                   seed: int) -> LoadTrace:
    """The standard mixed open-loop workload: cheap bfs majority + sssp,
    with a 3x burst through the middle third (the regime change an
    adaptive policy has to ride out).  Exact apps only, so replays are
    bitwise-reproducible."""
    return LoadTrace.synthesize(
        duration_s=duration_s, qps=qps, mix={"bfs": 3.0, "sssp": 1.0},
        num_vertices=n, seed=seed, max_iters=32,
        burst=(duration_s / 3, 2 * duration_s / 3, 3.0))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="GraphService load generator (closed / open / replay)")
    ap.add_argument("--mode", choices=("closed", "open", "replay"),
                    default="closed")
    ap.add_argument("--scale", type=int, default=14,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", default=None,
                    help="serve an existing preprocessed graph instead of "
                         "generating one")
    # closed-loop shape
    ap.add_argument("--clients", type=int, nargs="+", default=[1, 4, 16])
    ap.add_argument("--queries", type=int, default=8,
                    help="queries per client (closed mode)")
    ap.add_argument("--app", default="ppr",
                    help="closed-mode app: ppr / sssp / bfs / cc / pagerank")
    ap.add_argument("--max-iters", type=int, default=30)
    # open-loop shape
    ap.add_argument("--qps", type=float, default=40.0,
                    help="open-mode Poisson arrival rate")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="open-mode trace length, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--speed", type=float, default=1.0,
                    help="replay time compression factor")
    # policy
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=25.0)
    ap.add_argument("--max-inflight", type=int, default=2)
    # traces
    ap.add_argument("--record-trace", default=None, metavar="FILE",
                    help="save submitted traffic as a LoadTrace JSONL")
    ap.add_argument("--replay-trace", default=None, metavar="FILE",
                    help="trace file for --mode replay")
    # self-tuning + telemetry
    ap.add_argument("--adaptive", action="store_true",
                    help="attach the SLO-aware controller (open/replay)")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0)
    ap.add_argument("--controller-interval", type=float, default=0.25)
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="stream MetricsHub JSONL snapshots here "
                         "(also honors GRAPHMP_METRICS)")
    ap.add_argument("--require-converged", action="store_true",
                    help="exit 1 unless the controller converged cleanly")
    args = ap.parse_args(argv)

    from repro.session import GraphSession

    store = args.graph or prepare_store(args.scale, args.edge_factor)

    if args.mode == "closed":
        recorder = (TraceRecorder(meta={"mode": "closed", "app": args.app})
                    if args.record_trace else None)
        batched = ServiceConfig(max_batch=args.max_batch,
                                max_wait_ms=args.max_wait_ms,
                                max_inflight=args.max_inflight,
                                memoize=False)
        print("policy,clients,qps,p50_ms,p95_ms,p99_ms,mean_occupancy,"
              "disk_MB")
        for clients in args.clients:
            for name, cfg in (("sequential", SEQUENTIAL),
                              ("batched", batched)):
                with GraphSession(store) as session:
                    r = run_load(session, clients=clients,
                                 queries_per_client=args.queries, config=cfg,
                                 app=args.app, max_iters=args.max_iters,
                                 recorder=(recorder if name == "batched"
                                           else None))
                print(f"{name},{clients},{r['qps']:.2f},{r['p50_ms']:.1f},"
                      f"{r['p95_ms']:.1f},{r['p99_ms']:.1f},"
                      f"{r['mean_occupancy']:.2f},{r['disk_bytes']/1e6:.1f}",
                      flush=True)
        if recorder is not None:
            recorder.save(args.record_trace)
            print(f"# recorded {len(recorder)} events -> "
                  f"{args.record_trace}")
        return 0

    # open / replay: one open-loop run against the configured policy
    if args.mode == "replay" and not args.replay_trace:
        ap.error("--mode replay needs --replay-trace FILE")
    cfg = ServiceConfig(max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        max_inflight=args.max_inflight, memoize=False)
    hub = None
    if args.metrics or os.environ.get("GRAPHMP_METRICS"):
        hub = MetricsHub(emit_path=args.metrics or None)
    try:
        with GraphSession(store) as session:
            if args.mode == "replay":
                trace = LoadTrace.load(args.replay_trace)
            else:
                trace = _default_trace(session.n, qps=args.qps,
                                       duration_s=args.duration,
                                       seed=args.seed)
            if args.record_trace:
                trace.save(args.record_trace)
                print(f"# trace: {len(trace)} events -> "
                      f"{args.record_trace}")
            r = replay_trace(session, trace, cfg, adaptive=args.adaptive,
                             slo_p99_ms=args.slo_p99_ms,
                             controller_interval_s=args.controller_interval,
                             hub=hub, speed=args.speed)
    finally:
        if hub is not None:
            hub.close()
    print("mode,events,completed,rejected,qps,p50_ms,p95_ms,p99_ms,"
          "mean_occupancy,max_batch,max_wait_ms")
    print(f"{args.mode},{r['events']},{r['completed']},{r['rejected']},"
          f"{r['qps']:.2f},{r['p50_ms']:.1f},{r['p95_ms']:.1f},"
          f"{r['p99_ms']:.1f},{r['mean_occupancy']:.2f},{r['max_batch']},"
          f"{r['max_wait_ms']:.2f}", flush=True)
    print(f"# result_digest={r['result_digest']}")
    if args.adaptive:
        print(f"# controller: ticks={r['controller_ticks']} "
              f"adjustments={r['adjustments']} converged={r['converged']} "
              f"error={r['controller_error']}")
        if args.require_converged and (not r["converged"]
                                       or r["controller_error"]):
            print("# FAIL: controller did not converge cleanly")
            return 1
    if r["failed"]:
        print(f"# FAIL: {r['failed']} requests errored")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
