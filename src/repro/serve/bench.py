"""Closed-loop load generator for GraphService (``python -m repro.serve.bench``).

Each of N client threads plays a user: submit one query, block on the
future, immediately submit the next — so concurrency in flight equals the
client count (a closed loop), and queries/sec measures the whole stack:
admission, coalescing, the batched VSW sweep, and future resolution.

The interesting comparison is the same traffic against two policies:

* ``sequential`` — ``max_batch=1, max_wait_ms=0, max_inflight=1``: honest
  one-query-at-a-time serving (what a naive wrapper around ``session.run``
  would do);
* ``batched`` — the real dynamic micro-batching policy.

With K concurrent clients issuing compatible queries, batched serving
should approach ONE sweep per K queries (PR 2's amortization), so
throughput climbs with client count while sequential stays flat.

Usage::

    PYTHONPATH=src python -m repro.serve.bench --scale 14 --clients 1 4 16

(benchmarks/fig_serve_throughput.py drives the same harness for the
acceptance sweep.)
"""
from __future__ import annotations

import argparse
import os
import tempfile
import threading
import time
from pathlib import Path

from repro.serve.graph_service import GraphService, ServiceConfig

SEQUENTIAL = ServiceConfig(max_batch=1, max_wait_ms=0.0, max_inflight=1,
                           memoize=False)


def prepare_store(scale: int = 14, edge_factor: int = 8,
                  base_dir: str | os.PathLike | None = None):
    """Preprocess (once, cached on disk) an RMAT graph for serving benches."""
    from repro.graph.generate import materialize, rmat_edges
    from repro.graph.preprocess import preprocess_graph
    from repro.graph.storage import GraphStore, write_edge_list

    base = Path(base_dir or os.environ.get(
        "BENCH_DIR", tempfile.gettempdir())) / "repro_serve_bench"
    tag = f"s{scale}_e{edge_factor}"
    out = base / f"store_{tag}"
    if (out / "property.json").exists():
        return GraphStore(out)
    src, dst = materialize(rmat_edges(scale=scale, edge_factor=edge_factor,
                                      seed=11))
    el = base / f"el_{tag}"
    if not (el / "meta.json").exists():
        write_edge_list(el, [(src, dst)], num_vertices=1 << scale)
    return preprocess_graph(str(el), str(out),
                            threshold_edge_num=1 << max(scale - 2, 10),
                            lane=16)


def run_load(session, *, clients: int, queries_per_client: int,
             config: ServiceConfig, app: str = "ppr", max_iters: int = 30,
             seed: int = 0, warmup: bool = True) -> dict:
    """Drive one closed-loop experiment; returns throughput + latency stats.

    Every client issues ``queries_per_client`` queries of ``app`` from
    deterministic, per-client-distinct sources (seeded), so runs are
    reproducible and memoization cannot shortcut the measurement — the
    speedup under test comes from COALESCING alone.
    """
    from repro.core.apps import batch_spec

    n = session.n
    spec = batch_spec(app)
    param = spec.source_param if spec is not None else None
    with GraphService(session, config) as svc:
        if warmup:
            svc.warmup(apps=(app,))
        errors: list[BaseException] = []
        lock = threading.Lock()

        def client(cid: int) -> None:
            for i in range(queries_per_client):
                # distinct sources per (client, query): no two in-flight
                # queries collapse to the same column or memo entry
                source = (seed + cid * queries_per_client + i) * 9973 % n
                try:
                    kw = {param: source} if param else {}
                    fut = svc.submit(app, max_iters=max_iters, **kw)
                    fut.result()
                except BaseException as exc:  # noqa: BLE001 — reported below
                    with lock:
                        errors.append(exc)
                    return

        threads = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        snap = svc.stats.snapshot()
    total = clients * queries_per_client
    occ = snap["batch_occupancy"]
    batches = sum(occ.values())
    return dict(
        clients=clients, queries=total, wall_seconds=wall,
        qps=total / max(wall, 1e-9),
        p50_ms=snap["p50_ms"], p95_ms=snap["p95_ms"], p99_ms=snap["p99_ms"],
        mean_occupancy=(sum(k * v for k, v in occ.items()) / batches
                        if batches else 0.0),
        batches=batches, disk_bytes=session.stats.disk_bytes,
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Closed-loop GraphService throughput benchmark")
    ap.add_argument("--scale", type=int, default=14,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--clients", type=int, nargs="+", default=[1, 4, 16])
    ap.add_argument("--queries", type=int, default=8,
                    help="queries per client")
    ap.add_argument("--app", default="ppr",
                    help="ppr (seed queries; the amortization-friendly "
                         "workload) / sssp / bfs / cc / pagerank")
    ap.add_argument("--max-iters", type=int, default=30)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=25.0)
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--graph", default=None,
                    help="serve an existing preprocessed graph instead of "
                         "generating one")
    args = ap.parse_args(argv)

    from repro.session import GraphSession

    store = args.graph or prepare_store(args.scale, args.edge_factor)
    batched = ServiceConfig(max_batch=args.max_batch,
                            max_wait_ms=args.max_wait_ms,
                            max_inflight=args.max_inflight, memoize=False)
    print("policy,clients,qps,p50_ms,p95_ms,p99_ms,mean_occupancy,disk_MB")
    for clients in args.clients:
        for name, cfg in (("sequential", SEQUENTIAL), ("batched", batched)):
            with GraphSession(store) as session:
                r = run_load(session, clients=clients,
                             queries_per_client=args.queries, config=cfg,
                             app=args.app, max_iters=args.max_iters)
            print(f"{name},{clients},{r['qps']:.2f},{r['p50_ms']:.1f},"
                  f"{r['p95_ms']:.1f},{r['p99_ms']:.1f},"
                  f"{r['mean_occupancy']:.2f},{r['disk_bytes']/1e6:.1f}",
                  flush=True)


if __name__ == "__main__":
    main()
