"""Serving layer.

``GraphService`` (graph_service.py) is the graph-query service: concurrent
single-query submissions dynamically micro-batched onto one shared
``GraphSession``.  ``ServeEngine`` (engine.py) is the LLM serving engine
kept from the seed code; it is imported lazily so graph serving does not
pull the model stack in.
"""
from repro.serve.graph_service import (AdmissionError, GraphService,
                                       ServiceClosed, ServiceConfig,
                                       ServiceStats, percentile)

__all__ = ["AdmissionError", "GraphService", "ServiceClosed", "ServiceConfig",
           "ServiceStats", "percentile", "ServeEngine"]


def __getattr__(name):
    if name == "ServeEngine":
        from repro.serve.engine import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
