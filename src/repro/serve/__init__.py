"""Serving layer.

``GraphService`` (graph_service.py) is the graph-query service: concurrent
single-query submissions dynamically micro-batched onto one shared
``GraphSession``.  ``ServeEngine`` (engine.py) is the LLM serving engine
kept from the seed code; it is imported lazily so graph serving does not
pull the model stack in.

Observability and self-tuning live in ``repro.obs`` (GraphPulse): attach a
``MetricsHub`` via ``GraphService.attach_hub`` / ``GraphSession.attach_hub``
and steer the batching policy with ``AdaptiveServeController`` through
``GraphService.reconfigure``.
"""
from repro.serve.graph_service import (AdmissionError, GraphService,
                                       MutationReport, ServiceClosed,
                                       ServiceConfig, ServiceStats,
                                       percentile)

__all__ = ["AdmissionError", "GraphService", "MutationReport",
           "ServiceClosed", "ServiceConfig", "ServiceStats", "percentile",
           "ServeEngine"]


def __getattr__(name):
    if name == "ServeEngine":
        from repro.serve.engine import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
