"""ESG baseline — a faithful-in-I/O, simplified X-Stream (SOSP'13).

Edge-centric scatter-gather with streaming partitions:
  phase 1 (scatter): stream the edge list from disk (D|E| read), emit one
  update record per edge to an on-disk updates file (C|E| write);
  phase 2 (gather): stream the updates (C|E| read), fold into vertex values,
  write vertices (C|V| write).

No sorting or index structures — exactly why its preprocessing is the
cheapest (Table 8) and its per-iteration I/O the fattest (Table 3).
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core.apps import VertexProgram
from repro.graph.storage import BytesCounter


class ESGEngine:
    def __init__(self, workdir: str, src: np.ndarray, dst: np.ndarray,
                 num_vertices: int, num_partitions: int = 8):
        self.dir = Path(workdir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n = num_vertices
        self.P = num_partitions
        self.io = BytesCounter()
        bounds = np.linspace(0, num_vertices, num_partitions + 1).astype(np.int64)
        self.bounds = bounds
        owner = np.searchsorted(bounds, src, side="right") - 1  # by SOURCE
        self.out_deg = np.bincount(src, minlength=num_vertices).astype(np.int64)
        for p in range(num_partitions):
            m = owner == p
            arr = np.stack([src[m], dst[m]])
            np.save(self.dir / f"edges_{p}.npy", arr)  # unsorted append-only
            self.io.written += arr.nbytes

    def _read(self, name):
        p = self.dir / name
        arr = np.load(p)
        self.io.read += p.stat().st_size
        return arr

    def _write(self, name, arr):
        np.save(self.dir / name, arr)
        self.io.written += (self.dir / name).stat().st_size

    def run(self, program: VertexProgram, max_iters: int = 100):
        import jax.numpy as jnp
        vals, _ = program.init(self.n, None, self.out_deg)
        self._write("vertices.npy", vals.astype(np.float32))
        t0 = time.time()
        it = 0
        for it in range(1, max_iters + 1):
            vertices = self._read("vertices.npy")
            x = np.asarray(program.gather_transform(
                jnp.asarray(vertices), jnp.asarray(self.out_deg.astype(np.float32))))
            # scatter: stream edges, write update records (dst, value)
            for p in range(self.P):
                edges = self._read(f"edges_{p}.npy")     # D|E| read
                w = 1.0 if program.semiring == "min_plus" else 0.0
                upd = np.stack([edges[1].astype(np.float32),
                                x[edges[0]].astype(np.float32) + w])
                self._write(f"updates_{p}.npy", upd)      # C|E| write
            # gather: stream updates, fold into vertices
            plus = program.semiring.startswith("plus")
            part = np.zeros(self.n, np.float32) if plus else np.full(self.n, np.inf,
                                                                     np.float32)
            for p in range(self.P):
                upd = self._read(f"updates_{p}.npy")      # C|E| read
                d = upd[0].astype(np.int64)
                if plus:
                    np.add.at(part, d, upd[1])
                else:
                    np.minimum.at(part, d, upd[1])
            new_vals = np.asarray(program.post(jnp.asarray(part),
                                               jnp.asarray(vertices), self.n))
            if not program.semiring.startswith("plus"):
                new_vals = np.minimum(new_vals, vertices)
            changed = np.asarray(program.changed(jnp.asarray(new_vals),
                                                 jnp.asarray(vertices)))
            self._write("vertices.npy", new_vals)         # C|V| write
            if not changed.any():
                break
        return self._read("vertices.npy"), it, time.time() - t0
