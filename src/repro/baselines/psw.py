"""PSW baseline — a faithful-in-I/O, simplified GraphChi (OSDI'12).

What matters for the paper's comparison (Table 3) is the I/O *pattern*, which
this reproduces with real files:
  * vertex values live ON DISK and are read+written every iteration (C|V|);
  * edges carry attached source-vertex values (record size C+D), so each
    iteration reads 2(C+D)|E|-ish and re-writes edge values after vertices
    change — the PSW model's defining cost;
  * computation itself is vectorized numpy (we are benchmarking I/O patterns,
    not Python loops).

GraphMP's advantage in the Table-5 benchmark is therefore structural (VSW
keeps vertices in memory and never writes them), not an artifact of a slow
baseline implementation.
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core.apps import VertexProgram
from repro.graph.storage import BytesCounter


class PSWEngine:
    def __init__(self, workdir: str, src: np.ndarray, dst: np.ndarray,
                 num_vertices: int, num_shards: int = 8):
        self.dir = Path(workdir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n = num_vertices
        self.P = num_shards
        self.io = BytesCounter()
        bounds = np.linspace(0, num_vertices, num_shards + 1).astype(np.int64)
        self.bounds = bounds
        owner = np.searchsorted(bounds, dst, side="right") - 1
        self.out_deg = np.bincount(src, minlength=num_vertices).astype(np.int64)
        for p in range(num_shards):
            m = owner == p
            # GraphChi stores edges sorted by source within a shard
            order = np.argsort(src[m], kind="stable")
            arr = np.stack([src[m][order], dst[m][order]])
            np.save(self.dir / f"edges_{p}.npy", arr)
            self.io.written += arr.nbytes
            # attached edge values (the C in C+D)
            ev = np.zeros(m.sum(), dtype=np.float32)
            np.save(self.dir / f"evals_{p}.npy", ev)
            self.io.written += ev.nbytes

    def _read(self, name):
        p = self.dir / name
        arr = np.load(p)
        self.io.read += p.stat().st_size
        return arr

    def _write(self, name, arr):
        np.save(self.dir / name, arr)
        self.io.written += (self.dir / name).stat().st_size

    def run(self, program: VertexProgram, max_iters: int = 100) -> tuple[np.ndarray, int, float]:
        import jax.numpy as jnp
        vals, _ = program.init(self.n, None, self.out_deg)
        self._write("vertices.npy", vals.astype(np.float32))
        # seed edge values with gather-transformed source values
        x0 = np.asarray(program.gather_transform(
            jnp.asarray(vals.astype(np.float32)),
            jnp.asarray(self.out_deg.astype(np.float32))))
        for p in range(self.P):
            edges = self._read(f"edges_{p}.npy")
            self._write(f"evals_{p}.npy", x0[edges[0]].astype(np.float32))
        t0 = time.time()
        it = 0
        for it in range(1, max_iters + 1):
            vertices = self._read("vertices.npy")  # C|V| read
            new_vals = vertices.copy()
            x = np.asarray(program.gather_transform(
                jnp.asarray(vertices), jnp.asarray(self.out_deg.astype(np.float32))))
            changed_any = False
            for p in range(self.P):
                edges = self._read(f"edges_{p}.npy")       # D|E| read
                evals = self._read(f"evals_{p}.npy")       # C|E| read (attached)
                lo, hi = self.bounds[p], self.bounds[p + 1]
                contrib = evals  # values attached to in-edges (already x[src])
                if program.semiring.startswith("plus"):
                    part = np.zeros(hi - lo, np.float32)
                    np.add.at(part, edges[1] - lo, contrib)
                else:
                    part = np.full(hi - lo, np.inf, np.float32)
                    w = 1.0 if program.semiring == "min_plus" else 0.0
                    np.minimum.at(part, edges[1] - lo, contrib + w)
                old = vertices[lo:hi]
                upd = np.asarray(program.post(jnp.asarray(part), jnp.asarray(old), self.n))
                # degree-0 vertices with min semirings keep old values
                if not program.semiring.startswith("plus"):
                    upd = np.minimum(upd, old)
                new_vals[lo:hi] = upd
            changed = np.asarray(program.changed(jnp.asarray(new_vals),
                                                 jnp.asarray(vertices)))
            changed_any = bool(changed.any())
            self._write("vertices.npy", new_vals)          # C|V| write
            xn = np.asarray(program.gather_transform(
                jnp.asarray(new_vals), jnp.asarray(self.out_deg.astype(np.float32))))
            for p in range(self.P):                        # (C+D)|E| write
                edges = self._read(f"edges_{p}.npy")
                self._write(f"evals_{p}.npy", xn[edges[0]].astype(np.float32))
            if not changed_any:
                break
        return self._read("vertices.npy"), it, time.time() - t0
