"""GraphPulse: observability + self-tuning for the serving stack.

Three pieces (see each module's docstring for the design):

* ``repro.obs.metrics`` — bounded telemetry primitives (``Reservoir``
  log-binned histograms with documented percentile error, ``MetricsHub``
  registry + JSONL snapshot emitter, schema validation / CLI).
* ``repro.obs.controller`` — ``AdaptiveServeController``, the SLO-aware
  feedback loop steering ``GraphService.reconfigure``.
* ``repro.obs.trace`` — ``LoadTrace`` record/replay format so policy
  changes are benchmarked against recorded traffic.
"""
from repro.obs.controller import (AdaptiveServeController, ControllerConfig,
                                  Decision)
from repro.obs.metrics import (Counter, Gauge, MetricsHub, Reservoir,
                               validate_file, validate_snapshot)
from repro.obs.trace import LoadTrace, TraceEvent, TraceRecorder

__all__ = [
    "AdaptiveServeController",
    "ControllerConfig",
    "Counter",
    "Decision",
    "Gauge",
    "LoadTrace",
    "MetricsHub",
    "Reservoir",
    "TraceEvent",
    "TraceRecorder",
    "validate_file",
    "validate_snapshot",
]
