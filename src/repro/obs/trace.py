"""LoadTrace: record/replay format for GraphServe traffic.

Policy changes (batching windows, fair-share weights, the adaptive
controller itself) must be judged against the SAME traffic, or the
comparison measures the load generator, not the policy.  A ``LoadTrace``
is that fixed traffic: a sorted sequence of arrival events, each an offset
from trace start plus the exact ``submit()`` arguments.

On-disk format — JSONL, one object per line:

    {"trace": 1, "meta": {"seed": 7, "qps": 40.0, ...}}   # optional header
    {"t": 0.0132, "app": "sssp", "params": {"source": 311, "max_iters": 64}}
    {"t": 0.0279, "app": "bfs",  "params": {"source": 19, "max_iters": 64}}

``t`` is seconds since trace start (non-negative; events are kept sorted).
``params`` is passed to ``GraphService.submit(app, **params)`` verbatim at
replay, so a trace replays bit-for-bit: same apps, same sources, same
iteration caps.  The committed mini-trace under ``benchmarks/traces/``
uses only *exact* app families (min-propagation sssp/bfs), so replayed
request results are bitwise identical run to run regardless of how the
policy happens to coalesce them (``tests/test_trace.py`` pins this).

``TraceRecorder`` captures live traffic (``serve/bench.py --record-trace``
hooks it into both the closed and open loop); ``LoadTrace.synthesize``
generates reproducible Poisson traffic with an optional mid-trace burst —
how the committed mini-trace was produced (generator committed with it).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from pathlib import Path

import numpy as np

TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: ``t`` seconds after trace start, submit ``app, **params``."""

    t: float
    app: str
    params: dict

    def to_json(self) -> str:
        return json.dumps({"t": round(self.t, 6), "app": self.app,
                           "params": self.params}, sort_keys=True)


def _parse_event(obj: dict, where: str) -> TraceEvent:
    try:
        t = float(obj["t"])
        app = obj["app"]
        params = obj.get("params", {})
    except (KeyError, TypeError, ValueError):
        raise ValueError(f"{where}: malformed trace event {obj!r}") from None
    if t < 0 or not isinstance(app, str) or not isinstance(params, dict):
        raise ValueError(f"{where}: malformed trace event {obj!r}")
    return TraceEvent(t=t, app=app, params=params)


class LoadTrace:
    """An immutable, time-sorted sequence of ``TraceEvent``s plus metadata."""

    def __init__(self, events, meta: dict | None = None):
        self.events: tuple[TraceEvent, ...] = tuple(
            sorted(events, key=lambda e: e.t))
        self.meta: dict = dict(meta or {})

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __getitem__(self, i):
        return self.events[i]

    @property
    def duration(self) -> float:
        return self.events[-1].t if self.events else 0.0

    def apps(self) -> dict:
        """{app: event count} — the traffic mix at a glance."""
        out: dict[str, int] = {}
        for e in self.events:
            out[e.app] = out.get(e.app, 0) + 1
        return dict(sorted(out.items()))

    def mean_qps(self) -> float:
        return len(self.events) / self.duration if self.duration > 0 else 0.0

    # -- persistence -----------------------------------------------------
    def save(self, path: str | os.PathLike) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps({"trace": TRACE_VERSION, "meta": self.meta},
                               sort_keys=True) + "\n")
            for e in self.events:
                f.write(e.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str | os.PathLike) -> "LoadTrace":
        path = Path(path)
        events, meta = [], {}
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                where = f"{path}:{lineno}"
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError:
                    raise ValueError(f"{where}: not JSON") from None
                if not isinstance(obj, dict):
                    raise ValueError(f"{where}: expected an object")
                if "trace" in obj:  # header line
                    if obj["trace"] != TRACE_VERSION:
                        raise ValueError(
                            f"{where}: unknown trace version "
                            f"{obj['trace']!r}")
                    meta = dict(obj.get("meta", {}))
                    continue
                events.append(_parse_event(obj, where))
        if not events:
            raise ValueError(f"{path}: trace has no events")
        return cls(events, meta)

    # -- synthesis -------------------------------------------------------
    @classmethod
    def synthesize(cls, *, duration_s: float, qps: float, mix: dict,
                   num_vertices: int, seed: int = 0, max_iters: int = 64,
                   params_by_app: dict | None = None,
                   burst: tuple | None = None) -> "LoadTrace":
        """Reproducible Poisson traffic: exponential inter-arrivals at
        ``qps``, apps drawn by ``mix`` weights, sources uniform over
        ``num_vertices`` (apps with a ``BatchSpec`` get the spec's source
        param; others run source-free).  ``burst=(start_s, end_s, factor)``
        multiplies the arrival rate inside that span — the regime change
        the adaptive controller has to ride out.  Same arguments, same
        trace, bit for bit (seeded ``RandomState``).
        """
        from repro.core.apps import batch_spec

        if qps <= 0 or duration_s <= 0:
            raise ValueError("duration_s and qps must be > 0")
        if not mix or any(w <= 0 for w in mix.values()):
            raise ValueError(f"mix must map apps to positive weights: {mix!r}")
        rng = np.random.RandomState(seed)
        apps = sorted(mix)
        weights = np.asarray([mix[a] for a in apps], dtype=np.float64)
        weights /= weights.sum()
        params_by_app = params_by_app or {}
        events, t = [], 0.0
        while True:
            rate = qps
            if burst is not None and burst[0] <= t < burst[1]:
                rate = qps * burst[2]
            t += float(rng.exponential(1.0 / rate))
            if t >= duration_s:
                break
            app = apps[int(rng.choice(len(apps), p=weights))]
            params = dict(params_by_app.get(app, {}))
            params.setdefault("max_iters", max_iters)
            spec = batch_spec(app)
            if spec is not None and spec.source_param not in params:
                params[spec.source_param] = int(rng.randint(num_vertices))
            events.append(TraceEvent(t=t, app=app, params=params))
        meta = {"seed": seed, "qps": qps, "duration_s": duration_s,
                "mix": dict(sorted(mix.items())),
                "num_vertices": num_vertices, "max_iters": max_iters}
        if burst is not None:
            meta["burst"] = list(burst)
        return cls(events, meta)

    def __repr__(self) -> str:
        return (f"LoadTrace({len(self.events)} events, "
                f"{self.duration:.2f}s, apps={self.apps()})")


class TraceRecorder:
    """Thread-safe capture of live submissions into a ``LoadTrace``.

    ``record(app, params)`` stamps the event at now minus the first
    record's timestamp (so traces always start near 0); pass ``t=`` to
    record an *intended* arrival offset instead — the open-loop bench does
    this so the recorded trace is the schedule, not the schedule plus
    generator jitter.
    """

    def __init__(self, meta: dict | None = None, clock=time.perf_counter):
        self.meta = dict(meta or {})
        self._clock = clock
        self._lock = threading.Lock()
        self._t0: float | None = None
        self._events: list[TraceEvent] = []

    def record(self, app: str, params: dict, t: float | None = None) -> None:
        with self._lock:
            if t is None:
                now = self._clock()
                if self._t0 is None:
                    self._t0 = now
                t = now - self._t0
            self._events.append(TraceEvent(t=float(t), app=app,
                                           params=dict(params)))

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def trace(self) -> LoadTrace:
        with self._lock:
            return LoadTrace(self._events, self.meta)

    def save(self, path: str | os.PathLike) -> Path:
        return self.trace().save(path)
