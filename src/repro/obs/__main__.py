"""``python -m repro.obs FILE...`` — validate metrics JSONL files."""
from repro.obs.metrics import main

raise SystemExit(main())
