"""GraphPulse telemetry: bounded metrics primitives + a structured emitter.

GraphMP's premise is that the right execution strategy depends on runtime
conditions (available memory, cache hit rate, frontier density — NXgraph
makes the same argument for strategy *selection*), yet a point-in-time
``snapshot()`` is all the serving layer had.  This module is the telemetry
half of the fix:

* ``Reservoir`` — a bounded log-binned histogram with a **documented
  percentile error**: quantiles are reported as the geometric midpoint of
  the bin holding the nearest-rank sample, so the relative error is at most
  ``sqrt(growth) - 1`` (< 1% at the default ``growth=1.02``) for values
  inside ``[min_value, max_value]``.  Memory is O(#bins), independent of
  how many observations arrive — a long-lived service never accumulates
  one float per request.  Bin counts are exposed (``counts()``) and
  quantiles can be computed over a counts *delta*, which is how the
  adaptive controller gets rolling-window percentiles without a second
  data structure.
* ``MetricsHub`` — a named registry of counters (monotone), gauges (last
  value wins) and histograms (``Reservoir``), plus *pollers* (callables
  returning a dict, flattened into gauges at sample time — how
  ``CompressedShardCache.report()`` and ``ServiceStats`` feed the hub
  without double bookkeeping).  ``sample()`` takes one self-consistent
  snapshot dict, retains a bounded ring of them for the in-process
  ``timeseries()`` API, and — when an emit path is configured
  (``GRAPHMP_METRICS``) — a background thread appends one JSON object per
  line every ``GRAPHMP_METRICS_INTERVAL`` seconds.
* ``validate_snapshot`` / ``python -m repro.obs.metrics file.jsonl`` — the
  snapshot schema, enforced; CI replays the committed load trace and
  schema-checks the JSONL this module emitted.

Everything here is stdlib + numpy: no metrics backend dependency, and all
structures are thread-safe (instrumentation hooks fire from client, runner
and pipeline threads concurrently).
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from collections import OrderedDict, deque
from pathlib import Path

import numpy as np

SNAPSHOT_VERSION = 1

# quantiles every histogram snapshot reports (p50 the median, p99 the SLO
# edge the controller steers on)
HISTOGRAM_QUANTILES = (50, 90, 95, 99)


class Counter:
    """Monotone counter (float-valued: byte totals and stall *seconds* are
    both counters).  ``inc`` with a negative amount is a programming error."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters are monotone; inc({amount!r})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-value-wins instantaneous measurement (queue depth, hit ratio)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Reservoir:
    """Bounded log-binned histogram with a documented quantile error.

    Bin layout (``nbins + 2`` int64 counts, ~10 KB at the defaults):

    * bin 0: values ``<= min_value`` (including zero and negatives) —
      reported as ``min_value`` exactly, so the *absolute* error down there
      is at most ``min_value``;
    * bin ``i`` in ``1..nbins``: ``(min_value * g^(i-1), min_value * g^i]``
      — reported as the geometric midpoint ``min_value * g^(i-0.5)``, so
      the *relative* error is at most ``sqrt(g) - 1`` (< 1% at the default
      ``growth = 1.02``; ``tests/test_obs.py`` regression-pins this bound
      against exact nearest-rank percentiles);
    * the last bin catches values ``> max_value`` (reported as
      ``max_value`` — a clamp, not an estimate).

    ``quantile(q)`` locates the bin containing the ceil(q/100 * N)-th
    smallest observation — the same nearest-rank definition the serving
    stats always used — in O(#bins).  ``count``/``sum``/``min``/``max``
    are tracked exactly.  ``quantile(q, counts=...)`` evaluates an
    arbitrary counts vector with this reservoir's bin geometry: subtract
    two ``counts()`` snapshots and you have an exact rolling-window
    percentile, which is how ``AdaptiveServeController`` reads "p99 since
    my last tick" without any extra recording machinery.
    """

    def __init__(self, min_value: float = 1e-6, max_value: float = 1e5,
                 growth: float = 1.02):
        if not (0 < min_value < max_value):
            raise ValueError(
                f"need 0 < min_value < max_value, got {min_value!r}, "
                f"{max_value!r}")
        if not growth > 1.0:
            raise ValueError(f"growth must be > 1, got {growth!r}")
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.nbins = int(math.ceil(
            math.log(self.max_value / self.min_value) / self._log_g))
        self._lock = threading.Lock()
        self._counts = np.zeros(self.nbins + 2, dtype=np.int64)
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # -- recording -------------------------------------------------------
    def _index(self, value: float) -> int:
        if value <= self.min_value:
            return 0
        if value > self.max_value:
            return self.nbins + 1
        # value in (min * g^(i-1), min * g^i]  =>  i = ceil(log_g(v/min))
        i = int(math.ceil(math.log(value / self.min_value) / self._log_g
                          - 1e-12))
        return min(max(i, 1), self.nbins)

    def observe(self, value: float) -> None:
        value = float(value)
        idx = self._index(value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._min = min(self._min, value)
            self._max = max(self._max, value)

    # -- reading ---------------------------------------------------------
    @property
    def count(self) -> int:
        with self._lock:
            return int(self._counts.sum())

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def min(self) -> float:
        with self._lock:
            return self._min if math.isfinite(self._min) else 0.0

    @property
    def max(self) -> float:
        with self._lock:
            return self._max if math.isfinite(self._max) else 0.0

    @property
    def mean(self) -> float:
        with self._lock:
            n = int(self._counts.sum())
            return self._sum / n if n else 0.0

    def counts(self) -> np.ndarray:
        """Consistent copy of the bin counts (subtract two snapshots for a
        rolling window; pass the difference back to ``quantile``)."""
        with self._lock:
            return self._counts.copy()

    def _bin_value(self, idx: int) -> float:
        if idx <= 0:
            return self.min_value
        if idx >= self.nbins + 1:
            return self.max_value
        return self.min_value * self.growth ** (idx - 0.5)

    def quantile(self, q: float, counts: np.ndarray | None = None) -> float:
        """Nearest-rank quantile (bin-midpoint estimate, error documented in
        the class docstring).  ``counts`` overrides the live counts — pass a
        snapshot delta for a windowed percentile.  Empty data -> 0.0."""
        if not 0 < q <= 100:
            raise ValueError(f"quantile q must be in (0, 100], got {q!r}")
        if counts is None:
            counts = self.counts()
        n = int(counts.sum())
        if n <= 0:
            return 0.0
        rank = math.ceil(q / 100.0 * n)  # 1-based nearest rank
        cum = 0
        for idx, c in enumerate(counts):
            cum += int(c)
            if cum >= rank:
                return self._bin_value(idx)
        return self._bin_value(len(counts) - 1)  # unreachable

    def to_dict(self, scale: float = 1.0) -> dict:
        """One snapshot dict (``scale`` converts units, e.g. 1e3 for
        seconds -> milliseconds in the emitted metric)."""
        with self._lock:
            counts = self._counts.copy()
            total = int(counts.sum())
            s = self._sum
            lo = self._min if math.isfinite(self._min) else 0.0
            hi = self._max if math.isfinite(self._max) else 0.0
        out = {
            "count": total,
            "sum": s * scale,
            "min": lo * scale,
            "max": hi * scale,
            "mean": (s / total if total else 0.0) * scale,
        }
        for q in HISTOGRAM_QUANTILES:
            out[f"p{q}"] = self.quantile(q, counts=counts) * scale
        return out


class MetricsHub:
    """Named registry of counters/gauges/histograms + snapshot emitter.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` get-or-create
    by name (first caller fixes a histogram's bin geometry);
    ``adopt_histogram(name, reservoir)`` registers an *existing* Reservoir
    so a producer (``ServiceStats``) and the hub share ONE bounded
    structure instead of recording twice.  ``register_poller(prefix, fn)``
    attaches a callable returning a (possibly nested) dict; at ``sample()``
    time its numeric leaves become gauges named ``prefix.key`` — how
    ``cache.report()`` and service queue depths enter snapshots without
    hub-aware call sites.

    ``sample()`` returns the snapshot dict, appends it to a bounded ring
    (``retain``), and — when constructed with ``emit_path`` (default: env
    ``GRAPHMP_METRICS``; empty/unset disables) — is called periodically by
    a daemon thread (``emit_interval``, env ``GRAPHMP_METRICS_INTERVAL``,
    default 1.0 s) that appends one JSON line per sample.  ``close()``
    stops the thread and emits one final snapshot, and is idempotent;
    after it, recording calls still work (cheap, in-memory) but nothing
    more is written.

    ``timeseries(name)`` reads the retained ring: a list of ``(t, value)``
    for a counter/gauge name, or ``(t, dict)`` for a histogram.  ``t`` is
    seconds since the hub started (monotonic clock), so emitted files from
    repeated runs line up at 0.
    """

    def __init__(self, emit_path: str | os.PathLike | None = None, *,
                 emit_interval: float | None = None, retain: int = 1024,
                 clock=time.monotonic):
        if emit_path is None:
            emit_path = os.environ.get("GRAPHMP_METRICS") or None
        if emit_interval is None:
            try:
                emit_interval = float(
                    os.environ.get("GRAPHMP_METRICS_INTERVAL", "") or 1.0)
            except ValueError:
                emit_interval = 1.0
        self.emit_path = Path(emit_path) if emit_path else None
        self.emit_interval = max(float(emit_interval), 0.05)
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.RLock()
        self._counters: OrderedDict[str, Counter] = OrderedDict()
        self._gauges: OrderedDict[str, Gauge] = OrderedDict()
        self._histograms: OrderedDict[str, Reservoir] = OrderedDict()
        self._pollers: OrderedDict[str, object] = OrderedDict()
        self._ring: deque[dict] = deque(maxlen=max(int(retain), 1))
        self._file = None
        self._closed = False
        self._stop = threading.Event()
        self._thread = None
        if self.emit_path is not None:
            self.emit_path.parent.mkdir(parents=True, exist_ok=True)
            self._file = open(self.emit_path, "a", buffering=1)
            self._thread = threading.Thread(
                target=self._emit_loop, name="graphpulse-emit", daemon=True)
            self._thread.start()

    # -- registry --------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str, **reservoir_kwargs) -> Reservoir:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Reservoir(**reservoir_kwargs)
            return h

    def adopt_histogram(self, name: str, reservoir: Reservoir) -> Reservoir:
        """Register an existing Reservoir under ``name`` (shared-structure
        wiring; replaces any previous registration)."""
        with self._lock:
            self._histograms[name] = reservoir
            return reservoir

    def register_poller(self, prefix: str, fn) -> None:
        """``fn() -> dict``; numeric leaves appear as gauges ``prefix.key``
        (nested dicts flatten with dots, non-numeric leaves are skipped)."""
        with self._lock:
            self._pollers[prefix] = fn

    def unregister_poller(self, prefix: str) -> None:
        with self._lock:
            self._pollers.pop(prefix, None)

    # -- snapshots -------------------------------------------------------
    @staticmethod
    def _flatten(prefix: str, obj, out: dict) -> None:
        if isinstance(obj, dict):
            for k, v in obj.items():
                MetricsHub._flatten(f"{prefix}.{k}", v, out)
        elif isinstance(obj, (list, tuple)):
            for i, v in enumerate(obj):
                MetricsHub._flatten(f"{prefix}.{i}", v, out)
        elif isinstance(obj, bool):
            out[prefix] = float(obj)
        elif isinstance(obj, (int, float, np.integer, np.floating)):
            v = float(obj)
            if math.isfinite(v):
                out[prefix] = v
        # strings and other leaves are labels, not metrics: skipped

    def sample(self) -> dict:
        """Take one snapshot: run pollers, read every metric, append to the
        retained ring, and return the dict (callers may emit or inspect)."""
        with self._lock:
            pollers = list(self._pollers.items())
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = {n: h.to_dict() for n, h in self._histograms.items()}
        for prefix, fn in pollers:
            try:
                polled = fn()
            except Exception:
                continue  # a dead poller must not kill the emitter
            if isinstance(polled, dict):
                self._flatten(prefix, polled, gauges)
        snap = {
            "v": SNAPSHOT_VERSION,
            "t": round(self._clock() - self._t0, 6),
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
        }
        with self._lock:
            self._ring.append(snap)
        return snap

    def timeseries(self, name: str) -> list[tuple]:
        """``[(t, value), ...]`` for a metric across retained snapshots
        (counters and gauges yield floats; histograms yield their snapshot
        dicts; unknown names yield an empty list)."""
        with self._lock:
            snaps = list(self._ring)
        out = []
        for s in snaps:
            for section in ("gauges", "counters", "histograms"):
                if name in s[section]:
                    out.append((s["t"], s[section][name]))
                    break
        return out

    @property
    def snapshots(self) -> list[dict]:
        with self._lock:
            return list(self._ring)

    # -- emission --------------------------------------------------------
    def emit(self, snapshot: dict | None = None) -> None:
        """Append one snapshot as a JSON line (no-op without an emit path
        or after close)."""
        if snapshot is None:
            snapshot = self.sample()
        with self._lock:
            if self._file is None or self._closed:
                return
            self._file.write(json.dumps(snapshot, sort_keys=True) + "\n")

    def _emit_loop(self) -> None:
        while not self._stop.wait(self.emit_interval):
            self.emit()

    def close(self) -> None:
        """Stop the emitter and flush a final snapshot.  Idempotent; the
        in-memory registry keeps working afterwards."""
        with self._lock:
            if self._closed:
                return
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        if self._file is not None:
            self.emit()  # final snapshot: a run's last state always lands
        with self._lock:
            self._closed = True
            if self._file is not None:
                self._file.close()
                self._file = None

    def __enter__(self) -> "MetricsHub":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# schema validation (CI gates emitted files on this)
# ---------------------------------------------------------------------------
_HIST_REQUIRED = ("count", "sum", "min", "max", "mean") + tuple(
    f"p{q}" for q in HISTOGRAM_QUANTILES)


def _require_number(value, what: str) -> float:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise ValueError(f"{what} must be a number, got {value!r}")
    if not math.isfinite(value):
        raise ValueError(f"{what} must be finite, got {value!r}")
    return float(value)


def validate_snapshot(obj) -> None:
    """Raise ``ValueError`` unless ``obj`` is a well-formed v1 snapshot:
    ``v == 1``, ``t`` a finite number >= 0, ``counters``/``gauges`` dicts of
    finite numbers (counters >= 0), ``histograms`` a dict of dicts carrying
    ``count``/``sum``/``min``/``max``/``mean``/``p50``/``p90``/``p95``/
    ``p99`` with a non-negative integer count."""
    if not isinstance(obj, dict):
        raise ValueError(f"snapshot must be a dict, got {type(obj).__name__}")
    if obj.get("v") != SNAPSHOT_VERSION:
        raise ValueError(f"unknown snapshot version {obj.get('v')!r}")
    if _require_number(obj.get("t"), "t") < 0:
        raise ValueError(f"t must be >= 0, got {obj['t']!r}")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(obj.get(section), dict):
            raise ValueError(f"missing/invalid section {section!r}")
    for name, value in obj["counters"].items():
        if _require_number(value, f"counter {name!r}") < 0:
            raise ValueError(f"counter {name!r} is negative: {value!r}")
    for name, value in obj["gauges"].items():
        _require_number(value, f"gauge {name!r}")
    for name, hist in obj["histograms"].items():
        if not isinstance(hist, dict):
            raise ValueError(f"histogram {name!r} must be a dict")
        for field in _HIST_REQUIRED:
            if field not in hist:
                raise ValueError(f"histogram {name!r} missing {field!r}")
            _require_number(hist[field], f"histogram {name!r}.{field}")
        count = hist["count"]
        if not isinstance(count, int) or isinstance(count, bool) or count < 0:
            raise ValueError(
                f"histogram {name!r}.count must be an int >= 0, got "
                f"{count!r}")


def validate_file(path: str | os.PathLike) -> int:
    """Validate every line of a metrics JSONL file; returns the number of
    snapshots, raises ``ValueError`` (with the line number) on the first
    malformed one.  Zero lines is an error: an 'emitting' run must emit."""
    count = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
                validate_snapshot(obj)
            except ValueError as exc:
                raise ValueError(f"{path}:{lineno}: {exc}") from None
            count += 1
    if count == 0:
        raise ValueError(f"{path}: no snapshots emitted")
    return count


def main(argv=None) -> int:
    """``python -m repro.obs.metrics FILE...`` — schema-check metrics JSONL
    files (what the CI autotune job runs on the replay's emissions)."""
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate GraphPulse metrics JSONL files")
    ap.add_argument("files", nargs="+", help="metrics .jsonl files to check")
    args = ap.parse_args(argv)
    for path in args.files:
        try:
            n = validate_file(path)
        except (OSError, ValueError) as exc:
            print(f"FAIL {exc}")
            return 1
        print(f"ok {path}: {n} snapshots")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
