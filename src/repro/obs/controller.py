"""SLO-aware adaptive batching controller for GraphService.

``ServiceConfig`` fixes ``max_batch``/``max_wait_ms`` up front, but the
right values change minute to minute with traffic: a straggler window that
buys 12-column occupancy at 40 qps is pure added latency at 2 qps, and a
batch cap tuned for PPR seeds is too small when a BFS burst floods the
queue.  NXgraph's lesson (PAPERS.md) — pick the execution strategy from
*observed* conditions, not a priori — applied to the serving layer:

    MetricsHub / ServiceStats reservoirs
        │  (windowed p99, batch occupancy, queue depth)
        ▼
    AdaptiveServeController.tick()          every ``interval_s``
        │  hysteresis band around the SLO, clamped multiplicative steps
        ▼
    GraphService.reconfigure(max_batch=…, max_wait_ms=…)

Control law (one knob move per tick, multiplicative steps, hard clamps):

* **p99 above SLO·(1+hysteresis)** — the service is missing its target,
  and the *cause* decides the direction.  If the queue is deep
  (> 2·max_batch pending) the bottleneck is sweep throughput: raise
  ``max_batch`` so each sweep retires more queries.  Else if batches are
  already coalescing (mean occupancy ≥ ``coalesce_occupancy``) the breach
  is queueing/service time, not straggler-waiting — *raise*
  ``max_wait_ms``: under backlog full groups dispatch immediately, so the
  window cap adds no latency while harder coalescing lifts capacity
  (shrinking here is the classic mistake: it cuts coalescing exactly when
  the service is drowning).  Only when occupancy is low — most sweeps are
  near-singletons, so the window itself is plausibly the latency — shrink
  ``max_wait_ms`` (never by less than ``min_wait_step_ms`` — a 2% shave
  of a 0.01 ms window is not progress).
* **p99 below SLO·(1−hysteresis) with low occupancy and a shallow queue**
  — there is latency headroom being wasted on under-filled sweeps: raise
  ``max_wait_ms`` to harvest occupancy.  Guarded *predictively*: the raise
  is applied only if ``p99 + added_wait`` still clears the lower band, so
  the controller cannot talk itself into a breach it then has to undo
  (the classic limit-cycle oscillation; ``tests/test_controller.py`` pins
  steadiness on a steady trace).
* **inside the band** — hold.  ``settle_ticks`` consecutive holds set
  ``converged`` (the CI autotune job asserts this on the committed trace).

``tick()`` is deliberately clock-free and deterministic: it consumes only
*deltas* of the stats reservoirs since the previous tick (bin-count
subtraction, see ``Reservoir.quantile(counts=...)``), so unit tests drive
it with a fake service and hand-fed latencies — no sleeping, no wall
clock.  ``start()`` wraps it in a daemon thread for real deployments; the
loop exits cleanly when the service closes under it (``ServiceClosed`` is
the normal shutdown signal, in either close order — the close-race
satellite).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Targets, clamps and gains for ``AdaptiveServeController``.

    slo_p99_ms:
        The latency objective: windowed p99 the controller steers to keep
        below this.
    min_batch / max_batch_limit, min_wait_ms / max_wait_ms_limit:
        Hard clamps on the two knobs — the controller never proposes a
        value outside these, whatever the stats say.
    hysteresis:
        Dead band around the SLO as a fraction: no action while p99 is in
        ``[slo·(1−h), slo·(1+h)]``.  Wider = steadier, slower to react.
    step:
        Multiplicative step per adjustment (batch sizes round up).
    min_wait_step_ms:
        Progress floor for wait-window moves, so repeated shrinks of an
        already-tiny window terminate instead of asymptoting.
    coalesce_occupancy:
        Mean live columns per sweep above which an SLO breach is blamed on
        queueing rather than the straggler window (see the control law:
        raise the window to coalesce harder instead of shrinking it).
    min_samples:
        Minimum completed requests in the tick window before the p99 is
        trusted; thinner windows hold (and count toward settling — no
        traffic is not a reason to twist knobs).
    settle_ticks:
        Consecutive no-adjustment ticks before ``converged`` reports True.
    interval_s:
        Period of the background loop (``start()``); ``tick()`` callers
        set their own cadence.
    """

    slo_p99_ms: float = 50.0
    min_batch: int = 1
    max_batch_limit: int = 64
    min_wait_ms: float = 0.0
    max_wait_ms_limit: float = 50.0
    hysteresis: float = 0.15
    step: float = 1.3
    min_wait_step_ms: float = 0.25
    coalesce_occupancy: float = 2.0
    min_samples: int = 8
    settle_ticks: int = 5
    interval_s: float = 0.25

    def __post_init__(self):
        if self.slo_p99_ms <= 0:
            raise ValueError(f"slo_p99_ms must be > 0, got {self.slo_p99_ms!r}")
        if not 1 <= self.min_batch <= self.max_batch_limit:
            raise ValueError(
                f"need 1 <= min_batch <= max_batch_limit, got "
                f"{self.min_batch!r}, {self.max_batch_limit!r}")
        if not 0 <= self.min_wait_ms <= self.max_wait_ms_limit:
            raise ValueError(
                f"need 0 <= min_wait_ms <= max_wait_ms_limit, got "
                f"{self.min_wait_ms!r}, {self.max_wait_ms_limit!r}")
        if not 0 <= self.hysteresis < 1:
            raise ValueError(f"hysteresis must be in [0, 1), got "
                             f"{self.hysteresis!r}")
        if self.step <= 1.0:
            raise ValueError(f"step must be > 1, got {self.step!r}")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One tick's observation + what (if anything) the controller did."""

    tick: int
    action: str            # raise_batch | shrink_wait | raise_wait | hold
    reason: str            # human-readable why
    window: int            # completed requests observed this window
    p99_ms: float          # windowed p99 (0.0 when window is empty)
    mean_occupancy: float  # mean live columns per batch this window
    queue_depth: int
    max_batch: int         # knob values AFTER this tick
    max_wait_ms: float


class AdaptiveServeController:
    """Feedback loop steering one ``GraphService``'s batching policy.

    Reads the service's reservoir-backed stats (windowed deltas), writes
    through ``service.reconfigure``.  ``tick()`` is synchronous and
    deterministic; ``start()``/``stop()`` run it on a daemon thread.
    Shutdown is safe in either order relative to ``service.close()``:
    ``reconfigure`` on a closing service raises ``ServiceClosed``, which
    the loop treats as a normal stop (never an error).
    """

    def __init__(self, service, config: ControllerConfig | None = None,
                 *, hub=None, history: int = 256, **overrides):
        if config is None:
            config = ControllerConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.service = service
        self.config = config
        self.hub = hub
        self.decisions: deque[Decision] = deque(maxlen=max(history, 1))
        self.error: BaseException | None = None
        self._ticks = 0
        self._settled = 0
        self._adjustments = 0
        self._prev_counts = service.stats.latency_hist.counts()
        self._prev_occ: dict = dict(service.stats.occupancy())
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._tick_lock = threading.Lock()

    # -- observation -----------------------------------------------------
    def _window(self) -> tuple[int, float, float]:
        """(completed, p99_ms, mean_occupancy) since the previous tick."""
        hist = self.service.stats.latency_hist
        counts = hist.counts()
        delta = counts - self._prev_counts
        self._prev_counts = counts
        occ = dict(self.service.stats.occupancy())
        occ_delta = {k: occ.get(k, 0) - self._prev_occ.get(k, 0)
                     for k in set(occ) | set(self._prev_occ)}
        self._prev_occ = occ
        window = int(delta.sum())
        p99_ms = hist.quantile(99, counts=delta) * 1e3 if window else 0.0
        batches = sum(occ_delta.values())
        mean_occ = (sum(k * v for k, v in occ_delta.items()) / batches
                    if batches > 0 else 0.0)
        return window, p99_ms, mean_occ

    # -- the control law -------------------------------------------------
    def tick(self) -> Decision:
        """One control step: observe the window, maybe move ONE knob.

        Raises ``ServiceClosed`` (from ``reconfigure``) if the service shut
        down — callers driving ``tick()`` by hand see it; the background
        loop converts it to a clean stop.
        """
        with self._tick_lock:
            return self._tick_locked()

    def _tick_locked(self) -> Decision:
        ctl = self.config
        window, p99_ms, mean_occ = self._window()
        queue_depth = self.service.queue_depth
        cfg = self.service.config
        batch, wait = cfg.max_batch, cfg.max_wait_ms
        self._ticks += 1
        high = ctl.slo_p99_ms * (1.0 + ctl.hysteresis)
        low = ctl.slo_p99_ms * (1.0 - ctl.hysteresis)

        action, reason = "hold", "p99 within hysteresis band"
        new_batch, new_wait = batch, wait
        if window < ctl.min_samples:
            reason = (f"window too thin ({window} < {ctl.min_samples} "
                      "samples)")
        elif p99_ms > high:
            if queue_depth > 2 * batch and batch < ctl.max_batch_limit:
                # backlog despite full-ish sweeps: grow sweep width
                new_batch = min(ctl.max_batch_limit,
                                max(batch + 1, math.ceil(batch * ctl.step)))
                action = "raise_batch"
                reason = (f"p99 {p99_ms:.1f}ms > {high:.1f}ms with deep "
                          f"queue ({queue_depth})")
            elif (mean_occ >= ctl.coalesce_occupancy
                    and wait < ctl.max_wait_ms_limit):
                # batches already coalesce: the breach is queueing, not
                # straggler-waiting — widen the window to lift capacity
                # (full groups dispatch immediately, so the cap is free)
                new_wait = min(ctl.max_wait_ms_limit,
                               max(wait * ctl.step,
                                   wait + ctl.min_wait_step_ms))
                action = "raise_wait"
                reason = (f"p99 {p99_ms:.1f}ms > {high:.1f}ms with "
                          f"occupancy {mean_occ:.1f} — coalescing harder")
            elif wait > ctl.min_wait_ms:
                # the straggler window itself is the latency: shrink it
                new_wait = max(ctl.min_wait_ms,
                               min(wait / ctl.step,
                                   wait - ctl.min_wait_step_ms))
                action = "shrink_wait"
                reason = f"p99 {p99_ms:.1f}ms > {high:.1f}ms"
            else:
                reason = (f"p99 {p99_ms:.1f}ms over SLO but both knobs at "
                          "their limits")
        elif (p99_ms < low and mean_occ < 0.5 * batch
                and queue_depth <= batch and wait < ctl.max_wait_ms_limit):
            candidate = min(ctl.max_wait_ms_limit,
                            max(wait * ctl.step, wait + ctl.min_wait_step_ms))
            # predictive guard: a longer window can add (candidate - wait)
            # ms to every latency; only raise if that still clears the low
            # band, so this tick cannot force a shrink next tick
            if p99_ms + (candidate - wait) <= low:
                new_wait = candidate
                action = "raise_wait"
                reason = (f"p99 {p99_ms:.1f}ms < {low:.1f}ms, occupancy "
                          f"{mean_occ:.1f}/{batch}")
            else:
                reason = (f"occupancy low but +{candidate - wait:.2f}ms "
                          "wait would risk the SLO")

        if action != "hold":
            # may raise ServiceClosed — deliberately NOT caught here
            self.service.reconfigure(max_batch=new_batch,
                                     max_wait_ms=new_wait)
            self._settled = 0
            self._adjustments += 1
        else:
            self._settled += 1
        decision = Decision(
            tick=self._ticks, action=action, reason=reason, window=window,
            p99_ms=p99_ms, mean_occupancy=mean_occ, queue_depth=queue_depth,
            max_batch=new_batch, max_wait_ms=new_wait)
        self.decisions.append(decision)
        self._publish(decision)
        return decision

    def _publish(self, d: Decision) -> None:
        if self.hub is None:
            return
        try:
            self.hub.gauge("controller.max_batch").set(d.max_batch)
            self.hub.gauge("controller.max_wait_ms").set(d.max_wait_ms)
            self.hub.gauge("controller.window_p99_ms").set(d.p99_ms)
            self.hub.gauge("controller.mean_occupancy").set(d.mean_occupancy)
            self.hub.gauge("controller.converged").set(float(self.converged))
            if d.action != "hold":
                self.hub.counter("controller.adjustments").inc()
        except Exception:
            pass  # telemetry must never take down the control loop

    # -- status ----------------------------------------------------------
    @property
    def converged(self) -> bool:
        """True after ``settle_ticks`` consecutive ticks without a knob
        move (resets on every adjustment)."""
        return self._settled >= self.config.settle_ticks

    @property
    def ticks(self) -> int:
        return self._ticks

    @property
    def adjustments(self) -> int:
        return self._adjustments

    @property
    def last_decision(self) -> Decision | None:
        return self.decisions[-1] if self.decisions else None

    # -- background loop -------------------------------------------------
    def start(self) -> "AdaptiveServeController":
        """Run ``tick()`` every ``interval_s`` on a daemon thread."""
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="graphpulse-controller", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        from repro.serve.graph_service import ServiceClosed

        while not self._stop.wait(self.config.interval_s):
            # a closed service would only surface as ServiceClosed when a
            # tick tries to move a knob; holding ticks would spin forever
            if getattr(self.service, "is_closed", False):
                break
            try:
                self.tick()
            except ServiceClosed:
                break  # the service shut down first: a clean stop
            except Exception as exc:  # noqa: BLE001 — surfaced via .error
                self.error = exc
                break

    def stop(self, timeout: float | None = 5.0) -> None:
        """Stop the background loop (idempotent; safe before OR after the
        service closes).  ``tick()`` remains callable by hand afterwards."""
        self._stop.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout)

    close = stop

    def __enter__(self) -> "AdaptiveServeController":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def __repr__(self) -> str:
        return (f"AdaptiveServeController(slo_p99_ms="
                f"{self.config.slo_p99_ms}, ticks={self._ticks}, "
                f"adjustments={self._adjustments}, "
                f"converged={self.converged})")
