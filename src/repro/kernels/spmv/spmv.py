"""Pallas TPU kernels for blocked-ELL semiring SpMV (the paper's hot loop).

GraphMP's per-shard update — "pull source values, combine along in-edges,
reduce per destination" — is the compute hot-spot of the whole system.  On
TPU we lay shards out as blocked-ELL (DESIGN.md §2/§4) and fuse
mask→combine→reduce in VMEM:

  * ``ell_fold_pallas``        — sources pre-gathered by XLA (HBM gather is
    XLA-native); kernel folds [R, W] tiles to [R, 1] partials.  Grid is
    (rows/TR, W/TW) with sequential accumulation over the W axis into the
    revisited output block (identity-init at the first W step).
  * ``ell_fold_batch_pallas``  — batched fold over the *native* [R, W, K]
    gather layout: the edge tile is read ONCE and folded against all K
    source columns resident in the same VMEM block, so kernel-level edge
    traffic no longer scales with K.
  * ``ell_gather_fold_pallas`` — 2-D-tiled (GridGraph-style) variant where
    the source *interval* block x_blk is VMEM-resident and the gather runs
    inside the kernel.
  * ``ell_spmv_fused_pallas``  — the fused gather→fold kernel: the whole
    [n, K] source matrix stays VMEM-resident across the grid and the gather
    happens in-kernel, so the [R, W, K] gathered matrix is never
    materialized in HBM.  Emits [R, K] per-ELL-row partials; the wrapped-row
    segment-combine runs outside on the W×-smaller partials (in-kernel
    scatter across row tiles is not expressible on TPU Pallas because
    ``row_map`` segments span tiles).

Edge values may arrive quantized (int8/float16, see
``repro.core.shards.quantize_edge_vals``); every kernel dequantizes them
in-VMEM from a (1, 2) float32 (scale, zero) qparams block, so HBM traffic
for edge values is the *quantized* byte count.

All kernels are validated in interpret mode against `ref.py` over
shape/dtype/semiring sweeps (tests/test_kernels_spmv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.semiring import SEMIRINGS, Semiring
from repro.core.shards import LANE, SUBLANE

DEFAULT_TR = 256  # row-tile (multiple of 8 sublanes)
DEFAULT_TW = 512  # width-tile (multiple of 128 lanes)

# VMEM budget for the gathered-source tile of the batched kernels: the
# [tr, tw, K] block is the largest resident array, so (tr, tw) shrink until
# it fits (TPU cores have ~16 MB VMEM; 2 MB leaves room for edges + output).
TILE_BYTES_BUDGET = 2 << 20

# Edge-value dtypes that carry affine qparams (scale, zero).  bfloat16 and
# other float dtypes pass through the semiring untouched.
QUANTIZED_DTYPES = (jnp.int8, jnp.float16)


def _as_semiring(s: Semiring | str) -> Semiring:
    return SEMIRINGS[s] if isinstance(s, str) else s


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def vmem_block_bytes(shape, itemsize: int = 4) -> int:
    """Actual VMEM footprint of a block of the given shape.

    VMEM lays blocks out in (8 sublane, 128 lane) tiles over the two minor
    dims, so both are padded up: a [tr, tw, 1] source tile really occupies
    tr * tw * 128 elements, not tr * tw.  Every byte budget in this module
    (and ops.FUSED_X_BYTES_LIMIT) must be compared against this padded
    size — the unpadded product under-counts K=1 blocks by 128x.
    """
    dims = list(shape)
    if len(dims) >= 1:
        dims[-1] = _round_up(dims[-1], LANE)
    if len(dims) >= 2:
        dims[-2] = _round_up(dims[-2], SUBLANE)
    total = itemsize
    for d in dims:
        total *= d
    return total


def _is_quantized(vals) -> bool:
    return vals.dtype in QUANTIZED_DTYPES


def _qparams_2d(qparams) -> jnp.ndarray:
    """Canonical (1, 2) float32 (scale, zero) block for the kernels."""
    if qparams is None:
        qparams = jnp.asarray([1.0, 0.0], jnp.float32)
    return jnp.asarray(qparams, jnp.float32).reshape(1, 2)


def _edge_tile(vals_ref, qp_ref):
    """Edge-value tile, dequantized in-VMEM when a qparams block is present.

    The affine formula matches ``ref.maybe_dequantize`` exactly so the jnp
    fallback and the kernels agree bitwise.
    """
    if qp_ref is None:
        return vals_ref[...]
    return (vals_ref[...].astype(jnp.float32) - qp_ref[0, 1]) * qp_ref[0, 0]


def _fold_tile(sem: Semiring, vals, xg, cols):
    mask = cols >= 0
    contrib = sem.combine(vals, xg)
    contrib = jnp.where(mask, contrib, jnp.asarray(sem.identity, contrib.dtype))
    if sem.is_plus:
        return jnp.sum(contrib, axis=-1, keepdims=True)
    if sem.is_max:
        return jnp.max(contrib, axis=-1, keepdims=True)
    return jnp.min(contrib, axis=-1, keepdims=True)


def _fold_tile_batch(sem: Semiring, vals, xg, cols):
    """[tr, tw] edges × [tr, tw, K] gathered sources -> [tr, K] partials."""
    mask = cols >= 0
    contrib = sem.combine(vals[:, :, None], xg)
    contrib = jnp.where(mask[:, :, None], contrib,
                        jnp.asarray(sem.identity, contrib.dtype))
    if sem.is_plus:
        return jnp.sum(contrib, axis=1)
    if sem.is_max:
        return jnp.max(contrib, axis=1)
    return jnp.min(contrib, axis=1)


def _batch_tiles(R: int, W: int, K: int, itemsize: int = 4) -> tuple[int, int]:
    """(tr, tw) such that the [tr, tw, K] source tile fits the VMEM budget.

    The budget is checked against the *padded* footprint
    (``vmem_block_bytes``): K sits on the lane dim and pads to 128, so small
    K shrinks (tr, tw) much harder than the raw element count suggests.
    """
    tr, tw = min(DEFAULT_TR, R), min(DEFAULT_TW, W)
    floor_w, floor_r = min(W, LANE), min(R, SUBLANE)
    while vmem_block_bytes((tr, tw, K), itemsize) > TILE_BYTES_BUDGET and tw > floor_w:
        tw = max(tw // 2, floor_w)
    while vmem_block_bytes((tr, tw, K), itemsize) > TILE_BYTES_BUDGET and tr > floor_r:
        tr = max(tr // 2, floor_r)
    return tr, tw


def _split_qp(rest):
    """Kernel arg unpacking: rest is (out_ref,) or (qp_ref, out_ref)."""
    if len(rest) == 2:
        return rest[0], rest[1]
    return None, rest[0]


def _ell_fold_kernel(xg_ref, vals_ref, cols_ref, *rest, sem: Semiring):
    qp_ref, out_ref = _split_qp(rest)
    w_step = pl.program_id(1)
    partial = _fold_tile(sem, _edge_tile(vals_ref, qp_ref), xg_ref[...],
                         cols_ref[...])

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_fold_pallas(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                    semiring: str, tr: int = DEFAULT_TR, tw: int = DEFAULT_TW,
                    interpret: bool = True, qparams=None) -> jnp.ndarray:
    """[R, W] -> [R, 1] per-row semiring partials (pre-gathered sources)."""
    sem = _as_semiring(semiring)
    R, W = xg.shape
    tr = min(tr, R)
    tw = min(tw, W)
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    quant = _is_quantized(vals)
    in_specs = [
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
    ]
    args = [xg, vals, cols]
    if quant:
        in_specs.append(pl.BlockSpec((1, 2), lambda i, j: (0, 0)))
        args.append(_qparams_2d(qparams))
    return pl.pallas_call(
        functools.partial(_ell_fold_kernel, sem=sem),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tr, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), xg.dtype),
        interpret=interpret,
    )(*args)


def _ell_fold_batch_kernel(xg_ref, vals_ref, cols_ref, *rest, sem: Semiring):
    qp_ref, out_ref = _split_qp(rest)
    w_step = pl.program_id(1)
    # xg block is (tr, tw, K): the edge tile is loaded once and folded
    # against ALL K resident source columns — kernel-level edge traffic is
    # amortized across the batch (the old [K, R, W] layout revisited each
    # edge tile K times and needed a transpose round-trip around the call).
    partial = _fold_tile_batch(sem, _edge_tile(vals_ref, qp_ref),
                               xg_ref[...], cols_ref[...])

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_fold_batch_pallas(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                          semiring: str, tr: int | None = None,
                          tw: int | None = None,
                          interpret: bool = True, qparams=None) -> jnp.ndarray:
    """Batched fold over the native gather layout: [R, W, K] -> [R, K].

    Grid is (rows/TR, W/TW) with the W axis innermost-sequential, exactly
    like ``ell_fold_pallas``; K stays resident inside each block.  Tile
    sizes shrink automatically so the [tr, tw, K] source tile fits VMEM.
    """
    sem = _as_semiring(semiring)
    R, W, K = xg.shape
    atr, atw = _batch_tiles(R, W, K, xg.dtype.itemsize)
    tr = min(tr, R) if tr else atr
    tw = min(tw, W) if tw else atw
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    quant = _is_quantized(vals)
    in_specs = [
        pl.BlockSpec((tr, tw, K), lambda i, j: (i, j, 0)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
    ]
    args = [xg, vals, cols]
    if quant:
        in_specs.append(pl.BlockSpec((1, 2), lambda i, j: (0, 0)))
        args.append(_qparams_2d(qparams))
    return pl.pallas_call(
        functools.partial(_ell_fold_batch_kernel, sem=sem),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tr, K), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, K), xg.dtype),
        interpret=interpret,
    )(*args)


def _ell_gather_fold_kernel(x_ref, cols_ref, vals_ref, *rest, sem: Semiring):
    qp_ref, out_ref = _split_qp(rest)
    w_step = pl.program_id(1)
    cols = cols_ref[...]
    safe = jnp.where(cols >= 0, cols, 0)
    # VMEM gather: the source interval block is fully resident in x_ref.
    xg = jnp.take(x_ref[0], safe.reshape(-1), axis=0).reshape(cols.shape)
    partial = _fold_tile(sem, _edge_tile(vals_ref, qp_ref), xg, cols)

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_gather_fold_pallas(x_blk: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                           semiring: str, tr: int = DEFAULT_TR, tw: int = DEFAULT_TW,
                           interpret: bool = True, qparams=None) -> jnp.ndarray:
    """2-D-tiled SpMV: cols index the VMEM-resident source block x_blk [VB]."""
    sem = _as_semiring(semiring)
    R, W = cols.shape
    VB = x_blk.shape[0]
    tr = min(tr, R)
    tw = min(tw, W)
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    quant = _is_quantized(vals)
    in_specs = [
        pl.BlockSpec((1, VB), lambda i, j: (0, 0)),  # whole interval, revisited
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
    ]
    args = [x_blk[None, :], cols, vals]
    if quant:
        in_specs.append(pl.BlockSpec((1, 2), lambda i, j: (0, 0)))
        args.append(_qparams_2d(qparams))
    return pl.pallas_call(
        functools.partial(_ell_gather_fold_kernel, sem=sem),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tr, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), x_blk.dtype),
        interpret=interpret,
    )(*args)


def _ell_spmv_fused_kernel(x_ref, cols_ref, vals_ref, *rest, sem: Semiring):
    qp_ref, out_ref = _split_qp(rest)
    w_step = pl.program_id(1)
    cols = cols_ref[...]
    safe = jnp.where(cols >= 0, cols, 0)
    k = x_ref.shape[1]
    # In-kernel gather: x [n, K] is fully VMEM-resident across the grid, so
    # the [R, W, K] gathered matrix never exists in HBM.
    xg = jnp.take(x_ref[...], safe.reshape(-1), axis=0)
    xg = xg.reshape(cols.shape + (k,))
    partial = _fold_tile_batch(sem, _edge_tile(vals_ref, qp_ref), xg, cols)

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_spmv_fused_pallas(x: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                          semiring: str, tr: int | None = None,
                          tw: int | None = None,
                          interpret: bool = True, qparams=None) -> jnp.ndarray:
    """Fused gather→fold: [n, K] resident sources + [R, W] edges -> [R, K].

    The caller gates this on the padded [n, K] footprint
    (``vmem_block_bytes``) fitting a VMEM budget (ops.FUSED_X_BYTES_LIMIT);
    the wrapped-row segment-combine runs outside the kernel on the
    W×-smaller [R, K] partials.
    """
    sem = _as_semiring(semiring)
    R, W = cols.shape
    n, K = x.shape
    atr, atw = _batch_tiles(R, W, K, x.dtype.itemsize)
    tr = min(tr, R) if tr else atr
    tw = min(tw, W) if tw else atw
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    quant = _is_quantized(vals)
    in_specs = [
        pl.BlockSpec((n, K), lambda i, j: (0, 0)),  # whole frontier, revisited
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
    ]
    args = [x, cols, vals]
    if quant:
        in_specs.append(pl.BlockSpec((1, 2), lambda i, j: (0, 0)))
        args.append(_qparams_2d(qparams))
    return pl.pallas_call(
        functools.partial(_ell_spmv_fused_kernel, sem=sem),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tr, K), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, K), x.dtype),
        interpret=interpret,
    )(*args)
