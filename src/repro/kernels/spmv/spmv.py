"""Pallas TPU kernels for blocked-ELL semiring SpMV (the paper's hot loop).

GraphMP's per-shard update — "pull source values, combine along in-edges,
reduce per destination" — is the compute hot-spot of the whole system.  On
TPU we lay shards out as blocked-ELL (DESIGN.md §2/§4) and fuse
mask→combine→reduce in VMEM:

  * ``ell_fold_pallas``        — sources pre-gathered by XLA (HBM gather is
    XLA-native); kernel folds [R, W] tiles to [R, 1] partials.  Grid is
    (rows/TR, W/TW) with sequential accumulation over the W axis into the
    revisited output block (identity-init at the first W step).
  * ``ell_gather_fold_pallas`` — 2-D-tiled (GridGraph-style) variant where
    the source *interval* block x_blk is VMEM-resident and the gather runs
    inside the kernel.  This is the TPU-native analogue of GraphMP sliding
    its window over vertex intervals: the window IS the VMEM block.

Both are validated in interpret mode against `ref.py` over shape/dtype/
semiring sweeps (tests/test_kernels_spmv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.semiring import SEMIRINGS, Semiring

DEFAULT_TR = 256  # row-tile (multiple of 8 sublanes)
DEFAULT_TW = 512  # width-tile (multiple of 128 lanes)


def _as_semiring(s: Semiring | str) -> Semiring:
    return SEMIRINGS[s] if isinstance(s, str) else s


def _fold_tile(sem: Semiring, vals, xg, cols):
    mask = cols >= 0
    contrib = sem.combine(vals, xg)
    contrib = jnp.where(mask, contrib, jnp.asarray(sem.identity, contrib.dtype))
    if sem.is_plus:
        return jnp.sum(contrib, axis=-1, keepdims=True)
    if sem.is_max:
        return jnp.max(contrib, axis=-1, keepdims=True)
    return jnp.min(contrib, axis=-1, keepdims=True)


def _ell_fold_kernel(xg_ref, vals_ref, cols_ref, out_ref, *, sem: Semiring):
    w_step = pl.program_id(1)
    partial = _fold_tile(sem, vals_ref[...], xg_ref[...], cols_ref[...])

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_fold_pallas(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                    semiring: str, tr: int = DEFAULT_TR, tw: int = DEFAULT_TW,
                    interpret: bool = True) -> jnp.ndarray:
    """[R, W] -> [R, 1] per-row semiring partials (pre-gathered sources)."""
    sem = _as_semiring(semiring)
    R, W = xg.shape
    tr = min(tr, R)
    tw = min(tw, W)
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    return pl.pallas_call(
        functools.partial(_ell_fold_kernel, sem=sem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), xg.dtype),
        interpret=interpret,
    )(xg, vals, cols)


def _ell_fold_batch_kernel(xg_ref, vals_ref, cols_ref, out_ref, *, sem: Semiring):
    w_step = pl.program_id(2)
    # xg block is (1, tr, tw): one column's tile against the shared edge tile.
    # The K grid axis revisits each (i, j) edge block once per column, so
    # HBM-level edge traffic still scales with K — the batching amortizes the
    # disk + decompression + host→device tier (the system bottleneck), not
    # VMEM streaming.  A K-resident block layout is the follow-up if kernel
    # bandwidth ever dominates.
    partial = _fold_tile(sem, vals_ref[...], xg_ref[0], cols_ref[...])

    @pl.when(w_step == 0)
    def _init():
        out_ref[0] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[0] = sem.reduce(out_ref[0], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_fold_batch_pallas(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                          semiring: str, tr: int = DEFAULT_TR,
                          tw: int = DEFAULT_TW,
                          interpret: bool = True) -> jnp.ndarray:
    """Batched fold: [K, R, W] gathered sources + shared [R, W] edges -> [K, R, 1].

    Grid is (K, rows/TR, W/TW) with the W axis innermost-sequential, exactly
    like ``ell_fold_pallas`` — the K axis just revisits the same edge tiles
    with a different source column.
    """
    sem = _as_semiring(semiring)
    K, R, W = xg.shape
    tr = min(tr, R)
    tw = min(tw, W)
    grid = (K, pl.cdiv(R, tr), pl.cdiv(W, tw))
    return pl.pallas_call(
        functools.partial(_ell_fold_batch_kernel, sem=sem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tr, tw), lambda k, i, j: (k, i, j)),
            pl.BlockSpec((tr, tw), lambda k, i, j: (i, j)),
            pl.BlockSpec((tr, tw), lambda k, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, tr, 1), lambda k, i, j: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R, 1), xg.dtype),
        interpret=interpret,
    )(xg, vals, cols)


def _ell_gather_fold_kernel(x_ref, cols_ref, vals_ref, out_ref, *, sem: Semiring):
    w_step = pl.program_id(1)
    cols = cols_ref[...]
    safe = jnp.where(cols >= 0, cols, 0)
    # VMEM gather: the source interval block is fully resident in x_ref.
    xg = jnp.take(x_ref[0], safe.reshape(-1), axis=0).reshape(cols.shape)
    partial = _fold_tile(sem, vals_ref[...], xg, cols)

    @pl.when(w_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(w_step != 0)
    def _acc():
        out_ref[...] = sem.reduce(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("semiring", "tr", "tw", "interpret"))
def ell_gather_fold_pallas(x_blk: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                           semiring: str, tr: int = DEFAULT_TR, tw: int = DEFAULT_TW,
                           interpret: bool = True) -> jnp.ndarray:
    """2-D-tiled SpMV: cols index the VMEM-resident source block x_blk [VB]."""
    sem = _as_semiring(semiring)
    R, W = cols.shape
    VB = x_blk.shape[0]
    tr = min(tr, R)
    tw = min(tw, W)
    grid = (pl.cdiv(R, tr), pl.cdiv(W, tw))
    return pl.pallas_call(
        functools.partial(_ell_gather_fold_kernel, sem=sem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, VB), lambda i, j: (0, 0)),  # whole interval, revisited
            pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), x_blk.dtype),
        interpret=interpret,
    )(x_blk[None, :], cols, vals)
