"""jit'd public wrappers for the SpMV kernels.

Dispatch is honest about the platform (``_resolve``): backends where the
kernels are known-correct compiled (tpu — see ``_COMPILED_BACKENDS`` for why
that list is TPU-only) compile them; everything else runs them in interpret
mode.  ``use_pallas`` selects the family:

  * ``"auto"``  — fastest correct path per platform.  Compiled backends take
    Pallas (fused gather→fold when the [n, K] frontier fits VMEM, otherwise
    XLA-gather + native batched fold).  On CPU the single-column path keeps
    Pallas in interpret mode (cheap enough, keeps the lowering exercised)
    but the BATCHED [n, K] fold falls back to pure jnp — interpret mode
    executes the grid step-by-step in Python with cost scaling in K, which
    would erase exactly the amortization ``run_batch``/GraphService exist
    for.  Non-CPU interpreting backends (gpu, until the kernels are ported)
    demote to jnp for every K: the jnp path is fully XLA-compiled there,
    while interpret mode would be step-by-step Python.  The demotion applies
    only when *interpreting*, never on a compiled backend.
  * ``True``    — force Pallas (interpret on CPU; the A/B referee tests use
    this), including the fused kernel when the frontier fits.
  * ``False``   — force the pure-jnp oracle path.

Quantized edge values (int8/float16 + affine qparams) are dequantized
in-kernel on the Pallas paths and via the bit-identical
``ref.maybe_dequantize`` on the jnp path.  ``describe_dispatch`` reports the
path a given configuration takes (used by the roofline report and docs).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.spmv import ref as _ref
from repro.kernels.spmv import spmv as _pallas

# Backends allowed to COMPILE the Pallas kernels; anything else interprets
# (or, under "auto", demotes to jnp — see _pick_path).  TPU-only on purpose:
# every kernel in spmv.py accumulates into a revisited out_ref across the W
# grid axis (pl.when(w_step != 0) read-modify-write), which is only safe
# because TPU executes the grid sequentially.  GPU backends (cuda/rocm/
# triton) run grid programs in parallel, so that accumulation races — and
# the in-kernel jnp.take gather has no Triton lowering.  Do not add a GPU
# backend here until the kernels are ported to (and tested on) one.
_COMPILED_BACKENDS = ("tpu",)

# The fused gather→fold kernel keeps the whole [n, K] source matrix resident
# in VMEM; frontiers bigger than this fall back to XLA-gather + batched fold.
FUSED_X_BYTES_LIMIT = int(os.environ.get("GRAPHMP_FUSED_VMEM", 4 << 20))


def _resolve(use_pallas) -> tuple[bool, bool]:
    """-> (use_pallas, interpret), dispatching on the *actual* platform.

    ``use_pallas=False`` short-circuits to the jnp path (no dead interpret
    flag); otherwise interpret mode is everything off ``_COMPILED_BACKENDS``
    — including GPU, whose parallel grid execution would race the kernels'
    sequential W-axis accumulation if compiled (see the comment on
    ``_COMPILED_BACKENDS``).
    """
    if not use_pallas:  # False
        return False, False
    return True, jax.default_backend() not in _COMPILED_BACKENDS


def _auto_demotes(use_pallas, interp: bool, k: int) -> bool:
    """Should an interpreting "auto" call take the jnp path instead?

    Interpret mode earns its keep only as the cheap single-column CPU
    referee path.  Batched folds demote (interpret cost scales with K), and
    so does every non-CPU interpreting backend (gpu): there the jnp path is
    fully XLA-compiled while interpret mode is step-by-step Python.
    """
    if use_pallas != "auto" or not interp:
        return False
    return k > 1 or jax.default_backend() != "cpu"


def _fused_fits(n: int, k: int, itemsize: int = 4) -> bool:
    """True when the [n, K] frontier's VMEM footprint fits the fused gate.

    Footprint is the *padded* block size: VMEM tiles the two minor dims to
    (8 sublane, 128 lane), so a K=1 column really occupies 128 lanes per
    row — n*k*itemsize would under-count that case by 128x and admit
    frontiers that cannot compile on TPU.
    """
    return _pallas.vmem_block_bytes((n, k), itemsize) <= FUSED_X_BYTES_LIMIT


def _pick_path(use_pallas, n: int, k: int, itemsize: int = 4) -> tuple[str, bool]:
    """-> (path, interpret) with path in {'jnp', 'pallas-fold', 'pallas-fused'}.

    The spmv dispatch table (docs/ARCHITECTURE.md "Kernels"):
      * jnp            — use_pallas=False anywhere, or "auto" on an
        interpreting backend with K > 1 or off-CPU (the interpret
        demotions; see ``_auto_demotes``).
      * pallas-fused   — compiled backends (and forced ``True``) when the
        [n, K] frontier fits FUSED_X_BYTES_LIMIT.
      * pallas-fold    — everything else on the Pallas family: XLA gather +
        fold kernel (single-column CPU "auto" stays here, preserving the
        cheap interpret referee path).
    """
    use, interp = _resolve(use_pallas)
    if not use:
        return "jnp", False
    if _auto_demotes(use_pallas, interp, k):
        return "jnp", False
    if _fused_fits(n, k, itemsize) and (use_pallas is True or not interp):
        return "pallas-fused", interp
    return "pallas-fold", interp


def describe_dispatch(use_pallas="auto", *, n: int, k: int = 1,
                      itemsize: int = 4) -> str:
    """Human-readable path ``ell_spmv``/``ell_spmv_batch`` takes on this
    process's default backend: ``jnp`` | ``pallas:<mode>:<kernel>``."""
    path, interp = _pick_path(use_pallas, n, k, itemsize)
    if path == "jnp":
        return "jnp"
    mode = "interpret" if interp else "compiled"
    kernel = "fused" if path == "pallas-fused" else "gather+fold"
    return f"pallas:{mode}:{kernel}"


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_fold(xg, vals, cols, semiring: str, use_pallas="auto", qparams=None):
    use, interp = _resolve(use_pallas)
    if use and not _auto_demotes(use_pallas, interp, 1):
        return _pallas.ell_fold_pallas(xg, vals, cols, semiring,
                                       interpret=interp, qparams=qparams)
    return _ref.ell_fold_ref(xg, _ref.maybe_dequantize(vals, qparams), cols,
                             semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_gather_fold(x_blk, cols, vals, semiring: str, use_pallas="auto",
                    qparams=None):
    use, interp = _resolve(use_pallas)
    if use and not _auto_demotes(use_pallas, interp, 1):
        return _pallas.ell_gather_fold_pallas(x_blk, cols, vals, semiring,
                                              interpret=interp, qparams=qparams)
    return _ref.ell_gather_fold_ref(x_blk, cols,
                                    _ref.maybe_dequantize(vals, qparams),
                                    semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv(x, cols, vals, row_map, num_segments: int, semiring: str,
             use_pallas="auto", qparams=None):
    """Full shard update: gather + fold + segment combine.

    x: [n] resident source array; returns [num_segments] partials for the
    shard's destination interval (identity where the interval has no edges).
    On the fused path the gather happens inside the kernel against the
    VMEM-resident frontier; otherwise XLA gathers from HBM first.
    """
    path, interp = _pick_path(use_pallas, x.shape[0], 1, x.dtype.itemsize)
    if path == "jnp":
        return _ref.ell_spmv_ref(x, cols, _ref.maybe_dequantize(vals, qparams),
                                 row_map, num_segments, semiring)
    if path == "pallas-fused":
        partials = _pallas.ell_spmv_fused_pallas(
            x[:, None], cols, vals, semiring, interpret=interp, qparams=qparams)
    else:
        # masking is handled inside the fold via cols>=0; clamp for a safe gather
        xg = x[jnp.where(cols >= 0, cols, 0)]
        partials = _pallas.ell_fold_pallas(xg, vals, cols, semiring,
                                           interpret=interp, qparams=qparams)
    return _ref.segment_combine(partials, row_map, num_segments, semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv_batch(x, cols, vals, row_map, num_segments: int, semiring: str,
                   use_pallas="auto", qparams=None):
    """Batched shard update: one edge pass serves K frontiers.

    x: [n, K] resident source matrix; returns [num_segments, K] partials —
    column k is exactly ``ell_spmv(x[:, k], ...)``.  The fused path keeps x
    VMEM-resident and never materializes the [R, W, K] gathered matrix in
    HBM; the fold path gathers once in XLA and feeds the kernel the native
    [R, W, K] layout (no transpose round-trip).
    """
    n, k = x.shape
    path, interp = _pick_path(use_pallas, n, k, x.dtype.itemsize)
    if path == "jnp":
        xg = x[jnp.where(cols >= 0, cols, 0)]      # [R, W, K]
        partials = _ref.ell_fold_batch_ref(xg, _ref.maybe_dequantize(vals, qparams),
                                           cols, semiring)
    elif path == "pallas-fused":
        partials = _pallas.ell_spmv_fused_pallas(
            x, cols, vals, semiring, interpret=interp, qparams=qparams)
    else:
        xg = x[jnp.where(cols >= 0, cols, 0)]      # [R, W, K]
        partials = _pallas.ell_fold_batch_pallas(
            xg, vals, cols, semiring, interpret=interp, qparams=qparams)
    return _ref.segment_combine_batch(partials, row_map, num_segments, semiring)
