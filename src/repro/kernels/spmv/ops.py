"""jit'd public wrappers for the SpMV kernels.

Dispatch is honest about the platform (``_resolve``): backends with a real
Pallas lowering (tpu/gpu) compile the kernels; everything else (cpu) runs
them in interpret mode.  ``use_pallas`` selects the family:

  * ``"auto"``  — fastest correct path per platform.  Compiled backends take
    Pallas (fused gather→fold when the [n, K] frontier fits VMEM, otherwise
    XLA-gather + native batched fold).  On CPU the single-column path keeps
    Pallas in interpret mode (cheap enough, keeps the lowering exercised)
    but the BATCHED [n, K] fold falls back to pure jnp — interpret mode
    executes the grid step-by-step in Python with cost scaling in K, which
    would erase exactly the amortization ``run_batch``/GraphService exist
    for.  The demotion applies only when *interpreting*, never on a
    compiled backend.
  * ``True``    — force Pallas (interpret on CPU; the A/B referee tests use
    this), including the fused kernel when the frontier fits.
  * ``False``   — force the pure-jnp oracle path.

Quantized edge values (int8/float16 + affine qparams) are dequantized
in-kernel on the Pallas paths and via the bit-identical
``ref.maybe_dequantize`` on the jnp path.  ``describe_dispatch`` reports the
path a given configuration takes (used by the roofline report and docs).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.spmv import ref as _ref
from repro.kernels.spmv import spmv as _pallas

# Backends with a compiled Pallas lowering; anything else interprets.
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")

# The fused gather→fold kernel keeps the whole [n, K] source matrix resident
# in VMEM; frontiers bigger than this fall back to XLA-gather + batched fold.
FUSED_X_BYTES_LIMIT = int(os.environ.get("GRAPHMP_FUSED_VMEM", 4 << 20))


def _resolve(use_pallas) -> tuple[bool, bool]:
    """-> (use_pallas, interpret), dispatching on the *actual* platform.

    ``use_pallas=False`` short-circuits to the jnp path (no dead interpret
    flag); otherwise interpret mode is reserved for backends without a
    compiled Pallas lowering (cpu) — a GPU gets compiled kernels, not
    step-by-step Python execution.
    """
    if not use_pallas:  # False
        return False, False
    return True, jax.default_backend() not in _COMPILED_BACKENDS


def _fused_fits(n: int, k: int, itemsize: int = 4) -> bool:
    return n * k * itemsize <= FUSED_X_BYTES_LIMIT


def _pick_path(use_pallas, n: int, k: int, itemsize: int = 4) -> tuple[str, bool]:
    """-> (path, interpret) with path in {'jnp', 'pallas-fold', 'pallas-fused'}.

    The spmv dispatch table (docs/ARCHITECTURE.md "Kernels"):
      * jnp            — use_pallas=False anywhere, or "auto" on an
        interpreting backend with K > 1 (the batched-interpret demotion).
      * pallas-fused   — compiled backends (and forced ``True``) when the
        [n, K] frontier fits FUSED_X_BYTES_LIMIT.
      * pallas-fold    — everything else on the Pallas family: XLA gather +
        fold kernel (single-column CPU "auto" stays here, preserving the
        cheap interpret referee path).
    """
    use, interp = _resolve(use_pallas)
    if not use:
        return "jnp", False
    if use_pallas == "auto" and interp and k > 1:
        return "jnp", False  # interpret-mode cost scales with K; see docstring
    if _fused_fits(n, k, itemsize) and (use_pallas is True or not interp):
        return "pallas-fused", interp
    return "pallas-fold", interp


def describe_dispatch(use_pallas="auto", *, n: int, k: int = 1,
                      itemsize: int = 4) -> str:
    """Human-readable path ``ell_spmv``/``ell_spmv_batch`` takes on this
    process's default backend: ``jnp`` | ``pallas:<mode>:<kernel>``."""
    path, interp = _pick_path(use_pallas, n, k, itemsize)
    if path == "jnp":
        return "jnp"
    mode = "interpret" if interp else "compiled"
    kernel = "fused" if path == "pallas-fused" else "gather+fold"
    return f"pallas:{mode}:{kernel}"


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_fold(xg, vals, cols, semiring: str, use_pallas="auto", qparams=None):
    use, interp = _resolve(use_pallas)
    if use:
        return _pallas.ell_fold_pallas(xg, vals, cols, semiring,
                                       interpret=interp, qparams=qparams)
    return _ref.ell_fold_ref(xg, _ref.maybe_dequantize(vals, qparams), cols,
                             semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_gather_fold(x_blk, cols, vals, semiring: str, use_pallas="auto",
                    qparams=None):
    use, interp = _resolve(use_pallas)
    if use:
        return _pallas.ell_gather_fold_pallas(x_blk, cols, vals, semiring,
                                              interpret=interp, qparams=qparams)
    return _ref.ell_gather_fold_ref(x_blk, cols,
                                    _ref.maybe_dequantize(vals, qparams),
                                    semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv(x, cols, vals, row_map, num_segments: int, semiring: str,
             use_pallas="auto", qparams=None):
    """Full shard update: gather + fold + segment combine.

    x: [n] resident source array; returns [num_segments] partials for the
    shard's destination interval (identity where the interval has no edges).
    On the fused path the gather happens inside the kernel against the
    VMEM-resident frontier; otherwise XLA gathers from HBM first.
    """
    path, interp = _pick_path(use_pallas, x.shape[0], 1, x.dtype.itemsize)
    if path == "jnp":
        return _ref.ell_spmv_ref(x, cols, _ref.maybe_dequantize(vals, qparams),
                                 row_map, num_segments, semiring)
    if path == "pallas-fused":
        partials = _pallas.ell_spmv_fused_pallas(
            x[:, None], cols, vals, semiring, interpret=interp, qparams=qparams)
    else:
        # masking is handled inside the fold via cols>=0; clamp for a safe gather
        xg = x[jnp.where(cols >= 0, cols, 0)]
        partials = _pallas.ell_fold_pallas(xg, vals, cols, semiring,
                                           interpret=interp, qparams=qparams)
    return _ref.segment_combine(partials, row_map, num_segments, semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv_batch(x, cols, vals, row_map, num_segments: int, semiring: str,
                   use_pallas="auto", qparams=None):
    """Batched shard update: one edge pass serves K frontiers.

    x: [n, K] resident source matrix; returns [num_segments, K] partials —
    column k is exactly ``ell_spmv(x[:, k], ...)``.  The fused path keeps x
    VMEM-resident and never materializes the [R, W, K] gathered matrix in
    HBM; the fold path gathers once in XLA and feeds the kernel the native
    [R, W, K] layout (no transpose round-trip).
    """
    n, k = x.shape
    path, interp = _pick_path(use_pallas, n, k, x.dtype.itemsize)
    if path == "jnp":
        xg = x[jnp.where(cols >= 0, cols, 0)]      # [R, W, K]
        partials = _ref.ell_fold_batch_ref(xg, _ref.maybe_dequantize(vals, qparams),
                                           cols, semiring)
    elif path == "pallas-fused":
        partials = _pallas.ell_spmv_fused_pallas(
            x, cols, vals, semiring, interpret=interp, qparams=qparams)
    else:
        xg = x[jnp.where(cols >= 0, cols, 0)]      # [R, W, K]
        partials = _pallas.ell_fold_batch_pallas(
            xg, vals, cols, semiring, interpret=interp, qparams=qparams)
    return _ref.segment_combine_batch(partials, row_map, num_segments, semiring)
