"""jit'd public wrappers for the SpMV kernels.

``use_pallas='auto'`` picks the fastest correct path per platform: compiled
Pallas kernels on TPU; on CPU the single-column kernels run Pallas in
interpret mode (cheap enough, keeps the lowering exercised) but the BATCHED
[n, K] fold falls back to the pure-jnp path — interpret mode executes the
(K, R, W) grid step-by-step in Python and is ~10x slower per column, which
would erase exactly the amortization ``run_batch``/GraphService exist for.
``True`` forces Pallas (interpret on CPU — the A/B correctness tests use
this); ``False`` forces the pure-jnp oracle path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmv import ref as _ref
from repro.kernels.spmv import spmv as _pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if use_pallas == "auto":
        return True, not _on_tpu()
    return bool(use_pallas), not _on_tpu()


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_fold(xg, vals, cols, semiring: str, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _pallas.ell_fold_pallas(xg, vals, cols, semiring, interpret=interp)
    return _ref.ell_fold_ref(xg, vals, cols, semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "use_pallas"))
def ell_gather_fold(x_blk, cols, vals, semiring: str, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _pallas.ell_gather_fold_pallas(x_blk, cols, vals, semiring, interpret=interp)
    return _ref.ell_gather_fold_ref(x_blk, cols, vals, semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv(x, cols, vals, row_map, num_segments: int, semiring: str,
             use_pallas="auto"):
    """Full shard update: XLA HBM-gather + Pallas fold + segment combine.

    x: [n] resident source array; returns [num_segments] partials for the
    shard's destination interval (identity where the interval has no edges).
    """
    # masking is handled inside the fold via cols>=0; clamp for a safe gather
    xg = x[jnp.where(cols >= 0, cols, 0)]
    partials = ell_fold(xg, vals, cols, semiring, use_pallas=use_pallas)
    return _ref.segment_combine(partials, row_map, num_segments, semiring)


@functools.partial(jax.jit, static_argnames=("semiring", "num_segments", "use_pallas"))
def ell_spmv_batch(x, cols, vals, row_map, num_segments: int, semiring: str,
                   use_pallas="auto"):
    """Batched shard update: one edge pass serves K frontiers.

    x: [n, K] resident source matrix; returns [num_segments, K] partials —
    column k is exactly ``ell_spmv(x[:, k], ...)``.  The gather reads each
    edge's K source values together; the fold streams the [R, W] edge tiles
    once and reduces every column against them.
    """
    xg = x[jnp.where(cols >= 0, cols, 0)]      # [R, W, K]
    use, interp = _resolve(use_pallas)
    if use_pallas == "auto" and interp:
        use = False  # interpret-mode cost scales with K; see module docstring
    if use:
        folded = _pallas.ell_fold_batch_pallas(
            jnp.transpose(xg, (2, 0, 1)), vals, cols, semiring, interpret=interp)
        partials = jnp.transpose(folded[:, :, 0], (1, 0))  # [R, K]
    else:
        partials = _ref.ell_fold_batch_ref(xg, vals, cols, semiring)
    return _ref.segment_combine_batch(partials, row_map, num_segments, semiring)
