from repro.kernels.spmv.ops import ell_spmv, ell_fold, ell_gather_fold  # noqa: F401
