"""Pure-jnp oracles for the blocked-ELL semiring SpMV kernels.

These are the correctness references the Pallas kernels are swept against
(tests/test_kernels_spmv.py) and the fallback path on backends without
Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import SEMIRINGS, Semiring


def _as_semiring(s: Semiring | str) -> Semiring:
    return SEMIRINGS[s] if isinstance(s, str) else s


# Edge-value storage dtypes that carry affine qparams (see
# repro.core.shards.quantize_edge_vals).  bfloat16 et al. pass through: only
# these two dtypes are produced by the quantizer and carry scale/zero.
QUANTIZED_DTYPES = (jnp.int8, jnp.float16)


def maybe_dequantize(vals: jnp.ndarray, qparams: jnp.ndarray | None) -> jnp.ndarray:
    """Dequantize int8/float16 edge values to float32 with the canonical
    affine formula ``(q - zero) * scale``; other dtypes pass through.

    ``qparams`` is a [2] float32 array (scale, zero); ``None`` means identity
    parameters.  This is the *same* arithmetic the Pallas kernels apply
    in-VMEM, so the jnp fallback and the kernel agree bitwise.
    """
    if vals.dtype not in QUANTIZED_DTYPES:
        return vals
    if qparams is None:
        return vals.astype(jnp.float32)
    qp = qparams.astype(jnp.float32)
    # NOTE: backends may contract this multiply into an FMA with a following
    # semiring add (min_plus's `w + s`), which single-rounds.  All dispatch
    # paths contract identically — they stay bitwise-equal to each other —
    # but can sit 1 ulp from a dequantize-then-combine oracle.
    return (vals.astype(jnp.float32) - qp[1]) * qp[0]


def ell_fold_ref(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                 semiring: Semiring | str) -> jnp.ndarray:
    """[R, W] gathered sources + edge vals -> [R, 1] per-ELL-row partials.

    ``cols < 0`` marks padded slots (contribute the reduce identity).
    """
    sem = _as_semiring(semiring)
    mask = cols >= 0
    return sem.fold(vals, xg, mask, axis=-1)[:, None]


def ell_gather_fold_ref(x_blk: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                        semiring: Semiring | str) -> jnp.ndarray:
    """2-D-tiled variant: cols index a small *local* source block x_blk [VB]."""
    sem = _as_semiring(semiring)
    mask = cols >= 0
    xg = x_blk[jnp.where(mask, cols, 0)]
    return sem.fold(vals, xg, mask, axis=-1)[:, None]


def ell_fold_batch_ref(xg: jnp.ndarray, vals: jnp.ndarray, cols: jnp.ndarray,
                       semiring: Semiring | str) -> jnp.ndarray:
    """Batched fold: [R, W, K] gathered sources + shared [R, W] edges -> [R, K].

    One read of the edge tile serves all K columns (the batched-frontier
    amortization); ``cols < 0`` slots contribute the reduce identity in
    every column.
    """
    sem = _as_semiring(semiring)
    mask = cols >= 0
    return sem.fold_batch(vals, xg, mask)


def segment_combine(partials: jnp.ndarray, row_map: jnp.ndarray,
                    num_segments: int, semiring: Semiring | str) -> jnp.ndarray:
    """Fold wrapped ELL rows of the same destination: [R] -> [num_segments]."""
    sem = _as_semiring(semiring)
    p = partials.reshape(-1)
    if sem.is_plus:
        return jax.ops.segment_sum(p, row_map, num_segments=num_segments)
    if sem.is_max:
        return jax.ops.segment_max(p, row_map, num_segments=num_segments)
    return jax.ops.segment_min(p, row_map, num_segments=num_segments)


def segment_combine_batch(partials: jnp.ndarray, row_map: jnp.ndarray,
                          num_segments: int, semiring: Semiring | str) -> jnp.ndarray:
    """Batched wrapped-row fold: [R, K] -> [num_segments, K] (segment ids
    index the leading axis, so every column folds in one segment op)."""
    sem = _as_semiring(semiring)
    if sem.is_plus:
        return jax.ops.segment_sum(partials, row_map, num_segments=num_segments)
    if sem.is_max:
        return jax.ops.segment_max(partials, row_map, num_segments=num_segments)
    return jax.ops.segment_min(partials, row_map, num_segments=num_segments)


def ell_spmv_ref(x: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                 row_map: jnp.ndarray, num_segments: int,
                 semiring: Semiring | str) -> jnp.ndarray:
    """Full shard update oracle: gather + fold + segment-combine.

    x: [n] resident source values; cols/vals: [R, W] blocked-ELL;
    row_map: [R] local destination row per ELL row; -> [num_segments].
    """
    mask = cols >= 0
    xg = x[jnp.where(mask, cols, 0)]
    partials = ell_fold_ref(xg, vals, cols, semiring)
    return segment_combine(partials, row_map, num_segments, semiring)


def ell_spmv_batch_ref(x: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                       row_map: jnp.ndarray, num_segments: int,
                       semiring: Semiring | str) -> jnp.ndarray:
    """Batched shard update oracle: x is [n, K] -> [num_segments, K]."""
    mask = cols >= 0
    xg = x[jnp.where(mask, cols, 0)]          # [R, W, K]
    partials = ell_fold_batch_ref(xg, vals, cols, semiring)
    return segment_combine_batch(partials, row_map, num_segments, semiring)
