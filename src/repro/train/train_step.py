"""Train step: value_and_grad + clip + optimizer, with optional int8
error-feedback gradient compression (the "compressed cache" idea applied to
the DP collective — DESIGN.md §5/§6).

The compression math (quantize → dequantize with an error-feedback buffer
carried in the train state) runs inside the step so its effect on convergence
is real and tested; the collective-byte saving itself is measured in
benchmarks/grad_compression.py where the psum is explicit (XLA's automatic
gradient reduction cannot be intercepted from jit-level code).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.nn import Param
from repro.train.optimizer import OptConfig, apply_updates, init_opt_state


def _is_param(x):
    return isinstance(x, Param)


def _map(f, *trees):
    return jax.tree_util.tree_map(f, *trees, is_leaf=_is_param)


@dataclasses.dataclass
class TrainState:
    params: Any          # Param tree
    opt: Any             # optimizer state
    ef: Any              # error-feedback buffers (or None)
    step: Any            # int32 scalar


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.ef, s.step), None),
    lambda _, c: TrainState(*c),
)


# ---- int8 error-feedback compression ---------------------------------------
def quantize_int8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, ef):
    """Error-feedback int8: g' = deq(quant(g + e)); e' = (g + e) - g'."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads, is_leaf=_is_param)
    flat_e = treedef.flatten_up_to(ef)
    new_g, new_e = [], []
    for g, e in zip(flat_g, flat_e):
        gf = g.value.astype(jnp.float32) + e.value
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        new_g.append(Param(deq.astype(g.value.dtype), g.axes))
        new_e.append(Param(gf - deq, e.axes))
    return (jax.tree_util.tree_unflatten(treedef, new_g),
            jax.tree_util.tree_unflatten(treedef, new_e))


def init_ef(params):
    return _map(lambda p: Param(jnp.zeros(p.value.shape, jnp.float32), p.axes), params)


# ---- step factory ----------------------------------------------------------
def make_init_state(model: Model, opt_cfg: OptConfig, *, grad_compression=False):
    def init_state(key) -> TrainState:
        params = model.init(key)
        return TrainState(
            params=params,
            opt=init_opt_state(params, opt_cfg),
            ef=init_ef(params) if grad_compression else None,
            step=jnp.zeros((), jnp.int32),
        )
    return init_state


def make_train_step(model: Model, opt_cfg: OptConfig, *, grad_compression=False):
    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(state.params, batch)
        ef = state.ef
        if grad_compression:
            grads, ef = ef_compress_grads(grads, ef)
        params, opt, opt_metrics = apply_updates(state.params, grads, state.opt, opt_cfg)
        new_state = TrainState(params=params, opt=opt, ef=ef, step=state.step + 1)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, metrics

    return train_step
