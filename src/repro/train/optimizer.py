"""Optimizers from scratch (no optax in this container): AdamW + Adafactor.

State trees mirror the param tree (Param-shaped), so the FSDP weight
shardings apply verbatim to optimizer state — "all vertices in memory,
sharded" (the VSW discipline applied to optimizer state).

Adafactor (factored second moments over the last two dims) exists because
kimi-k2's 1T parameters cannot afford 2×fp32 Adam moments on a 256-chip pod —
EXPERIMENTS.md §Dry-run quantifies this.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.nn import Param


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # mixed precision: keep fp32 master weights when params are bf16
    master_fp32: bool = True
    # adafactor
    factored_min_dim: int = 128


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _is_param(x):
    return isinstance(x, Param)


def _map(f, *trees):
    return jax.tree_util.tree_map(f, *trees, is_leaf=_is_param)


def _factored(shape, min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def init_opt_state(params, cfg: OptConfig) -> dict:
    """Param tree -> state tree.  Leaves are Param-wrapped so shardings map."""

    def adam_leaf(p: Param):
        st = {
            "m": Param(jnp.zeros(p.value.shape, jnp.float32), p.axes),
            "v": Param(jnp.zeros(p.value.shape, jnp.float32), p.axes),
        }
        if cfg.master_fp32 and p.value.dtype != jnp.float32:
            st["master"] = Param(p.value.astype(jnp.float32), p.axes)
        return st

    def adafactor_leaf(p: Param):
        sh = p.value.shape
        st: dict[str, Any] = {}
        if _factored(sh, cfg.factored_min_dim):
            st["vr"] = Param(jnp.zeros(sh[:-1], jnp.float32), p.axes[:-1])
            st["vc"] = Param(jnp.zeros(sh[:-2] + sh[-1:], jnp.float32),
                             p.axes[:-2] + p.axes[-1:])
        else:
            st["v"] = Param(jnp.zeros(sh, jnp.float32), p.axes)
        if cfg.master_fp32 and p.value.dtype != jnp.float32:
            st["master"] = Param(p.value.astype(jnp.float32), p.axes)
        return st

    leaf = adam_leaf if cfg.name == "adamw" else adafactor_leaf
    return {"step": jnp.zeros((), jnp.int32), "ema": _map(leaf, params)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(_map(lambda p: jnp.sum(
        jnp.square(p.value.astype(jnp.float32))), grads))
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return _map(lambda p: Param(p.value * scale, p.axes), grads), gnorm


def apply_updates(params, grads, state, cfg: OptConfig):
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def adam_update(p: Param, g: Param, st: dict):
        gf = g.value.astype(jnp.float32)
        m = b1 * st["m"].value + (1 - b1) * gf
        v = b2 * st["v"].value + (1 - b2) * jnp.square(gf)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        master = st["master"].value if "master" in st else p.value.astype(jnp.float32)
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        new_master = master - lr * upd
        out_st = {"m": Param(m, p.axes), "v": Param(v, p.axes)}
        if "master" in st:
            out_st["master"] = Param(new_master, p.axes)
        return Param(new_master.astype(p.value.dtype), p.axes), out_st

    def adafactor_update(p: Param, g: Param, st: dict):
        gf = g.value.astype(jnp.float32)
        g2 = jnp.square(gf) + 1e-30
        if "vr" in st:
            vr = b2 * st["vr"].value + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * st["vc"].value + (1 - b2) * g2.mean(axis=-2)
            denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30))[..., None] \
                * vc[..., None, :]
            upd = gf * jax.lax.rsqrt(denom + 1e-30)
            out_st = {"vr": Param(vr, st["vr"].axes), "vc": Param(vc, st["vc"].axes)}
        else:
            v = b2 * st["v"].value + (1 - b2) * g2
            upd = gf * jax.lax.rsqrt(v + 1e-30)
            out_st = {"v": Param(v, p.axes)}
        # update clipping (Adafactor's d=1.0 RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
        upd = upd / jnp.maximum(1.0, rms)
        master = st["master"].value if "master" in st else p.value.astype(jnp.float32)
        new_master = master - lr * (upd + cfg.weight_decay * master)
        if "master" in st:
            out_st["master"] = Param(new_master, p.axes)
        return Param(new_master.astype(p.value.dtype), p.axes), out_st

    upd_fn = adam_update if cfg.name == "adamw" else adafactor_update
    flat_p, treedef = jax.tree_util.tree_flatten(params, is_leaf=_is_param)
    flat_g = jax.tree_util.tree_leaves(grads, is_leaf=_is_param)
    flat_s = treedef.flatten_up_to(state["ema"])
    new_p, new_s = [], []
    for p, g, st in zip(flat_p, flat_g, flat_s):
        np_, ns_ = upd_fn(p, g, st)
        new_p.append(np_)
        new_s.append(ns_)
    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = {"step": step, "ema": jax.tree_util.tree_unflatten(treedef, new_s)}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
