"""Data pipeline: deterministic, host-local, restart-safe.

Batches are a pure function of (step, host_id, shape) — a restarted or
replaced host regenerates exactly its stream with no coordination (the
straggler/elasticity story in DESIGN.md §6).  Two sources:

  * SyntheticLM — structured pseudo-text (Zipfian unigrams + a repeated-ngram
    process) so small models have something learnable to overfit;
  * corpus mode — a token array (e.g. bytes of a file) sampled in windows.

A background prefetch thread keeps `prefetch` batches ahead of the consumer
(host-side compute/IO overlap, same double-buffering the VSW engine uses for
shards).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 host_id: int = 0, seed: int = 0, corpus: np.ndarray | None = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.host = host_id
        self.seed = seed
        if corpus is None:
            # small deterministic "language": Zipf unigrams with ngram reuse
            rng = np.random.default_rng(seed)
            zipf = rng.zipf(1.5, size=1 << 16).astype(np.int64) % vocab_size
            self.corpus = zipf
        else:
            self.corpus = corpus.astype(np.int64) % vocab_size

    def get_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host, step]))
        n = len(self.corpus) - self.seq - 1
        starts = rng.integers(0, n, size=self.batch)
        idx = starts[:, None] + np.arange(self.seq + 1)[None, :]
        window = self.corpus[idx]
        return {"tokens": window[:, :-1].astype(np.int32),
                "targets": window[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background thread keeping `depth` batches ready."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.source.get_batch(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
