from repro.train.optimizer import OptConfig, init_opt_state, apply_updates, lr_at  # noqa: F401
from repro.train.train_step import TrainState, make_train_step, make_init_state  # noqa: F401
