"""Fault-tolerant checkpointing for Param/opt trees.

Properties required at scale (DESIGN.md §6):
  * atomic publish — write to a temp name, fsync, os.replace; a crash mid-save
    never corrupts the latest checkpoint;
  * keep-N GC;
  * mesh-shape-agnostic restore — leaves are stored as full (unsharded) numpy
    arrays keyed by their tree path; on load they are device_put against
    *whatever* sharding the new mesh prescribes → elastic re-scaling across
    pod counts and axis shapes;
  * async save — the serialization runs on a worker thread so the train loop
    keeps stepping (emergency saves on SIGTERM flush synchronously).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.models.nn import Param


def _is_param(x):
    return isinstance(x, Param)


def _flatten_named(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(re.sub(r"[\[\]'\.]", "", str(k)) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16: store as f32 (exact)
            arr = arr.astype(np.float32)
        flat[name] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    # ---- save -----------------------------------------------------------
    def save(self, step: int, state, extra: dict | None = None, *, sync: bool = False):
        # pull to host synchronously (cheap vs serialization), serialize async
        flat = _flatten_named(state)
        if sync:
            self._write(step, flat, extra or {})
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, flat, extra or {})

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = self.dir / f".tmp_step_{step:08d}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.dir / f"step_{step:08d}.npz")
        meta_tmp = self.dir / "latest.json.tmp"
        with open(meta_tmp, "w") as f:
            json.dump({"step": step, **extra}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(meta_tmp, self.dir / "latest.json")
        self._gc()

    def _gc(self):
        cks = sorted(self.dir.glob("step_*.npz"))
        for old in cks[: -self.keep]:
            old.unlink()

    # ---- restore ---------------------------------------------------------
    def latest_step(self) -> int | None:
        meta = self.dir / "latest.json"
        if not meta.exists():
            return None
        with open(meta) as f:
            return int(json.load(f)["step"])

    def restore(self, abstract_state, step: int | None = None,
                shardings: Any = None):
        """Restore into the structure of `abstract_state`; device_put each
        leaf against `shardings` (same-tree NamedShardings) when given —
        this is where elastic re-sharding happens."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        path = self.dir / f"step_{step:08d}.npz"
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves_paths))
        out = []
        with np.load(path) as z:
            for (p, leaf), sh in zip(leaves_paths, sh_leaves):
                name = "/".join(re.sub(r"[\[\]'\.]", "", str(k)) for k in p)
                arr = z[name]
                dtype = getattr(leaf, "dtype", arr.dtype)
                jarr = jax.numpy.asarray(arr).astype(dtype)  # jnp handles bf16
                out.append(jax.device_put(jarr, sh) if sh is not None else jarr)
        return jax.tree_util.tree_unflatten(treedef, out), step
