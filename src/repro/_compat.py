"""Version bridge for the jax API surface this codebase targets.

The code is written against the post-0.5 public names (``jax.shard_map``
with ``check_vma``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``).  Containers pinned to jax 0.4.x expose the same
functionality under the pre-stabilization names
(``jax.experimental.shard_map.shard_map`` with ``check_rep``, implicit
Auto axis types).  Importing this module installs forward-compatible
aliases so one source tree runs on both; on new-enough jax it is a no-op.

Imported for its side effects from ``repro/__init__.py`` — every entry
point that reaches a mesh/shard_map call site goes through the package
import first, so the aliases are in place before first use.
"""
from __future__ import annotations

import enum
import functools

import jax


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            # pre-AxisType jax behaves as all-Auto; dropping the kwarg is exact
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    # Compiled.cost_analysis() returned a one-element list of dicts before
    # jax 0.5; callers index it like the current dict return.  Wrap lazily
    # (NO compilation here — importing repro must not init the backend,
    # launch/dryrun.py sets XLA_FLAGS first).
    try:
        compiled_cls = jax.stages.Compiled
        _orig_cost = compiled_cls.cost_analysis

        def _cost_analysis(self):
            out = _orig_cost(self)
            if isinstance(out, list):
                return out[0] if out else {}
            return out

        compiled_cls.cost_analysis = _cost_analysis
    except AttributeError:
        pass

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
            kw.pop("check_rep", None)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)

        jax.shard_map = shard_map


_install()
