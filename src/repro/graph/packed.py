"""Packed single-file backend: one mmap'd file, zero-copy shard views.

The npz-per-shard directory pays a zip-parse plus an array copy on every
shard miss.  The packed format removes both: all arrays live as raw
little-endian segments inside ONE file, 64-byte aligned, described by a JSON
header — ``read_shard`` returns ``ELLShard`` whose cols/vals/row_map are
**views into the shared mmap** (no parse, no copy; the OS pages data in on
first touch, which the ShardPipeline moves off the critical path).

File layout::

    offset 0   magic  b"GMPACK01"
    offset 8   uint64 LE header offset
    offset 16  uint64 LE header length
    offset 24  64-byte-aligned raw array segments (C-order tobytes)
    tail       header JSON:
                 properties      — carried verbatim from the source
                 vertex_info     — segment refs for in/out degree
                 blooms[p]       — segment ref + num_bits/num_hashes
                 shards[p]       — segment refs for cols/vals/row_map,
                                   start/end/nnz, canonical nbytes

``nbytes`` per shard is the **canonical npz-blob size recorded at pack
time**, so disk-byte accounting is identical to the npz backend serving the
same graph (Table-3 figures stay comparable across backends).  Unlike the
npz format, vals are always materialized — the packed file trades a little
disk for strictly zero-copy reads.

Convert a preprocessed directory with::

    python -m repro.graph.pack GRAPH_DIR [OUT_FILE]
"""
from __future__ import annotations

import json
import mmap
import os
from pathlib import Path

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import ELLShard
from repro.graph.source import (BytesCounter, MissingGraphError, ShardSource,
                                ShardSourceBase, pack_shard_npz,
                                validate_properties)

MAGIC = b"GMPACK01"
_PREAMBLE = len(MAGIC) + 16  # magic + header offset + header length
ALIGN = 64
PACKED_SUFFIX = ".gmpk"
DEFAULT_PACKED_NAME = "packed" + PACKED_SUFFIX


def is_packed_file(path: str | os.PathLike) -> bool:
    p = Path(path)
    if not p.is_file():
        return False
    with open(p, "rb") as f:
        return f.read(len(MAGIC)) == MAGIC


def _write_segment(f, arr: np.ndarray) -> dict:
    f.write(b"\0" * ((-f.tell()) % ALIGN))
    offset = f.tell()
    arr = np.ascontiguousarray(arr)
    f.write(arr.tobytes())
    return {"offset": offset, "dtype": arr.dtype.str, "shape": list(arr.shape)}


def pack_graph(source: ShardSource | str | os.PathLike,
               out_path: str | os.PathLike | None = None) -> Path:
    """Convert any ShardSource into a packed single file; returns its path."""
    from repro.graph.storage import GraphStore  # local: avoid import cycle

    if isinstance(source, (str, os.PathLike)):
        source = GraphStore(source)
    if out_path is None:
        base = getattr(source, "path", None)
        if base is None or not Path(base).is_dir():
            raise ValueError("out_path is required for a directory-less source")
        out_path = Path(base) / DEFAULT_PACKED_NAME
    out_path = Path(out_path)
    out_path.parent.mkdir(parents=True, exist_ok=True)

    header: dict = {"properties": dict(source.properties)}
    # per-process tmp name: concurrent auto-packs of one directory must not
    # interleave writes; last os.replace wins with a complete file either way
    tmp = out_path.with_name(f".{out_path.name}.{os.getpid()}.tmp")
    try:
        _write_packed(source, tmp, header)
        os.replace(tmp, out_path)
    except BaseException:
        tmp.unlink(missing_ok=True)  # no orphaned multi-GB temp on failure
        raise
    return out_path


def _write_packed(source: ShardSource, tmp: Path, header: dict) -> None:
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(bytes(16))  # header offset + length, patched at the end
        in_deg, out_deg = source.read_vertex_info()
        header["vertex_info"] = {"in_degree": _write_segment(f, in_deg),
                                 "out_degree": _write_segment(f, out_deg)}
        header["blooms"] = []
        for p in range(source.num_shards):
            b = source.read_bloom(p)
            header["blooms"].append({"bits": _write_segment(f, b.bits),
                                     "num_bits": b.num_bits,
                                     "num_hashes": b.num_hashes})
        header["shards"] = []
        for p in range(source.num_shards):
            s = source.read_shard(p)
            header["shards"].append({
                "start": int(s.start_vertex), "end": int(s.end_vertex),
                "nnz": int(s.nnz), "nbytes": int(source.shard_nbytes(p)),
                "val_scale": float(s.val_scale), "val_zero": float(s.val_zero),
                "cols": _write_segment(f, s.cols),
                "vals": _write_segment(f, s.vals),
                "row_map": _write_segment(f, s.row_map),
            })
        blob = json.dumps(header, sort_keys=True).encode()
        hdr_off = f.tell()
        f.write(blob)
        f.seek(len(MAGIC))
        f.write(hdr_off.to_bytes(8, "little"))
        f.write(len(blob).to_bytes(8, "little"))


class PackedGraphStore(ShardSourceBase):
    """Read-only ShardSource over one packed file (mmap'd once, shared)."""

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self.io = BytesCounter()
        if not self.path.is_file():
            raise MissingGraphError(
                f"{str(self.path)!r} is not a packed graph file; create one "
                "with `python -m repro.graph.pack GRAPH_DIR`")
        with open(self.path, "rb") as f:
            magic = f.read(len(MAGIC))
            if magic != MAGIC:
                raise MissingGraphError(
                    f"{str(self.path)!r} is not a packed graph "
                    f"(bad magic {magic!r}); create one with "
                    "`python -m repro.graph.pack GRAPH_DIR`")
            hdr_off = int.from_bytes(f.read(8), "little")
            hdr_len = int.from_bytes(f.read(8), "little")
            try:
                f.seek(hdr_off)
                header = json.loads(f.read(hdr_len))
            except (OSError, UnicodeDecodeError, json.JSONDecodeError) as exc:
                raise MissingGraphError(
                    f"{str(self.path)!r} has a corrupt or truncated packed "
                    f"header ({exc}); re-run `python -m repro.graph.pack`"
                ) from exc
            self._mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        self._header = header
        self._retired: list[mmap.mmap] = []
        self._prop = validate_properties(dict(header["properties"]),
                                         repr(str(self.path)))

    @property
    def properties(self) -> dict:
        return self._prop

    def _view(self, ref: dict) -> np.ndarray:
        dtype = np.dtype(ref["dtype"])
        shape = tuple(ref["shape"])
        count = int(np.prod(shape)) if shape else 1
        arr = np.frombuffer(self._mm, dtype=dtype, count=count,
                            offset=int(ref["offset"]))
        return arr.reshape(shape)

    def read_vertex_info(self) -> tuple[np.ndarray, np.ndarray]:
        # copies, not views: vertex info and blooms live for a whole session,
        # and long-lived views would pin the mmap open forever (close() path);
        # zero-copy is reserved for the hot per-iteration shard reads
        vi = self._header["vertex_info"]
        in_deg = np.array(self._view(vi["in_degree"]))
        out_deg = np.array(self._view(vi["out_degree"]))
        self.io.add_read(in_deg.nbytes + out_deg.nbytes)
        return in_deg, out_deg

    def _shard_view(self, shard_id: int) -> ELLShard:
        rec = self._header["shards"][shard_id]
        return ELLShard(
            shard_id=shard_id,
            start_vertex=int(rec["start"]),
            end_vertex=int(rec["end"]),
            nnz=int(rec["nnz"]),
            cols=self._view(rec["cols"]),
            vals=self._view(rec["vals"]),
            row_map=self._view(rec["row_map"]),
            val_scale=float(rec.get("val_scale", 1.0)),
            val_zero=float(rec.get("val_zero", 0.0)),
        )

    def read_shard(self, shard_id: int) -> ELLShard:
        self.io.add_read(self.shard_nbytes(shard_id))
        return self._shard_view(shard_id)

    def read_shard_bytes(self, shard_id: int) -> bytes:
        """Canonical npz blob, re-serialized from the mmap'd views."""
        self.io.add_read(self.shard_nbytes(shard_id))
        return pack_shard_npz(self._shard_view(shard_id))

    def shard_nbytes(self, shard_id: int) -> int:
        return int(self._header["shards"][shard_id]["nbytes"])

    def read_bloom(self, shard_id: int) -> BloomFilter:
        rec = self._header["blooms"][shard_id]
        bits = np.array(self._view(rec["bits"]))  # copy: see read_vertex_info
        self.io.add_read(bits.nbytes)
        return BloomFilter(bits=bits, num_bits=int(rec["num_bits"]),
                           num_hashes=int(rec["num_hashes"]))

    def remap(self) -> None:
        """Re-read the preamble/header and re-mmap the file after an in-place
        append (dirty-shard compaction).  The previous mapping is *retired*,
        not closed: shard views handed out before the remap may still alias
        its pages, and those stay valid because old segments are never
        overwritten — compaction only appends and repoints the header."""
        with open(self.path, "rb") as f:
            f.seek(len(MAGIC))
            hdr_off = int.from_bytes(f.read(8), "little")
            hdr_len = int.from_bytes(f.read(8), "little")
            f.seek(hdr_off)
            header = json.loads(f.read(hdr_len))
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        self._retired.append(self._mm)
        self._mm = mm
        self._header = header
        self._prop = validate_properties(dict(header["properties"]),
                                         repr(str(self.path)))

    def close(self) -> None:
        for mm in self._retired:
            try:
                mm.close()
            except BufferError:  # a live view still pins it; main close decides
                pass
        self._mm.close()
