from repro.graph.generate import rmat_edges, uniform_edges, zipf_edges  # noqa: F401
from repro.graph.source import (BytesCounter, ConcurrentMutationError,  # noqa: F401
                                MissingGraphError, ShardSource, graph_token)
from repro.graph.storage import GraphStore  # noqa: F401
from repro.graph.packed import PackedGraphStore, pack_graph  # noqa: F401
from repro.graph.memory import MemoryGraphStore  # noqa: F401
from repro.graph.preprocess import preprocess_graph  # noqa: F401
from repro.graph.delta import DeltaBudgetError, DeltaGraphStore  # noqa: F401
from repro.graph.compact import CompactionReport, compact  # noqa: F401
