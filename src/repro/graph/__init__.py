from repro.graph.generate import rmat_edges, uniform_edges, zipf_edges  # noqa: F401
from repro.graph.source import (BytesCounter, MissingGraphError,  # noqa: F401
                                ShardSource)
from repro.graph.storage import GraphStore  # noqa: F401
from repro.graph.packed import PackedGraphStore, pack_graph  # noqa: F401
from repro.graph.memory import MemoryGraphStore  # noqa: F401
from repro.graph.preprocess import preprocess_graph  # noqa: F401
