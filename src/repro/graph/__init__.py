from repro.graph.generate import rmat_edges, uniform_edges, zipf_edges  # noqa: F401
from repro.graph.storage import GraphStore  # noqa: F401
from repro.graph.preprocess import preprocess_graph  # noqa: F401
