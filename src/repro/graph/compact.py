"""Dirty-shard compaction: fold a DeltaGraphStore overlay back into its base.

Only the shards mutated since the last compaction are rewritten — Bloom
filters and degree arrays included — so compaction cost scales with the
delta, not the graph:

  * npz directory (``GraphStore``): dirty ``shard_*.npz``/``bloom_*.npz``
    files are rewritten in place, then ``vertex_info.npz`` and
    ``property.json`` (the property rewrite also bumps its mtime, which is
    what tells the session's auto-repack check that any stale ``.gmpk``
    sibling needs repacking).
  * packed file (``PackedGraphStore``): new segments for the dirty shards
    are **appended** after the current header, a new tail header is written,
    and finally the 16-byte preamble is repointed — crash-safe ordering (the
    file parses with the old header until the final small write).  The old
    header and superseded segments become dead bytes, reported in the
    ``CompactionReport``; a full ``pack_graph`` rewrite reclaims them.
  * memory (``MemoryGraphStore``): the merged views are swapped in.

Compaction does **not** bump the graph epoch and does not reset per-shard
epochs: shard *content* is unchanged, so cache entries and memo results
stamped with the current epoch remain valid across it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.graph.delta import DeltaGraphStore


@dataclasses.dataclass(frozen=True)
class CompactionReport:
    epoch: int                    # graph epoch the base now reflects
    backend: str                  # base store class name
    shards_rewritten: tuple[int, ...]
    bytes_written: int            # bytes pushed into the base store
    dead_bytes: int               # superseded bytes left behind (packed only)
    seconds: float


def _json_ready(obj):
    """Deep-copy ``obj`` into plain-JSON types (property.json / header)."""
    if isinstance(obj, dict):
        return {k: _json_ready(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_ready(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def compact(store: DeltaGraphStore) -> CompactionReport:
    """Rewrite the base's dirty shards from ``store``'s merged views, then
    release the overlay memory.  Safe to call with no dirty shards (no-op
    report).  The caller must ensure no run is mid-flight (GraphService
    drains; the engine's epoch pin turns a violation into an error, but
    compaction itself does not change shard content so it never trips it).
    """
    from repro.graph.packed import PackedGraphStore
    from repro.graph.storage import GraphStore
    from repro.graph.memory import MemoryGraphStore

    t0 = time.perf_counter()
    with store._lock:
        dirty = tuple(sorted(store._merged))
        base = store.base
        backend = type(base).__name__
        if not dirty:
            return CompactionReport(epoch=store.epoch(), backend=backend,
                                    shards_rewritten=(), bytes_written=0,
                                    dead_bytes=0,
                                    seconds=time.perf_counter() - t0)
        if isinstance(base, GraphStore):
            written, dead = _compact_npz(store, base, dirty)
        elif isinstance(base, PackedGraphStore):
            written, dead = _compact_packed(store, base, dirty)
        elif isinstance(base, MemoryGraphStore):
            written, dead = _compact_memory(store, base, dirty)
        else:
            raise TypeError(
                f"cannot compact into a {backend}: no rewrite support "
                "(wrap an npz/packed/memory base, or pack_graph the overlay "
                "to a fresh file instead)")
        store._compacted()
        return CompactionReport(epoch=store.epoch(), backend=backend,
                                shards_rewritten=dirty, bytes_written=written,
                                dead_bytes=dead,
                                seconds=time.perf_counter() - t0)


def _compact_npz(store: DeltaGraphStore, base, dirty) -> tuple[int, int]:
    written0 = base.io.written
    for p in dirty:
        base.write_shard(store._merged[p])
        base.write_bloom(p, store._blooms[p])
    base.write_vertex_info(store._in_deg, store._out_deg)
    base.write_properties(_json_ready(store._prop))
    return base.io.written - written0, 0


def _seg_nbytes(ref: dict) -> int:
    shape = tuple(ref["shape"])
    count = int(np.prod(shape)) if shape else 1
    return count * np.dtype(ref["dtype"]).itemsize


def _compact_packed(store: DeltaGraphStore, base, dirty) -> tuple[int, int]:
    from repro.graph.packed import MAGIC, _write_segment

    header = json.loads(json.dumps(base._header))  # deep copy
    # superseded bytes: the old tail header plus every segment being replaced
    dead = len(json.dumps(base._header, sort_keys=True).encode())
    for key in ("in_degree", "out_degree"):
        dead += _seg_nbytes(header["vertex_info"][key])
    for p in dirty:
        dead += _seg_nbytes(header["blooms"][p]["bits"])
        for key in ("cols", "vals", "row_map"):
            dead += _seg_nbytes(header["shards"][p][key])

    with open(base.path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        end0 = f.tell()
        header["vertex_info"] = {
            "in_degree": _write_segment(f, store._in_deg),
            "out_degree": _write_segment(f, store._out_deg)}
        for p in dirty:
            s = store._merged[p]
            b = store._blooms[p]
            header["blooms"][p] = {"bits": _write_segment(f, b.bits),
                                   "num_bits": b.num_bits,
                                   "num_hashes": b.num_hashes}
            header["shards"][p] = {
                "start": int(s.start_vertex), "end": int(s.end_vertex),
                "nnz": int(s.nnz), "nbytes": len(store._blobs[p]),
                "val_scale": float(s.val_scale), "val_zero": float(s.val_zero),
                "cols": _write_segment(f, s.cols),
                "vals": _write_segment(f, s.vals),
                "row_map": _write_segment(f, s.row_map)}
        header["properties"] = _json_ready(store._prop)
        blob = json.dumps(header, sort_keys=True).encode()
        hdr_off = f.tell()
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())  # data durable before the preamble repoints
        f.seek(len(MAGIC))
        f.write(hdr_off.to_bytes(8, "little"))
        f.write(len(blob).to_bytes(8, "little"))
        f.flush()
        written = f.seek(0, os.SEEK_END) - end0
    base.io.add_written(written)
    base.remap()
    return written, dead


def _compact_memory(store: DeltaGraphStore, base, dirty) -> tuple[int, int]:
    nbytes = {p: len(store._blobs[p]) for p in dirty}
    base._apply_compaction(
        shards={p: store._merged[p] for p in dirty},
        blooms={p: store._blooms[p] for p in dirty},
        nbytes=nbytes,
        vertex_info=(store._in_deg.copy(), store._out_deg.copy()),
        properties=_json_ready(store._prop))
    written = sum(nbytes.values())
    base.io.add_written(written)  # RAM swap, charged at canonical blob size
    return written, 0
