"""Synthetic power-law graph generators.

The paper's datasets (Twitter/UK-2007/UK-2014/EU-2015, up to 91.8B edges,
law.di.unimi.it) are not available offline; benchmarks use RMAT and Zipf
generators with matched degree skew (all four paper graphs are power-law,
Fig. 6).  Generators are deterministic in `seed` and stream in chunks so a
graph larger than host memory can be written straight to disk.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def rmat_edges(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    chunk: int = 1 << 22,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Stream RMAT (Graph500 parameters) edges as (src, dst) chunks.

    2**scale vertices, edge_factor * 2**scale edges (with duplicates and
    self-loops, like real crawls).
    """
    n_edges = edge_factor << scale
    rng = np.random.default_rng(seed)
    probs = np.array([a, b, c, 1.0 - a - b - c])
    emitted = 0
    while emitted < n_edges:
        m = min(chunk, n_edges - emitted)
        src = np.zeros(m, dtype=np.int64)
        dst = np.zeros(m, dtype=np.int64)
        for _ in range(scale):
            q = rng.choice(4, size=m, p=probs)
            src = (src << 1) | (q >> 1)
            dst = (dst << 1) | (q & 1)
        yield src, dst
        emitted += m


def zipf_edges(
    num_vertices: int,
    num_edges: int,
    alpha: float = 1.3,
    seed: int = 0,
    chunk: int = 1 << 22,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Edges with Zipf-distributed destinations (heavy in-degree skew, like
    the paper's web crawls whose max in-degree is ~20M on 1.1B vertices)."""
    rng = np.random.default_rng(seed)
    # Zipf ranks via inverse-CDF on a truncated harmonic distribution
    ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    cdf = np.cumsum(w) / w.sum()
    emitted = 0
    while emitted < num_edges:
        m = min(chunk, num_edges - emitted)
        u = rng.random(m)
        dst = np.searchsorted(cdf, u).astype(np.int64)
        src = rng.integers(0, num_vertices, size=m, dtype=np.int64)
        yield src, dst
        emitted += m


def uniform_edges(
    num_vertices: int, num_edges: int, seed: int = 0, chunk: int = 1 << 22
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    emitted = 0
    while emitted < num_edges:
        m = min(chunk, num_edges - emitted)
        yield (
            rng.integers(0, num_vertices, size=m, dtype=np.int64),
            rng.integers(0, num_vertices, size=m, dtype=np.int64),
        )
        emitted += m


def materialize(gen: Iterator[tuple[np.ndarray, np.ndarray]]) -> tuple[np.ndarray, np.ndarray]:
    srcs, dsts = [], []
    for s, d in gen:
        srcs.append(s)
        dsts.append(d)
    return np.concatenate(srcs), np.concatenate(dsts)
