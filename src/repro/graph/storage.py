"""On-disk graph store: the paper's property file + vertex info + shard files.

Layout of a preprocessed graph directory:

  property.json          — |V|, |E|, P, intervals, weighted, threshold (paper §2.2)
  vertex_info.npz        — in_degree, out_degree arrays
  bloom_<p>.npz          — per-shard Bloom filter over source vertices (§2.4.1)
  shard_<p>.npz          — blocked-ELL arrays (cols, vals, row_map) + metadata

Every read/write is a real file operation; `BytesCounter` instruments the
store so benchmarks report actual disk bytes, which is the paper's primary
metric (Table 3).
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import ELLShard


@dataclasses.dataclass
class BytesCounter:
    read: int = 0
    written: int = 0

    def reset(self) -> None:
        self.read = 0
        self.written = 0


class GraphStore:
    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self.io = BytesCounter()
        self._prop: dict | None = None

    # ---- property file -------------------------------------------------
    @property
    def properties(self) -> dict:
        if self._prop is None:
            with open(self.path / "property.json") as f:
                self._prop = json.load(f)
        return self._prop

    def write_properties(self, prop: dict) -> None:
        self.path.mkdir(parents=True, exist_ok=True)
        tmp = self.path / "property.json.tmp"
        with open(tmp, "w") as f:
            json.dump(prop, f)
        os.replace(tmp, self.path / "property.json")
        self._prop = prop

    @property
    def num_vertices(self) -> int:
        return int(self.properties["num_vertices"])

    @property
    def num_edges(self) -> int:
        return int(self.properties["num_edges"])

    @property
    def num_shards(self) -> int:
        return int(self.properties["num_shards"])

    @property
    def intervals(self) -> np.ndarray:
        return np.asarray(self.properties["intervals"], dtype=np.int64)

    # ---- vertex info ----------------------------------------------------
    def write_vertex_info(self, in_degree: np.ndarray, out_degree: np.ndarray) -> None:
        p = self.path / "vertex_info.npz"
        np.savez(p, in_degree=in_degree, out_degree=out_degree)
        self.io.written += p.stat().st_size

    def read_vertex_info(self) -> tuple[np.ndarray, np.ndarray]:
        p = self.path / "vertex_info.npz"
        with np.load(p) as z:
            self.io.read += p.stat().st_size
            return z["in_degree"], z["out_degree"]

    # ---- shards ----------------------------------------------------------
    def shard_path(self, shard_id: int) -> Path:
        return self.path / f"shard_{shard_id:05d}.npz"

    def write_shard(self, shard: ELLShard) -> None:
        p = self.shard_path(shard.shard_id)
        # unweighted graphs need no val array (paper §2.2) — vals are unit and
        # reconstructed from the col mask on read.
        mask = shard.cols >= 0
        unit = bool(np.array_equal(shard.vals, mask.astype(np.float32)))
        payload = dict(
            cols=shard.cols,
            row_map=shard.row_map,
            meta=np.array([shard.start_vertex, shard.end_vertex, shard.nnz,
                           int(unit)], dtype=np.int64),
        )
        if not unit:
            payload["vals"] = shard.vals
        np.savez(p, **payload)
        self.io.written += p.stat().st_size

    def read_shard(self, shard_id: int) -> ELLShard:
        p = self.shard_path(shard_id)
        self.io.read += p.stat().st_size
        with np.load(p) as z:
            meta = z["meta"]
            cols = z["cols"]
            unit = len(meta) > 3 and bool(meta[3])
            vals = ((cols >= 0).astype(np.float32) if unit else z["vals"])
            return ELLShard(
                shard_id=shard_id,
                start_vertex=int(meta[0]),
                end_vertex=int(meta[1]),
                nnz=int(meta[2]),
                cols=cols,
                vals=vals,
                row_map=z["row_map"],
            )

    def read_shard_bytes(self, shard_id: int) -> bytes:
        """Raw file bytes (used by the compressed cache, which stores blobs)."""
        p = self.shard_path(shard_id)
        data = p.read_bytes()
        self.io.read += len(data)
        return data

    def shard_nbytes(self, shard_id: int) -> int:
        return self.shard_path(shard_id).stat().st_size

    def total_shard_bytes(self) -> int:
        return sum(self.shard_nbytes(p) for p in range(self.num_shards))

    # ---- bloom filters ----------------------------------------------------
    def write_bloom(self, shard_id: int, bloom: BloomFilter) -> None:
        p = self.path / f"bloom_{shard_id:05d}.npz"
        np.savez(p, bits=bloom.bits, meta=np.array([bloom.num_bits, bloom.num_hashes]))
        self.io.written += p.stat().st_size

    def read_bloom(self, shard_id: int) -> BloomFilter:
        p = self.path / f"bloom_{shard_id:05d}.npz"
        self.io.read += p.stat().st_size
        with np.load(p) as z:
            meta = z["meta"]
            return BloomFilter(bits=z["bits"], num_bits=int(meta[0]), num_hashes=int(meta[1]))

    def read_all_blooms(self) -> list[BloomFilter]:
        return [self.read_bloom(p) for p in range(self.num_shards)]


# ---- raw edge-list files (preprocessing input) -----------------------------
def write_edge_list(path: str | os.PathLike, chunks, weighted: bool = False,
                    seed: int = 0, num_vertices: int | None = None) -> dict:
    """Write a binary edge list (.npy pair files per chunk) — the 'CSV' stand-in.

    Returns {num_vertices, num_edges, files}.  Using raw int64 binary instead
    of CSV keeps preprocessing benchmarks about I/O + layout, not atoi().
    """
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    n_edges = 0
    max_v = -1
    files = []
    for i, (src, dst) in enumerate(chunks):
        arr = np.stack([src, dst]).astype(np.int64)
        f = path / f"edges_{i:05d}.npy"
        np.save(f, arr)
        files.append(f.name)
        if weighted:
            w = rng.random(src.shape[0]).astype(np.float32) * 9 + 1
            np.save(path / f"weights_{i:05d}.npy", w)
        n_edges += src.shape[0]
        max_v = max(max_v, int(src.max(initial=-1)), int(dst.max(initial=-1)))
    meta = {"num_vertices": max(max_v + 1, num_vertices or 0),
            "num_edges": n_edges, "files": files, "weighted": weighted}
    with open(path / "meta.json", "w") as f:
        json.dump(meta, f)
    return meta


def iter_edge_list(path: str | os.PathLike, io: BytesCounter | None = None):
    """Yield (src, dst, val|None) chunks from a binary edge list directory."""
    path = Path(path)
    with open(path / "meta.json") as f:
        meta = json.load(f)
    for name in meta["files"]:
        p = path / name
        arr = np.load(p)
        if io is not None:
            io.read += p.stat().st_size
        w = None
        if meta.get("weighted"):
            wp = path / name.replace("edges_", "weights_")
            w = np.load(wp)
            if io is not None:
                io.read += wp.stat().st_size
        yield arr[0], arr[1], w
