"""npz-directory backend: the paper's property file + vertex info + shard files.

Layout of a preprocessed graph directory:

  property.json          — |V|, |E|, P, intervals, weighted, threshold (paper §2.2)
  vertex_info.npz        — in_degree, out_degree arrays
  bloom_<p>.npz          — per-shard Bloom filter over source vertices (§2.4.1)
  shard_<p>.npz          — blocked-ELL arrays (cols, vals, row_map) + metadata

``GraphStore`` is one implementation of the ``ShardSource`` protocol
(graph/source.py); the single-file mmap'd ``PackedGraphStore`` and the
RAM-resident ``MemoryGraphStore`` are the others.  Every read/write here is a
real file operation; the thread-safe ``BytesCounter`` instruments the store so
benchmarks report actual disk bytes, the paper's primary metric (Table 3).
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import ELLShard
from repro.graph.source import (BytesCounter, MissingGraphError,
                                ShardSourceBase, pack_shard_npz,
                                unpack_shard_npz, validate_properties)

__all__ = ["BytesCounter", "GraphStore", "MissingGraphError",
           "write_edge_list", "iter_edge_list"]


class GraphStore(ShardSourceBase):
    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self.io = BytesCounter()
        self._prop: dict | None = None

    # ---- property file -------------------------------------------------
    @property
    def properties(self) -> dict:
        if self._prop is None:
            p = self.path / "property.json"
            if not p.is_file():
                raise MissingGraphError(
                    f"{str(self.path)!r} is not a preprocessed graph "
                    "(no property.json); run "
                    "repro.graph.preprocess.preprocess_graph first")
            try:
                with open(p) as f:
                    prop = json.load(f)
            except json.JSONDecodeError as exc:
                raise MissingGraphError(
                    f"{str(p)!r} is not valid JSON ({exc}); the graph "
                    "directory is corrupt or half-written — re-run "
                    "preprocess_graph") from exc
            self._prop = validate_properties(prop, repr(str(self.path)))
        return self._prop

    def write_properties(self, prop: dict) -> None:
        self.path.mkdir(parents=True, exist_ok=True)
        tmp = self.path / "property.json.tmp"
        with open(tmp, "w") as f:
            json.dump(prop, f)
        os.replace(tmp, self.path / "property.json")
        self._prop = prop

    # ---- vertex info ----------------------------------------------------
    def write_vertex_info(self, in_degree: np.ndarray, out_degree: np.ndarray) -> None:
        p = self.path / "vertex_info.npz"
        np.savez(p, in_degree=in_degree, out_degree=out_degree)
        self.io.add_written(p.stat().st_size)

    def read_vertex_info(self) -> tuple[np.ndarray, np.ndarray]:
        p = self.path / "vertex_info.npz"
        with np.load(p) as z:
            self.io.add_read(p.stat().st_size)
            return z["in_degree"], z["out_degree"]

    # ---- shards ----------------------------------------------------------
    def shard_path(self, shard_id: int) -> Path:
        return self.path / f"shard_{shard_id:05d}.npz"

    def write_shard(self, shard: ELLShard) -> None:
        blob = pack_shard_npz(shard)
        self.shard_path(shard.shard_id).write_bytes(blob)
        self.io.add_written(len(blob))

    def read_shard(self, shard_id: int) -> ELLShard:
        return unpack_shard_npz(shard_id, self.read_shard_bytes(shard_id))

    def read_shard_bytes(self, shard_id: int) -> bytes:
        """Canonical npz blob — here that is exactly the file's bytes."""
        data = self.shard_path(shard_id).read_bytes()
        self.io.add_read(len(data))
        return data

    def shard_nbytes(self, shard_id: int) -> int:
        return self.shard_path(shard_id).stat().st_size

    # ---- bloom filters ----------------------------------------------------
    def write_bloom(self, shard_id: int, bloom: BloomFilter) -> None:
        p = self.path / f"bloom_{shard_id:05d}.npz"
        np.savez(p, bits=bloom.bits, meta=np.array([bloom.num_bits, bloom.num_hashes]))
        self.io.add_written(p.stat().st_size)

    def read_bloom(self, shard_id: int) -> BloomFilter:
        p = self.path / f"bloom_{shard_id:05d}.npz"
        self.io.add_read(p.stat().st_size)
        with np.load(p) as z:
            meta = z["meta"]
            return BloomFilter(bits=z["bits"], num_bits=int(meta[0]), num_hashes=int(meta[1]))


# ---- raw edge-list files (preprocessing input) -----------------------------
def write_edge_list(path: str | os.PathLike, chunks, weighted: bool = False,
                    seed: int = 0, num_vertices: int | None = None) -> dict:
    """Write a binary edge list (.npy pair files per chunk) — the 'CSV' stand-in.

    Returns {num_vertices, num_edges, files}.  Using raw int64 binary instead
    of CSV keeps preprocessing benchmarks about I/O + layout, not atoi().
    """
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    n_edges = 0
    max_v = -1
    files = []
    for i, (src, dst) in enumerate(chunks):
        arr = np.stack([src, dst]).astype(np.int64)
        f = path / f"edges_{i:05d}.npy"
        np.save(f, arr)
        files.append(f.name)
        if weighted:
            w = rng.random(src.shape[0]).astype(np.float32) * 9 + 1
            np.save(path / f"weights_{i:05d}.npy", w)
        n_edges += src.shape[0]
        max_v = max(max_v, int(src.max(initial=-1)), int(dst.max(initial=-1)))
    meta = {"num_vertices": max(max_v + 1, num_vertices or 0),
            "num_edges": n_edges, "files": files, "weighted": weighted}
    with open(path / "meta.json", "w") as f:
        json.dump(meta, f)
    return meta


def iter_edge_list(path: str | os.PathLike, io: BytesCounter | None = None):
    """Yield (src, dst, val|None) chunks from a binary edge list directory."""
    path = Path(path)
    with open(path / "meta.json") as f:
        meta = json.load(f)
    for name in meta["files"]:
        p = path / name
        arr = np.load(p)
        if io is not None:
            io.add_read(p.stat().st_size)
        w = None
        if meta.get("weighted"):
            wp = path / name.replace("edges_", "weights_")
            w = np.load(wp)
            if io is not None:
                io.add_read(wp.stat().st_size)
        yield arr[0], arr[1], w
