"""DeltaGraphStore: a mutable overlay over any frozen ShardSource backend.

GraphMP's VSW engine streams immutable destination-interval shards; this
module makes the graph mutable without the engine knowing.  A
``DeltaGraphStore`` wraps a base backend (npz directory, packed ``.gmpk``,
or RAM-resident) and keeps mutated shards as merged in-memory ``ELLShard``
views behind the exact same ``read_shard`` protocol:

  * ``apply(inserts=…, deletes=…)`` commits one **batch** of edge edits.
    Each commit bumps the store's **graph epoch** (a monotonic counter that
    replaces ``mtime_ns`` as the graph-identity/invalidation key) and stamps
    the touched shards with that epoch, so the cache and serve memo layers
    can invalidate *only* what changed.
  * Merging is **eager**: the dirty shard is re-laid out (CSR → blocked-ELL
    with the base store's layout parameters) at commit time, so
    ``properties`` (shard meta, ``num_edges``), degree arrays, Bloom
    filters, and canonical disk-byte accounting are consistent the moment
    ``apply`` returns — a run on the overlay is bitwise-identical to a run
    on the equivalent pre-merged frozen graph.
  * ``repro.graph.compact.compact`` folds the merged shards back into the
    base (rewriting only dirty shards) and releases the overlay memory.

Edit semantics are simple-digraph per ``(src, dst)`` key: an insert of an
edge that already exists is a weight **upsert** (parallel base copies
collapse to the single new edge); a delete removes every parallel copy; the
vertex set is fixed at wrap time.  A bounded per-epoch log records which
*source* vertices were touched and whether the commit was monotone for
min-propagation apps (insert-only / weight-non-increasing), which is what
``session.run_incremental`` seeds its frontier from.

Env knobs: ``GRAPHMP_DELTA_BUDGET`` caps resident overlay bytes (0 =
unbounded); when exceeded, ``GRAPHMP_DELTA_AUTOCOMPACT=1`` (default)
triggers an automatic ``compact()``, otherwise ``apply`` raises
``DeltaBudgetError``.
"""
from __future__ import annotations

import math
import os
import threading

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import CSRShard, ELLShard, csr_to_ell, quantize_shard
from repro.graph.source import ShardSourceBase, pack_shard_npz

_EPOCH_LOG_CAP = 256  # commits remembered for incremental-recompute seeding


class DeltaBudgetError(RuntimeError):
    """Overlay memory exceeded GRAPHMP_DELTA_BUDGET with auto-compact off."""


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return int(raw)
    except ValueError:
        return default


def _as_edge_arrays(edges, weighted_default: float = 1.0):
    """Normalize an edge batch to (src[int64], dst[int64], val[float32]).

    Accepts ``(src, dst)`` / ``(src, dst, val)`` array tuples or an iterable
    of ``(s, d)`` / ``(s, d, v)`` triples.  ``None``/empty → three empty
    arrays.
    """
    if edges is None:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0, dtype=np.float32)
    if isinstance(edges, tuple) and len(edges) in (2, 3) and \
            not np.isscalar(edges[0]):
        src = np.asarray(edges[0], dtype=np.int64).ravel()
        dst = np.asarray(edges[1], dtype=np.int64).ravel()
        val = (np.asarray(edges[2], dtype=np.float32).ravel()
               if len(edges) == 3
               else np.full(src.size, weighted_default, dtype=np.float32))
    else:
        rows = list(edges)
        src = np.array([r[0] for r in rows], dtype=np.int64)
        dst = np.array([r[1] for r in rows], dtype=np.int64)
        val = np.array([r[2] if len(r) > 2 else weighted_default
                        for r in rows], dtype=np.float32)
    if not (src.size == dst.size == val.size):
        raise ValueError("edge arrays must have matching lengths")
    return src, dst, val


def _ell_to_csr_triples(shard: ELLShard):
    """Decode a blocked-ELL shard back to CSR-ordered (local_dst, src, val).

    ``np.nonzero`` walks the [R, W] mask in C order — increasing ELL row,
    then column — which is exactly the original CSR edge order (wrapped rows
    of one destination are consecutive, padding rows are all-sentinel).
    """
    mask = shard.cols >= 0
    r_idx, c_idx = np.nonzero(mask)
    local = shard.row_map[r_idx].astype(np.int64)
    # vals_f32 dequantizes int8/float16 edge values (float32 passes through)
    return local, shard.cols[r_idx, c_idx].astype(np.int64), \
        shard.vals_f32()[r_idx, c_idx].astype(np.float32)


class DeltaGraphStore(ShardSourceBase):
    """Mutable overlay: frozen base + in-memory merged views of dirty shards.

    Thread-safe: reads and ``apply`` serialize on an internal RLock (the
    engine additionally pins the epoch per run and refuses shards from a
    newer one — see ``ShardPipeline``).  Byte accounting is delegated to the
    base store's counter so session/service stats keep one ledger.
    """

    def __init__(self, base, *, delta_budget_bytes: int | None = None,
                 auto_compact: bool | None = None):
        self.base = base
        self.io = base.io
        self._lock = threading.RLock()
        self._epoch = 0
        self._shard_epoch: dict[int, int] = {}
        # overlay state per dirty shard (cleared by compaction)
        self._merged: dict[int, ELLShard] = {}
        self._blobs: dict[int, bytes] = {}
        self._blooms: dict[int, BloomFilter] = {}
        # graph-level state, forked lazily from the base on first commit
        prop = base.properties
        self._prop = dict(prop)
        self._prop["shards"] = [dict(m) for m in prop["shards"]]
        self._in_deg, self._out_deg = (np.array(a, dtype=np.int64, copy=True)
                                       for a in base.read_vertex_info())
        self._intervals = np.asarray(prop["intervals"], dtype=np.int64)
        # epoch log: (epoch, affected_source_vertices, monotone) per commit
        self._log: list[tuple[int, np.ndarray, bool]] = []
        self._log_floor = 0  # epochs <= floor have been forgotten
        if delta_budget_bytes is None:
            delta_budget_bytes = _env_int("GRAPHMP_DELTA_BUDGET", 0)
        if auto_compact is None:
            auto_compact = _env_int("GRAPHMP_DELTA_AUTOCOMPACT", 1) != 0
        self.delta_budget_bytes = int(delta_budget_bytes)
        self.auto_compact = bool(auto_compact)
        self._lane = self._infer_lane()

    # -- identity / passthrough --------------------------------------------
    @property
    def path(self):
        return getattr(self.base, "path", "<delta>")

    @property
    def properties(self) -> dict:
        return self._prop

    def close(self) -> None:
        close = getattr(self.base, "close", None)
        if close is not None:
            close()

    def __repr__(self) -> str:
        return (f"DeltaGraphStore(base={type(self.base).__name__}, "
                f"epoch={self._epoch}, dirty={len(self._merged)})")

    # -- ShardSource surface ------------------------------------------------
    def read_vertex_info(self):
        with self._lock:
            if not self._shard_epoch:  # pristine: identical to base
                return self.base.read_vertex_info()
            self.io.add_read(self._in_deg.nbytes + self._out_deg.nbytes)
            return self._in_deg.copy(), self._out_deg.copy()

    def read_shard(self, shard_id: int) -> ELLShard:
        with self._lock:
            merged = self._merged.get(shard_id)
            if merged is None:
                return self.base.read_shard(shard_id)
            self.io.add_read(len(self._blobs[shard_id]))  # canonical charge
            return merged

    def read_shard_bytes(self, shard_id: int) -> bytes:
        with self._lock:
            blob = self._blobs.get(shard_id)
            if blob is None:
                return self.base.read_shard_bytes(shard_id)
            self.io.add_read(len(blob))
            return blob

    def shard_nbytes(self, shard_id: int) -> int:
        with self._lock:
            blob = self._blobs.get(shard_id)
            return len(blob) if blob is not None else \
                self.base.shard_nbytes(shard_id)

    def read_bloom(self, shard_id: int) -> BloomFilter:
        with self._lock:
            bloom = self._blooms.get(shard_id)
            if bloom is None:
                return self.base.read_bloom(shard_id)
            self.io.add_read(bloom.nbytes())
            return bloom

    # -- epochs -------------------------------------------------------------
    def epoch(self) -> int:
        return self._epoch

    def shard_epoch(self, shard_id: int) -> int:
        return self._shard_epoch.get(shard_id, 0)

    def dirty_shards(self) -> list[int]:
        """Shards whose merged view has not yet been compacted into the base."""
        with self._lock:
            return sorted(self._merged)

    def delta_nbytes(self) -> int:
        """Resident overlay bytes (decoded merged shards + canonical blobs)."""
        with self._lock:
            return sum(s.decoded_nbytes() for s in self._merged.values()) + \
                sum(len(b) for b in self._blobs.values())

    # -- incremental-recompute support --------------------------------------
    def affected_sources_since(self, since_epoch: int) -> np.ndarray | None:
        """Union of source vertices touched by commits after ``since_epoch``,
        or None when the epoch log no longer reaches back that far."""
        with self._lock:
            if since_epoch < self._log_floor:
                return None
            parts = [srcs for (e, srcs, _m) in self._log if e > since_epoch]
            if not parts:
                return np.zeros(0, dtype=np.int64)
            return np.unique(np.concatenate(parts))

    def monotone_since(self, since_epoch: int) -> bool:
        """True iff every commit after ``since_epoch`` only added relaxation
        opportunities for min-propagation apps (no deletes, no weight
        increases).  Conservative: unknown history → False."""
        with self._lock:
            if since_epoch >= self._epoch:
                return True
            if since_epoch < self._log_floor:
                return False
            return all(m for (e, _s, m) in self._log if e > since_epoch)

    # -- mutation ------------------------------------------------------------
    def apply(self, inserts=None, deletes=None, updates=None) -> int:
        """Commit one batch of edge edits; returns the new graph epoch.

        ``inserts``/``updates`` (synonyms — both upsert) take ``(src, dst)``
        or ``(src, dst, weight)`` arrays or triple iterables; ``deletes``
        takes ``(src, dst)`` pairs.  Within a batch the last edit of a
        ``(src, dst)`` key wins, with deletes ordered after upserts — a key
        both upserted and deleted in one batch ends up deleted.
        """
        ins_s, ins_d, ins_v = _as_edge_arrays(inserts)
        upd_s, upd_d, upd_v = _as_edge_arrays(updates)
        del_s, del_d, _ = _as_edge_arrays(deletes)
        ins_s = np.concatenate([ins_s, upd_s])
        ins_d = np.concatenate([ins_d, upd_d])
        ins_v = np.concatenate([ins_v, upd_v])
        if ins_s.size == 0 and del_s.size == 0:
            return self._epoch

        n = self.num_vertices
        for name, (s, d) in (("insert", (ins_s, ins_d)),
                             ("delete", (del_s, del_d))):
            if s.size and (s.min() < 0 or s.max() >= n or
                           d.min() < 0 or d.max() >= n):
                raise ValueError(
                    f"{name} endpoints must lie in [0, {n}): the vertex set "
                    "is fixed at DeltaGraphStore construction")

        with self._lock:
            # last-edit-wins dedup across the whole batch, deletes merged in
            # as NaN-valued upserts (keyed identically)
            keys = np.concatenate([ins_d * n + ins_s, del_d * n + del_s])
            vals = np.concatenate(
                [ins_v, np.full(del_s.size, np.nan, dtype=np.float32)])
            _, last = np.unique(keys[::-1], return_index=True)
            order = np.sort(keys.size - 1 - last)
            keys, vals = keys[order], vals[order]
            edit_s = (keys % n).astype(np.int64)
            edit_d = (keys // n).astype(np.int64)

            new_epoch = self._epoch + 1
            owner = np.searchsorted(self._intervals, edit_d,
                                    side="right") - 1
            affected, monotone = [], True
            for p in np.unique(owner):
                sel = owner == p
                aff_p, mono_p = self._merge_shard(
                    int(p), edit_s[sel], edit_d[sel], keys[sel], vals[sel])
                affected.append(aff_p)
                monotone = monotone and mono_p
                self._shard_epoch[int(p)] = new_epoch
            self._prop["num_edges"] = int(self._in_deg.sum())
            self._epoch = new_epoch
            self._log.append(
                (new_epoch,
                 np.unique(np.concatenate(affected)) if affected
                 else np.zeros(0, dtype=np.int64),
                 monotone))
            if len(self._log) > _EPOCH_LOG_CAP:
                self._log_floor = self._log[0][0]
                del self._log[0]

            if self.delta_budget_bytes and \
                    self.delta_nbytes() > self.delta_budget_bytes:
                if not self.auto_compact:
                    raise DeltaBudgetError(
                        f"overlay holds {self.delta_nbytes()} bytes > "
                        f"GRAPHMP_DELTA_BUDGET={self.delta_budget_bytes} "
                        "and auto-compact is off")
                from repro.graph.compact import compact
                compact(self)
            return self._epoch

    def _merge_shard(self, p: int, edit_s, edit_d, edit_keys, edit_vals):
        """Apply one shard's deduped edits to its current merged view.

        Returns ``(affected_sources, monotone)`` for the epoch log.  Must be
        called under the lock with ``edit_keys`` already deduplicated
        (last-edit-wins) and NaN values marking deletes.
        """
        n = self.num_vertices
        cur = self._merged.get(p)
        if cur is None:
            cur = self.base.read_shard(p)
        local, srcs, vals = _ell_to_csr_triples(cur)
        start = cur.start_vertex
        base_keys = (local + start) * n + srcs

        # copies of each edited key already present (degree/monotone math)
        uk, uc = np.unique(base_keys, return_counts=True)
        pos = np.searchsorted(uk, edit_keys)
        pos_ok = pos < uk.size
        present = np.zeros(edit_keys.size, dtype=np.int64)
        present[pos_ok] = np.where(uk[pos[pos_ok]] == edit_keys[pos_ok],
                                   uc[pos[pos_ok]], 0)
        # smallest existing weight per edited key (monotonicity check)
        old_min = np.full(edit_keys.size, np.inf, dtype=np.float64)
        if base_keys.size:
            o = np.argsort(base_keys, kind="stable")
            bk, bv = base_keys[o], vals[o]
            grp = np.searchsorted(bk, edit_keys)
            for i in np.nonzero(present > 0)[0]:
                lo = grp[i]
                old_min[i] = bv[lo:lo + present[i]].min()

        is_del = np.isnan(edit_vals)
        # drop every base copy of every edited key, then append the upserts
        keep = ~np.isin(base_keys, edit_keys)
        app = ~is_del
        m_local = np.concatenate([local[keep], edit_d[app] - start])
        m_srcs = np.concatenate([srcs[keep], edit_s[app]])
        m_vals = np.concatenate([vals[keep],
                                 edit_vals[app].astype(np.float32)])
        order = np.argsort(m_local, kind="stable")  # kept first, then new
        m_local, m_srcs, m_vals = m_local[order], m_srcs[order], m_vals[order]

        rows = cur.end_vertex - cur.start_vertex
        counts = np.bincount(m_local, minlength=rows)
        csr = CSRShard(
            shard_id=p, start_vertex=cur.start_vertex,
            end_vertex=cur.end_vertex,
            row=np.concatenate([[0], np.cumsum(counts)]).astype(np.int64),
            col=m_srcs.astype(np.int32), val=m_vals.astype(np.float32))
        merged = csr_to_ell(csr, max_width=self._ell_max_width(),
                            lane=self._lane)
        vd = self._val_dtype()
        if vd != "float32" and self._prop.get("weighted"):
            merged = quantize_shard(merged, vd)  # keep the store's edge dtype
        blob = pack_shard_npz(merged)

        # degrees + shard meta + epoch-log ingredients
        edge_delta = app.astype(np.int64) - present
        np.add.at(self._in_deg, edit_d, edge_delta)
        np.add.at(self._out_deg, edit_s, edge_delta)
        meta = self._prop["shards"][p]
        meta["rows"], meta["width"] = (int(x) for x in merged.shape)
        meta["nnz"] = int(merged.nnz)
        base_bloom = self._blooms.get(p) or self.base.read_bloom(p)
        self._merged[p] = merged
        self._blobs[p] = blob
        self._blooms[p] = BloomFilter.build(
            merged.source_vertices(), num_bits=base_bloom.num_bits,
            num_hashes=base_bloom.num_hashes)

        deleted_existing = is_del & (present > 0)
        increased = app & (present > 0) & (edit_vals > old_min)
        monotone = not (deleted_existing.any() or bool(increased.any()))
        affected = edit_s[app]  # sources of upserts seed incremental runs
        return np.unique(affected), monotone

    # -- layout parameters ---------------------------------------------------
    def _ell_max_width(self) -> int:
        return int(self._prop.get("ell_max_width", 512))

    def _val_dtype(self) -> str:
        return str(self._prop.get("val_dtype", "float32"))

    def _infer_lane(self) -> int:
        """Layout lane: recorded by preprocess since the delta subsystem
        landed; older stores fall back to the gcd of shard widths (every
        width is a lane multiple, so the gcd reproduces a valid layout)."""
        lane = self._prop.get("lane")
        if lane:
            return int(lane)
        widths = [int(m["width"]) for m in self._prop["shards"]]
        return math.gcd(*widths) if widths else 128

    # -- compaction hook -----------------------------------------------------
    def _compacted(self) -> None:
        """Release overlay state after the base absorbed it.  Epochs are
        kept: shard content is unchanged by compaction, so cache entries
        stamped with the dirty epoch stay valid."""
        with self._lock:
            self._merged.clear()
            self._blobs.clear()
            self._blooms.clear()
