"""Three-step preprocessing pipeline (paper §2.2), with real disk I/O.

Step 1: scan the edge list, count in/out-degrees, compute vertex intervals
        with Algorithm 1 (cost: D|E| read).
Step 2: re-scan the edge list, append each edge to its owning shard's scratch
        file by destination interval (D|E| read + D|E| write).
Step 3: per shard, sort by destination, emit CSR -> blocked-ELL, persist, and
        build the shard's Bloom filter over source vertices
        (D|E| read + ~D|E| write).

Total ~5 D|E| of I/O — matching the paper's Table 3 row for VSW.  One
preprocessing run serves every application (PR/SSSP/CC share the store).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import (EDGE_VAL_DTYPES, CSRShard, compute_intervals,
                               csr_to_ell, quantize_shard)
from repro.graph.storage import GraphStore, iter_edge_list


def resolve_val_dtype(val_dtype: str | None) -> str:
    """Edge-value storage dtype: explicit arg > GRAPHMP_EDGE_DTYPE > float32."""
    if val_dtype is None:
        val_dtype = os.environ.get("GRAPHMP_EDGE_DTYPE") or "float32"
    if val_dtype not in EDGE_VAL_DTYPES:
        raise ValueError(f"val_dtype must be one of {EDGE_VAL_DTYPES}, "
                         f"got {val_dtype!r}")
    return val_dtype


def preprocess_graph(
    edge_list_dir: str,
    out_dir: str,
    threshold_edge_num: int = 1 << 20,
    ell_max_width: int = 512,
    bloom_fp_rate: float = 0.01,
    num_vertices: int | None = None,
    lane: int = 128,
    val_dtype: str | None = None,
) -> GraphStore:
    val_dtype = resolve_val_dtype(val_dtype)
    store = GraphStore(out_dir)
    t0 = time.time()

    # ---- step 1: degree scan + Algorithm 1 --------------------------------
    with open(Path(edge_list_dir) / "meta.json") as f:
        meta = json.load(f)
    n = int(num_vertices or meta["num_vertices"])
    in_deg = np.zeros(n, dtype=np.int64)
    out_deg = np.zeros(n, dtype=np.int64)
    n_edges = 0
    for src, dst, _ in iter_edge_list(edge_list_dir, store.io):
        in_deg += np.bincount(dst, minlength=n)
        out_deg += np.bincount(src, minlength=n)
        n_edges += src.shape[0]
    starts = compute_intervals(in_deg, threshold_edge_num)
    P = len(starts) - 1

    # ---- step 2: bucket edges into per-shard scratch files -----------------
    scratch_dir = Path(out_dir) / "scratch"
    scratch_dir.mkdir(parents=True, exist_ok=True)
    scratch = [open(scratch_dir / f"s{p:05d}.bin", "wb") for p in range(P)]
    weighted = bool(meta.get("weighted"))
    for src, dst, val in iter_edge_list(edge_list_dir, store.io):
        owner = np.searchsorted(starts, dst, side="right") - 1
        order = np.argsort(owner, kind="stable")
        owner_s, src_s, dst_s = owner[order], src[order], dst[order]
        val_s = val[order] if val is not None else None
        bounds = np.searchsorted(owner_s, np.arange(P + 1))
        for p in range(P):
            lo, hi = bounds[p], bounds[p + 1]
            if lo == hi:
                continue
            if weighted:
                rec = np.empty((hi - lo, 3), dtype=np.int64)
                rec[:, 0], rec[:, 1] = src_s[lo:hi], dst_s[lo:hi]
                rec[:, 2] = val_s[lo:hi].view(np.uint32).astype(np.int64)
            else:
                rec = np.stack([src_s[lo:hi], dst_s[lo:hi]], axis=1)
            buf = rec.tobytes()
            scratch[p].write(buf)
            store.io.add_written(len(buf))
    for f in scratch:
        f.close()

    # ---- step 3: sort, CSR -> ELL, persist, Bloom ---------------------------
    bloom_bits = BloomFilter.sized_for(int(threshold_edge_num), bloom_fp_rate)
    shard_meta = []
    for p in range(P):
        sp = scratch_dir / f"s{p:05d}.bin"
        width = 3 if weighted else 2
        raw = np.fromfile(sp, dtype=np.int64).reshape(-1, width)
        store.io.add_read(sp.stat().st_size)
        lo, hi = int(starts[p]), int(starts[p + 1])
        dst_local = raw[:, 1] - lo
        order = np.argsort(dst_local, kind="stable")
        src_sorted = raw[order, 0]
        counts = np.bincount(dst_local, minlength=hi - lo)
        row = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        vals = None
        if weighted:
            vals = raw[order, 2].astype(np.uint32).view(np.float32)
        csr = CSRShard(
            shard_id=p, start_vertex=lo, end_vertex=hi,
            row=row, col=src_sorted.astype(np.int32), val=vals,
        )
        ell = csr_to_ell(csr, max_width=ell_max_width, lane=lane)
        if weighted and val_dtype != "float32":
            # quantize per shard (scale/zero recorded in the blob); unweighted
            # graphs keep unit float32 vals — the npz codec already elides them
            ell = quantize_shard(ell, val_dtype)
        store.write_shard(ell)
        store.write_bloom(p, BloomFilter.build(ell.source_vertices(), num_bits=bloom_bits))
        shard_meta.append({"rows": int(ell.shape[0]), "width": int(ell.shape[1]), "nnz": ell.nnz})
        sp.unlink()
    scratch_dir.rmdir()

    store.write_vertex_info(in_deg, out_deg)
    store.write_properties(
        {
            "num_vertices": n,
            "num_edges": int(n_edges),
            "num_shards": P,
            "intervals": [int(s) for s in starts],
            "weighted": weighted,
            "val_dtype": val_dtype if weighted else "float32",
            "threshold_edge_num": int(threshold_edge_num),
            "ell_max_width": int(ell_max_width),
            "lane": int(lane),  # DeltaGraphStore re-lays dirty shards with it
            "shards": shard_meta,
            "preprocess_seconds": time.time() - t0,
        }
    )
    return store
