"""In-memory backend: the whole graph RAM-resident (tests/benchmarks).

A ``MemoryGraphStore`` serves shards from host memory with zero real I/O —
the upper bound every disk backend is measured against (paper Figs. 9-10's
"GraphMP vs in-memory systems" comparison).  It still *accounts* every
``read_shard`` at the shard's canonical nbytes so runs report the same
"disk" byte totals as the npz/packed backends: benchmark deltas then isolate
the storage medium, not the bookkeeping.

Build one from any other source with ``MemoryGraphStore.from_source(...)``
(one full pass, charged to that source's counters), or construct directly
from shards for synthetic tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import ELLShard
import dataclasses

from repro.graph.source import (BytesCounter, ShardSource, ShardSourceBase,
                                pack_shard_npz, validate_properties)


def _materialized(shard: ELLShard) -> ELLShard:
    """Own the arrays: a shard read from the packed backend is a set of
    mmap views, and a 'RAM-resident' store holding views would stay
    disk-backed (pages droppable under pressure, mmap pinned forever)."""
    if shard.cols.flags.writeable:
        return shard  # already owned (npz / direct construction)
    return dataclasses.replace(shard, cols=np.array(shard.cols),
                               vals=np.array(shard.vals),
                               row_map=np.array(shard.row_map))


class MemoryGraphStore(ShardSourceBase):
    def __init__(self, properties: dict, vertex_info: tuple[np.ndarray, np.ndarray],
                 shards: list[ELLShard], blooms: list[BloomFilter],
                 shard_nbytes: list[int] | None = None,
                 path: str = "<memory>"):
        self._prop = validate_properties(dict(properties), "MemoryGraphStore")
        if len(shards) != self.num_shards or len(blooms) != self.num_shards:
            raise ValueError(
                f"properties claim {self.num_shards} shards, got "
                f"{len(shards)} shards / {len(blooms)} blooms")
        self._vertex_info = vertex_info
        self._shards = list(shards)
        self._blooms = list(blooms)
        # canonical per-shard accounting size; derived from the npz blob when
        # the caller has no on-disk sizes to carry over
        self._nbytes = ([int(b) for b in shard_nbytes]
                        if shard_nbytes is not None
                        else [len(pack_shard_npz(s)) for s in shards])
        self.path = path
        self.io = BytesCounter()

    @classmethod
    def from_source(cls, source: ShardSource) -> "MemoryGraphStore":
        """Load every shard/bloom of another source into RAM (one full pass)."""
        n = int(source.properties["num_shards"])
        return cls(
            properties=source.properties,
            vertex_info=source.read_vertex_info(),
            shards=[_materialized(source.read_shard(p)) for p in range(n)],
            blooms=[source.read_bloom(p) for p in range(n)],
            shard_nbytes=[int(source.shard_nbytes(p)) for p in range(n)],
            path=f"<memory:{getattr(source, 'path', '?')}>",
        )

    @property
    def properties(self) -> dict:
        return self._prop

    def read_vertex_info(self) -> tuple[np.ndarray, np.ndarray]:
        in_deg, out_deg = self._vertex_info
        self.io.add_read(in_deg.nbytes + out_deg.nbytes)
        return in_deg, out_deg

    def read_shard(self, shard_id: int) -> ELLShard:
        self.io.add_read(self.shard_nbytes(shard_id))
        return self._shards[shard_id]

    def read_shard_bytes(self, shard_id: int) -> bytes:
        self.io.add_read(self.shard_nbytes(shard_id))
        return pack_shard_npz(self._shards[shard_id])

    def shard_nbytes(self, shard_id: int) -> int:
        return self._nbytes[shard_id]

    def read_bloom(self, shard_id: int) -> BloomFilter:
        bloom = self._blooms[shard_id]
        self.io.add_read(bloom.nbytes())
        return bloom

    def _apply_compaction(self, shards: dict[int, ELLShard],
                          blooms: dict[int, BloomFilter],
                          nbytes: dict[int, int],
                          vertex_info: tuple[np.ndarray, np.ndarray],
                          properties: dict) -> None:
        """Absorb a DeltaGraphStore overlay (repro.graph.compact): swap in
        the merged views of the dirty shards and the updated graph-level
        state.  Clean shards keep their identity (views stay valid)."""
        for p, shard in shards.items():
            self._shards[p] = _materialized(shard)
            self._blooms[p] = blooms[p]
            self._nbytes[p] = int(nbytes[p])
        self._vertex_info = vertex_info
        self._prop = validate_properties(dict(properties), "MemoryGraphStore")
