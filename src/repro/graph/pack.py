"""CLI converter: npz graph directory -> packed single-file format.

    python -m repro.graph.pack GRAPH_DIR [OUT_FILE]

OUT_FILE defaults to GRAPH_DIR/packed.gmpk.  The packed file is the
zero-copy mmap backend consumed by ``GraphSession(path, backend="packed")``
(see repro/graph/packed.py for the layout).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.graph.packed import DEFAULT_PACKED_NAME, pack_graph
from repro.graph.source import MissingGraphError
from repro.graph.storage import GraphStore


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.graph.pack",
        description="Pack a preprocessed graph directory into one mmap-able "
                    "file (zero-copy shard views).")
    ap.add_argument("graph_dir", help="preprocessed graph directory "
                                      "(output of preprocess_graph)")
    ap.add_argument("out_file", nargs="?", default=None,
                    help=f"output file (default: GRAPH_DIR/{DEFAULT_PACKED_NAME})")
    args = ap.parse_args(argv)
    store = GraphStore(args.graph_dir)
    try:
        out = pack_graph(store, args.out_file)
    except MissingGraphError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    size = Path(out).stat().st_size
    print(f"packed {store.num_shards} shards, |V|={store.num_vertices}, "
          f"|E|={store.num_edges} -> {out} ({size / 1e6:.1f} MB)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
