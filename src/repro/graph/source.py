"""ShardSource: the storage-backend protocol the engine and cache talk to.

GraphMP's data path only ever needs five things from storage — decoded
shards, raw shard blobs, shard sizes, Bloom filters, and byte accounting —
so that surface IS the protocol.  Everything above it (``CompressedShardCache``,
``ShardPipeline``, ``VSWEngine``, ``GraphSession``) is backend-agnostic;
backends below it ship in three flavours:

  * ``repro.graph.storage.GraphStore``   — the original npz-per-shard directory
  * ``repro.graph.packed.PackedGraphStore`` — one mmap'd file, zero-copy views
  * ``repro.graph.memory.MemoryGraphStore`` — RAM-resident (tests/benchmarks)

Disk-byte accounting (the paper's Table-3 metric) is **canonical**: every
backend charges a shard read at the shard's canonical npz-blob size, so the
reported byte counts are identical whichever backend served the run — figures
stay comparable across backends and prefetch depths.  ``BytesCounter`` is
thread-safe because the ``ShardPipeline`` fetches from background threads.
"""
from __future__ import annotations

import io as _io
import threading
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.shards import ELLShard


class MissingGraphError(FileNotFoundError):
    """Raised when a path is not a preprocessed graph (no/invalid property.json)."""


class ConcurrentMutationError(RuntimeError):
    """Raised when a run observes a graph epoch newer than the one it pinned
    at start — i.e. the store was mutated mid-run without draining the run
    first (``GraphService.apply_mutations`` drains; direct ``apply`` calls
    against a store with live runs do not)."""


_REQUIRED_PROPERTIES = ("num_vertices", "num_edges", "num_shards",
                        "intervals", "shards")


def validate_properties(prop: dict, where: str) -> dict:
    """Check a property dict has the keys every consumer relies on."""
    missing = [k for k in _REQUIRED_PROPERTIES if k not in prop]
    if missing:
        raise MissingGraphError(
            f"{where} is not a preprocessed graph: property.json lacks "
            f"{missing}; run repro.graph.preprocess.preprocess_graph first")
    return prop


class BytesCounter:
    """Thread-safe read/written byte tally.

    Mutate through ``add_read``/``add_written`` (atomic under an internal
    lock — prefetch threads and the main loop share one counter).  The
    ``read``/``written`` attributes stay plain-readable, and their setters
    keep legacy ``counter.read += n`` call sites working (those are only
    atomic on a single thread; concurrent writers must use the adders).
    """

    __slots__ = ("_lock", "_read", "_written")

    def __init__(self, read: int = 0, written: int = 0):
        self._lock = threading.Lock()
        self._read = int(read)
        self._written = int(written)

    def add_read(self, n: int) -> None:
        with self._lock:
            self._read += int(n)

    def add_written(self, n: int) -> None:
        with self._lock:
            self._written += int(n)

    @property
    def read(self) -> int:
        return self._read

    @read.setter
    def read(self, value: int) -> None:
        with self._lock:
            self._read = int(value)

    @property
    def written(self) -> int:
        return self._written

    @written.setter
    def written(self, value: int) -> None:
        with self._lock:
            self._written = int(value)

    def reset(self) -> None:
        with self._lock:
            self._read = 0
            self._written = 0

    def __repr__(self) -> str:
        return f"BytesCounter(read={self.read}, written={self.written})"


# ---------------------------------------------------------------------------
# canonical shard serialization (npz blob) — shared by every backend + cache
# ---------------------------------------------------------------------------
def pack_shard_npz(shard: ELLShard) -> bytes:
    """Serialize a shard as the canonical npz blob (the on-disk npz format).

    Unweighted graphs need no val array (paper §2.2): vals are unit and
    reconstructed from the col mask on read.
    """
    buf = _io.BytesIO()
    mask = shard.cols >= 0
    unit = (shard.vals.dtype == np.float32
            and bool(np.array_equal(shard.vals, mask.astype(np.float32))))
    payload = dict(
        cols=shard.cols,
        row_map=shard.row_map,
        meta=np.array([shard.start_vertex, shard.end_vertex, shard.nnz,
                       int(unit)], dtype=np.int64),
    )
    if not unit:
        payload["vals"] = shard.vals
        if shard.vals.dtype != np.float32:
            # affine dequant params for quantized edge values; float64 so
            # the (float32-rounded) python floats round-trip exactly
            payload["qparams"] = np.array([shard.val_scale, shard.val_zero],
                                          dtype=np.float64)
    np.savez(buf, **payload)
    return buf.getvalue()


def unpack_shard_npz(shard_id: int, blob: bytes) -> ELLShard:
    with np.load(_io.BytesIO(blob)) as z:
        meta = z["meta"]
        cols = z["cols"]
        unit = len(meta) > 3 and bool(meta[3])
        vals = (cols >= 0).astype(np.float32) if unit else z["vals"]
        scale, zero = 1.0, 0.0
        if "qparams" in z.files:
            qp = z["qparams"]
            scale, zero = float(qp[0]), float(qp[1])
        return ELLShard(
            shard_id=shard_id,
            start_vertex=int(meta[0]),
            end_vertex=int(meta[1]),
            nnz=int(meta[2]),
            cols=cols,
            vals=vals,
            row_map=z["row_map"],
            val_scale=scale,
            val_zero=zero,
        )


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------
@runtime_checkable
class ShardSource(Protocol):
    """Structural type of a storage backend (what the cache/engine require)."""

    io: BytesCounter

    @property
    def properties(self) -> dict: ...
    def read_vertex_info(self) -> tuple[np.ndarray, np.ndarray]: ...
    def read_shard(self, shard_id: int) -> ELLShard: ...
    def read_shard_bytes(self, shard_id: int) -> bytes: ...
    def shard_nbytes(self, shard_id: int) -> int: ...
    def read_bloom(self, shard_id: int) -> BloomFilter: ...
    def epoch(self) -> int: ...
    def shard_epoch(self, shard_id: int) -> int: ...


class ShardSourceBase:
    """Derived accessors shared by every backend (all come off ``properties``)."""

    io: BytesCounter

    @property
    def properties(self) -> dict:  # pragma: no cover - abstract
        raise NotImplementedError

    @property
    def num_vertices(self) -> int:
        return int(self.properties["num_vertices"])

    @property
    def num_edges(self) -> int:
        return int(self.properties["num_edges"])

    @property
    def num_shards(self) -> int:
        return int(self.properties["num_shards"])

    @property
    def intervals(self) -> np.ndarray:
        return np.asarray(self.properties["intervals"], dtype=np.int64)

    def shard_ids(self) -> Iterable[int]:
        return range(self.num_shards)

    def total_shard_bytes(self) -> int:
        return sum(self.shard_nbytes(p) for p in self.shard_ids())

    def shard_nbytes(self, shard_id: int) -> int:  # pragma: no cover - abstract
        raise NotImplementedError

    def read_bloom(self, shard_id: int) -> BloomFilter:  # pragma: no cover
        raise NotImplementedError

    def read_all_blooms(self) -> list[BloomFilter]:
        return [self.read_bloom(p) for p in self.shard_ids()]

    # -- mutability surface (frozen stores sit forever at epoch 0) ----------
    def epoch(self) -> int:
        """Monotonic commit counter; 0 means the graph has never mutated."""
        return 0

    def shard_epoch(self, shard_id: int) -> int:
        """Epoch at which this shard's content last changed (0 = pristine)."""
        return 0


# ---------------------------------------------------------------------------
# graph identity / staleness — one code path for the serve memo layer and the
# session's auto-repack check
# ---------------------------------------------------------------------------
def path_mtime_ns(path) -> int:
    """mtime of ``path`` in ns, or -1 when it does not exist."""
    import os

    try:
        return os.stat(str(path)).st_mtime_ns
    except OSError:
        return -1


def graph_token(store) -> tuple:
    """A hashable token that changes iff the graph content may have changed.

    Mutable stores version themselves with :meth:`ShardSource.epoch`; frozen
    on-disk stores fall back to the mtime of the backing file
    (``property.json`` for directories), preserving the pre-epoch behavior.
    Stores with neither identity get an object-identity token.
    """
    epoch_fn = getattr(store, "epoch", None)
    epoch = int(epoch_fn()) if callable(epoch_fn) else 0
    path = getattr(store, "path", None)
    ident = str(path) if path is not None else f"<store:{id(store)}>"
    if epoch > 0:
        return (ident, "epoch", epoch)
    if path is not None:
        import os

        probe = str(path)
        if os.path.isdir(probe):
            probe = os.path.join(probe, "property.json")
        mtime = path_mtime_ns(probe)
        if mtime >= 0:
            return (ident, "mtime", mtime)
    return ("unversioned", id(store))
