"""Gemma 2B [arXiv:2403.08295; hf]: 18L, d_model 2048, 8 heads, MQA (kv=1),
head_dim 256, GeGLU d_ff 16384, vocab 256000, tied embeddings, full attention
(=> long_500k skipped, DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    rope_type="rope",
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2403.08295",
)
