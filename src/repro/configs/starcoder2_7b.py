"""StarCoder2-7B [arXiv:2402.19173; hf]: 32L, d_model 4608, 36H GQA kv=4,
d_ff 18432, vocab 49152, RoPE, sliding-window 4096 (paper §Model; makes the
arch sub-quadratic, so long_500k runs), LayerNorm + GELU MLP."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_type="rope",
    rope_theta=1e5,
    sliding_window=4096,
    sub_quadratic=True,
    source="arXiv:2402.19173",
)
