"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table]: 61L,
d_model 7168, 64H GQA kv=8, vocab 163840, MoE 384 experts top-8 with expert
d_ff 2048 + 1 shared expert, first layer dense.  Full attention =>
long_500k skipped.  The 384-expert top-8 routing is the closest LM analogue
of GraphMP's selective shard scheduling (DESIGN.md §5) — hillclimb cell."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_type="rope",
    rope_theta=5e4,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_k_dense=1),
    sub_quadratic=False,
    source="arXiv:2501.kimi2",
)
