"""Qwen2-VL-72B [arXiv:2409.12191; hf]: 80L, d_model 8192, 64H GQA kv=8,
d_ff 29568, vocab 152064, M-RoPE (3-section rotary over temporal/h/w),
dynamic-resolution vision frontend STUBBED per spec (precomputed patch
embeddings).  Full attention => long_500k skipped."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_type="mrope",
    rope_theta=1e6,
    modality_stub="image_patches",
    img_patches=256,
    sub_quadratic=False,
    source="arXiv:2409.12191",
)
