"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: 32L, d_model 4096, 32H GQA kv=8,
d_ff 14336, vocab 65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave
(one attention layer per 8), hybrid => sub-quadratic, long_500k runs."""
from repro.configs.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_type="none",  # Jamba uses no positional encoding (Mamba provides order)
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
    attn_every=8,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,
    source="arXiv:2403.19887",
)
