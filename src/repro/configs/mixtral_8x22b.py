"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L, d_model 6144, 48H GQA kv=8,
d_ff 16384, vocab 32768, MoE 8 experts top-2, sliding-window attention
(per the assignment table) => sub-quadratic, long_500k runs."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_type="rope",
    rope_theta=1e6,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    sub_quadratic=True,
    source="arXiv:2401.04088",
)
