"""Minitron-4B [arXiv:2407.14679; hf]: pruned Nemotron; 32L, d_model 3072,
24H GQA kv=8, d_ff 9216, vocab 256000, squared-ReLU MLP, full attention
(=> long_500k skipped)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    rope_type="partial",
    rope_fraction=0.5,
    sub_quadratic=False,
    source="arXiv:2407.14679",
)
