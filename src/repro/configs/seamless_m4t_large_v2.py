"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf]: encoder-decoder, 24+24L,
d_model 1024, 16H (kv=16), d_ff 8192, vocab 256206 (padded to 256256 for
16-way vocab sharding).  The audio frontend is a STUB per spec:
input_specs() provides precomputed frame embeddings [B, frames, d_model]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_type="none",  # learned/convolutional positions in the real model; stubbed
    modality_stub="audio_frames",
    stub_frames=1024,
    sub_quadratic=False,
    source="arXiv:2308.11596",
)
