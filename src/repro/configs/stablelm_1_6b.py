"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b; unverified]: 24L,
d_model 2048, 32H (kv=32 => MHA), d_ff 5632 SwiGLU, vocab 100352, partial
rotary (25%), LayerNorm, full attention (=> long_500k skipped)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    mlp_type="swiglu",
    norm_type="layernorm",
    rope_type="partial",
    rope_fraction=0.25,
    sub_quadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b",
)
