"""xLSTM-1.3B [arXiv:2405.04517; unverified]: 48 blocks, d_model 2048,
4 heads, no separate FFN (d_ff=0; projections live inside the m/sLSTM
blocks), vocab 50304, xLSTM[7:1] (one sLSTM block per 8), recurrent =>
O(1)-state decode, long_500k runs."""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_type="none",
    xlstm=XLSTMConfig(slstm_every=8),
    sub_quadratic=True,
    source="arXiv:2405.04517",
)
