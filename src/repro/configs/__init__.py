from repro.configs.base import ArchConfig, MoEConfig, MambaConfig, XLSTMConfig, get_config, ARCH_IDS  # noqa: F401
