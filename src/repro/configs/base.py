"""Architecture config system: one frozen dataclass per assigned arch.

``get_config(arch_id)`` resolves the full published config;
``cfg.reduced()`` gives the same *family* at smoke-test scale (tiny widths,
few layers/experts) for the per-arch CPU smoke tests required by the spec.
Input shapes (train_4k / prefill_32k / decode_32k / long_500k) live in
launch/shapes.py.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # kimi: first layer(s) dense
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8       # xLSTM[7:1]: one sLSTM block per 8
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    chunk_size: int = 256
    qkv_blocksize: int = 4     # block-diagonal q/k/v (paper's qkv_proj_blocksize)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | audio | vlm | ssm
    num_layers: int              # decoder layers for enc-dec
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // num_heads
    mlp_type: str = "swiglu"     # swiglu | geglu | gelu | relu2
    norm_type: str = "rmsnorm"
    rope_type: str = "rope"      # rope | partial | mrope | none
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 => full attention
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    attn_every: int = 0          # hybrid: one attention layer per this many (jamba=8)
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder_layers: int = 0      # >0 => encoder-decoder
    modality_stub: str = ""      # '' | 'audio_frames' | 'image_patches'
    stub_frames: int = 1024      # encoder frame count for audio stub
    img_patches: int = 256       # image patch count for vlm stub
    sub_quadratic: bool = False  # eligible for long_500k
    source: str = ""
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self) -> "ArchConfig":
        """Same family, smoke-test scale."""
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4 if (self.attn_every or self.xlstm) else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            attn_every=2 if self.attn_every else 0,
            moe=None if self.moe is None else dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                first_k_dense=min(self.moe.first_k_dense, 1),
                capacity_factor=8.0),  # no drops at smoke scale => exact tests
            mamba=None if self.mamba is None else dataclasses.replace(
                self.mamba, d_state=8, d_conv=4, expand=2),
            xlstm=None if self.xlstm is None else dataclasses.replace(
                self.xlstm, slstm_every=2, chunk_size=16),
            encoder_layers=2 if self.encoder_layers else 0,
            stub_frames=32,
            img_patches=16,
        )


ARCH_IDS = [
    "gemma-2b", "starcoder2-7b", "minitron-4b", "stablelm-1.6b",
    "jamba-v0.1-52b", "seamless-m4t-large-v2", "mixtral-8x22b",
    "kimi-k2-1t-a32b", "qwen2-vl-72b", "xlstm-1.3b",
]

_MODULES = {
    "gemma-2b": "gemma_2b",
    "starcoder2-7b": "starcoder2_7b",
    "minitron-4b": "minitron_4b",
    "stablelm-1.6b": "stablelm_1_6b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mixtral-8x22b": "mixtral_8x22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG
