"""Minimal parameter/module substrate (no flax): Param-annotated pytrees.

Every parameter is a ``Param(value, axes)`` where ``axes`` names the logical
axis of each dim ('embed', 'ffn', 'q_heads', ...).  ``split_params`` peels the
annotations off into a parallel tree used by dist/rules.py to derive
NamedShardings; ``jax.eval_shape`` over an ``init`` gives the abstract
(ShapeDtypeStruct) tree the dry-run lowers against — no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple[str | None, ...]


@dataclasses.dataclass
class Param:
    value: Any  # jax.Array | ShapeDtypeStruct
    axes: Axes

    def __repr__(self) -> str:  # keep test output readable
        return f"Param({getattr(self.value, 'shape', ())}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, v: Param(v[0], axes),
)


def split_params(tree):
    """Param tree -> (values tree, axes tree) with identical structure."""
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, Param))
    values = jax.tree_util.tree_map(lambda p: p.value, tree,
                                    is_leaf=lambda x: isinstance(x, Param))
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree,
                                  is_leaf=lambda x: isinstance(x, Param))
    del leaves
    return values, axes


def merge_params(values, axes):
    return jax.tree_util.tree_map(lambda v, a: Param(v, a), values, axes,
                                  is_leaf=lambda x: x is None)


def add_leading_axis(tree, name: str = "layers"):
    """After vmap-stacking layer params, annotate the new leading dim."""
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, (name,) + tuple(p.axes)),
        tree, is_leaf=lambda x: isinstance(x, Param))


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(key, shape: tuple[int, ...], axes: Axes, dtype=jnp.float32,
               scale: float | None = None) -> Param:
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return Param(jax.random.normal(key, shape, dtype) * std, axes)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Param:
    return Param(jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5),
                 ("vocab", "embed"))


def ones_init(shape: tuple[int, ...], axes: Axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def zeros_init(shape: tuple[int, ...], axes: Axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


class KeyGen:
    """Deterministic per-path PRNG splitting."""

    def __init__(self, key):
        self.key = key
        self._n = 0

    def __call__(self):
        self._n += 1
        return jax.random.fold_in(self.key, self._n)


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def apply_norm(x, p: dict, norm_type: str):
    if norm_type == "layernorm":
        return layer_norm(x, p["gamma"].value, p["beta"].value)
    return rms_norm(x, p["gamma"].value)


def init_norm(norm_type: str, d: int, dtype=jnp.float32) -> dict:
    if norm_type == "layernorm":
        return {"gamma": ones_init((d,), (None,), dtype),
                "beta": zeros_init((d,), (None,), dtype)}
    return {"gamma": zeros_init((d,), (None,), dtype)}  # (1+gamma) rmsnorm
