"""Attention: GQA/MQA, RoPE/partial-RoPE/M-RoPE, sliding window, flash-blocked.

Memory discipline follows the paper's VSW insight applied to attention
(DESIGN.md §5): the KV cache is the resident "vertex array" (HBM, sharded);
the score matrix is never materialized — both training and decode stream KV
in blocks with running (max, denom, acc) statistics, which is also what the
Pallas flash kernel would do on real TPU.

GQA on a 16-way tensor-parallel mesh repeats KV heads up to the TP degree
when needed (MaxText-style; see DESIGN.md §5 — e.g. kv=8 -> 16).  Archs whose
q-head count doesn't divide the TP degree keep attention replicated (gemma,
starcoder2, minitron) and take TP on the MLP only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.nn import KeyGen, Param

NEG_INF = -1e30


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, fraction: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, fraction: float,
               theta: float, mrope_sections: tuple[int, ...] | None = None):
    """x: [B, S, H, hd]; positions: [B, S] or [B, S, 3] for M-RoPE."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    inv = rope_freqs(hd, fraction, theta)  # [rot/2]
    if mrope_sections is not None:
        # M-RoPE: split the rot/2 frequency slots into (t, h, w) sections,
        # each driven by its own position stream (arXiv:2409.12191 §3).
        secs = np.asarray(mrope_sections)
        assert secs.sum() == rot // 2, (secs, rot)
        sec_id = np.repeat(np.arange(3), secs)  # [rot/2] -> which pos stream
        pos = positions[..., sec_id]            # [B, S, rot/2]
        ang = pos.astype(jnp.float32) * inv
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, rot/2]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


def positions_for(cfg, batch: int, seq: int, offset=0) -> jnp.ndarray:
    pos = offset + jnp.arange(seq)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_type == "mrope":
        return jnp.broadcast_to(pos[..., None], (batch, seq, 3))  # text: t=h=w
    return pos


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attention(kg: KeyGen, cfg, dtype) -> dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": nn.dense_init(kg(), (d, H, hd), ("embed", "q_heads", "head_dim"), dtype),
        "wk": nn.dense_init(kg(), (d, K, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": nn.dense_init(kg(), (d, K, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": nn.dense_init(kg(), (H, hd, d), ("q_heads", "head_dim", "embed"), dtype),
    }


def init_cross_attention(kg: KeyGen, cfg, dtype) -> dict:
    return init_attention(kg, cfg, dtype)


def kv_repeat_for(cfg, ctx: ShardCtx) -> int:
    """Physical KV-head repetition so heads shard on the model axis."""
    H, K = cfg.num_heads, cfg.num_kv_heads
    tp = ctx.axis_size("q_heads")
    if tp <= 1 or H % tp != 0:
        return 1
    r = 1
    while (K * r) % tp != 0 and (K * r) < H:
        r *= 2
    return r if (K * r) % tp == 0 and H % (K * r) == 0 else 1


# --------------------------------------------------------------------------
# flash attention (blocked, pure JAX; numerics match naive softmax)
# --------------------------------------------------------------------------
def _block_attend(q, kblk, vblk, m, l, acc, qpos, kpos, *, causal, window, kv_len):
    """One KV block of the streaming softmax. q:[B,Sq,K,G,hd] kblk:[B,bk,K,hd]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgh,bjkh->bkgqj", q, kblk, preferred_element_type=jnp.float32)
    s = s * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)  # [Sq, bk] over (qpos, kpos)
    valid = (kpos[None, :] >= 0)
    if kv_len is not None:
        valid = valid & (kpos[None, :] < kv_len)
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    if window:
        valid = valid & (kpos[None, :] > qpos[:, None] - window)
    mask = mask & valid
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))            # [B,K,G,Sq]
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqj,bjkh->bqkgh", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_k", "unroll"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_len=None, kv_positions=None, block_k: int = 512,
                    unroll: bool = False):
    """q: [B, Sq, H, hd]; k, v: [B, Skv, Keff, hd] -> [B, Sq, H, hd].

    Streams KV in blocks; never materializes [Sq, Skv].  ``kv_len`` masks a
    padded cache (decode); ``q_offset`` is the absolute position of q[0];
    ``kv_positions`` [Skv] overrides slot positions (ring-buffer SWA caches,
    where slot order is not chronological; -1 marks empty slots).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    bk = min(block_k, Skv)
    nblk = (Skv + bk - 1) // bk
    pad = nblk * bk - Skv
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
        if kv_len is not None:
            kv_positions = jnp.where(kv_positions < kv_len, kv_positions, -1)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kb = k.reshape(B, nblk, bk, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, bk, K, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(nblk, bk)
    qpos = q_offset + jnp.arange(Sq)
    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, hd), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk
        m, l, acc = _block_attend(qg, kblk, vblk, m, l, acc, qpos, kpos,
                                  causal=causal, window=window, kv_len=None)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb),
                                  unroll=nblk if unroll else 1)
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------
def attention_apply(p: dict, x, positions, cfg, ctx: ShardCtx, *,
                    causal: bool = True, cache: dict | None = None,
                    cache_index=None, kv_seq_sharded: bool = False,
                    cross_kv: jnp.ndarray | None = None, unroll: bool = False):
    """Self- or cross-attention.

    train/prefill: cache is None (or a dict to fill at positions [0, S)).
    decode: x is [B, 1, d], cache holds [B, S_max, Keff, hd], cache_index is
    the write position (scalar).  Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = kv_repeat_for(cfg, ctx)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
    kv_src = cross_kv if cross_kv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].value)
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].value)
    if cfg.rope_type in ("rope", "partial", "mrope") and cross_kv is None:
        frac = cfg.rope_fraction if cfg.rope_type == "partial" else 1.0
        sections = None
        if cfg.rope_type == "mrope":
            base = hd // 2
            sections = (base - 2 * (base // 3), base // 3, base // 3)
        q = apply_rope(q, positions, fraction=frac, theta=cfg.rope_theta,
                       mrope_sections=sections)
        k = apply_rope(k, positions, fraction=frac, theta=cfg.rope_theta,
                       mrope_sections=sections)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = ctx.constrain(q, ("batch", "seq", "q_heads", "head_dim"))
    k = ctx.constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    window = cfg.sliding_window

    new_cache = cache
    if cache is not None and cache_index is not None and S == 1:
        # decode: write the new KV into the cache, attend over it.  SWA archs
        # use a ring buffer of size window with per-slot absolute positions.
        S_max = cache["k"].shape[1]
        ring = "pos" in cache
        slot = jax.lax.rem(cache_index, S_max) if ring else cache_index
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        if ring:
            pos = jax.lax.dynamic_update_slice(
                cache["pos"], cache_index[None].astype(cache["pos"].dtype), (slot,))
            new_cache["pos"] = pos
            out = flash_attention(q, k_cache, v_cache, causal=True, window=window,
                                  q_offset=cache_index, kv_positions=pos,
                                  unroll=unroll)
        elif kv_seq_sharded and ctx.enabled:
            out = flash_decode_sharded(q, k_cache, v_cache, cache_index + 1, ctx,
                                       q_offset=cache_index, window=window)
        else:
            out = flash_attention(q, k_cache, v_cache, causal=True, window=window,
                                  q_offset=cache_index, kv_len=cache_index + 1,
                                  unroll=unroll)
    else:
        out = flash_attention(q, k, v, causal=causal and cross_kv is None,
                              window=window, unroll=unroll)
        if cache is not None:  # prefill fill (keep the last S_max positions)
            S_max = cache["k"].shape[1]
            if S_max >= k.shape[1]:
                kpad = S_max - k.shape[1]
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0))),
                }
                kept = jnp.arange(S_max)
                pos0 = jnp.where(kept < k.shape[1], kept, -1)
            else:
                new_cache = {"k": k[:, -S_max:], "v": v[:, -S_max:]}
                pos0 = jnp.arange(k.shape[1] - S_max, k.shape[1])
            if "pos" in cache:
                new_cache["pos"] = pos0.astype(cache["pos"].dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].value)
    return ctx.constrain(y, ("batch", "seq", "embed")), new_cache


def flash_decode_sharded(q, k_cache, v_cache, kv_len, ctx: ShardCtx, *,
                         q_offset, window: int = 0):
    """Sequence-parallel decode (long_500k): the KV cache is sharded over the
    'data' axis on its sequence dim; each device computes partial flash stats
    over its KV slice and the softmax is combined with tiny collectives
    (max, then sum) — flash-decoding adapted to shard_map."""
    mesh = ctx.mesh
    axis = "data"
    P = jax.sharding.PartitionSpec

    def local(qb, kb, vb):
        Sl = kb.shape[1]
        me = jax.lax.axis_index(axis)
        base = me * Sl
        B, Sq, H, hd = qb.shape
        K = kb.shape[2]
        G = H // K
        qg = qb.reshape(B, Sq, K, G, hd)
        m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, Sq, K, G, hd), jnp.float32)
        kpos = base + jnp.arange(Sl)
        qpos = q_offset + jnp.arange(Sq)
        m, l, acc = _block_attend(qg, kb, vb, m0, l0, a0, qpos, kpos,
                                  causal=True, window=window, kv_len=kv_len)
        # combine partial softmax stats across the sequence shards
        m_all = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_all)
        l_all = jax.lax.psum(l * corr, axis)
        acc_all = jax.lax.psum(acc * corr.transpose(0, 3, 1, 2)[..., None], axis)
        out = acc_all / jnp.maximum(l_all, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(B, Sq, H, hd).astype(qb.dtype)

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache)
