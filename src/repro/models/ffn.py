"""Dense FFN variants: SwiGLU / GeGLU / GELU / squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.nn import KeyGen


def init_ffn(kg: KeyGen, d: int, d_ff: int, mlp_type: str, dtype) -> dict:
    p = {
        "w_up": nn.dense_init(kg(), (d, d_ff), ("embed", "ffn"), dtype),
        "w_down": nn.dense_init(kg(), (d_ff, d), ("ffn", "embed"), dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = nn.dense_init(kg(), (d, d_ff), ("embed", "ffn"), dtype)
    return p


def _act(h, mlp_type: str):
    if mlp_type == "gelu":
        return jax.nn.gelu(h)
    if mlp_type == "relu2":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(mlp_type)


def ffn_apply(p: dict, x, mlp_type: str, ctx: ShardCtx):
    if mlp_type in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].value)
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"].value)
        gate = jax.nn.silu(gate) if mlp_type == "swiglu" else jax.nn.gelu(gate)
        h = gate * up
    else:
        h = _act(jnp.einsum("bsd,df->bsf", x, p["w_up"].value), mlp_type)
    h = ctx.constrain(h, ("batch", "seq", "ffn"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].value)
    return ctx.constrain(y, ("batch", "seq", "embed"))
