"""Mamba (selective SSM) block for the Jamba hybrid — chunked-parallel form.

Training/prefill uses a chunked linear-recurrence: the sequence is split into
chunks; within a chunk the recurrence h_t = dA_t ⊙ h_{t-1} + dB_t x_t is
solved with an associative scan (parallel, unrollable for the roofline delta
method); chunk boundary states are carried by an outer lax.scan.  Decode is
the O(1) recurrent step on carried (conv_state, ssm_state).

On real TPU the inner scan would be a Pallas kernel (the SSD/mamba-2 style
block); the chunked structure here is exactly the tiling that kernel uses,
so the roofline terms are representative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig
from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.nn import KeyGen


def d_inner_of(d_model: int, mc: MambaConfig) -> int:
    return mc.expand * d_model


def dt_rank_of(d_model: int, mc: MambaConfig) -> int:
    return mc.dt_rank or -(-d_model // 16)


def init_mamba(kg: KeyGen, d: int, mc: MambaConfig, dtype) -> dict:
    di = d_inner_of(d, mc)
    dtr = dt_rank_of(d, mc)
    N = mc.d_state
    return {
        "in_proj": nn.dense_init(kg(), (d, 2 * di), ("embed", "mamba_inner"), dtype),
        "conv_w": nn.dense_init(kg(), (mc.d_conv, di), (None, "mamba_inner"), dtype, scale=0.5),
        "conv_b": nn.zeros_init((di,), ("mamba_inner",), dtype),
        "x_proj": nn.dense_init(kg(), (di, dtr + 2 * N), ("mamba_inner", None), dtype),
        "dt_proj": nn.dense_init(kg(), (dtr, di), (None, "mamba_inner"), dtype),
        "dt_bias": nn.zeros_init((di,), ("mamba_inner",), dtype),
        "A_log": nn.Param(
            jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
            ("mamba_inner", "state")),
        "D": nn.ones_init((di,), ("mamba_inner",), dtype),
        "out_proj": nn.dense_init(kg(), (di, d), ("mamba_inner", "embed"), dtype),
    }


def _ssm_scan_chunked(dA, dBx, Cs, h0, chunk: int, unroll: bool):
    """y_t = C_t · h_t with h_t = dA_t ⊙ h_{t-1} + dBx_t.

    The [B, S, di, N] state sequence is never materialized across the whole
    sequence — only within one chunk (the VSW memory discipline again: tiny
    resident state, streamed long axis).  Returns (y [B,S,di], h_last).
    """
    B, S, di, N = dA.shape
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:  # identity steps: dA=1, dBx=0 leave the state unchanged
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
    dA_c = dA.reshape(B, nc, Q, di, N).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(B, nc, Q, di, N).transpose(1, 0, 2, 3, 4)
    Cs_c = Cs.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)

    def chunk_body(h, blk):
        a, bx, c = blk  # [B, Q, di, N], [B, Q, N]
        # prefix products within the chunk via associative scan (parallel)
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])
        pa, pb = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h_all = pa * h[:, None] + pb        # [B, Q, di, N] (chunk transient)
        y = jnp.einsum("bqin,bqn->bqi", h_all, c)
        return h_all[:, -1], y

    h_last, y_chunks = jax.lax.scan(chunk_body, h0, (dA_c, dBx_c, Cs_c),
                                    unroll=nc if unroll else 1)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(B, nc * Q, di)[:, :S]
    return y, h_last


def mamba_apply(p: dict, x, mc: MambaConfig, ctx: ShardCtx, *,
                state: dict | None = None, unroll: bool = False,
                chunk: int = 256, scan_dtype: str = "float32"):
    """x: [B, S, d] -> (y, new_state).  state carries (conv, ssm) for decode.

    ``scan_dtype='bfloat16'`` keeps the big [B,S,di,N] discretization tensors
    in bf16 (halving the dominant HBM traffic — §Perf); the recurrence carry
    stays f32 for stability, validated by tests/test_perf_variants.py."""
    B, S, d = x.shape
    di = p["D"].value.shape[0]
    N = p["A_log"].value.shape[1]
    dc = p["conv_w"].value.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].value)
    xr, z = jnp.split(xz, 2, axis=-1)
    xr = ctx.constrain(xr, ("batch", "seq", "mamba_inner"))

    # causal depthwise conv over the sequence
    if state is not None and S == 1:
        conv_in = jnp.concatenate([state["conv"], xr], axis=1)  # [B, dc, di]
        new_conv = conv_in[:, 1:]
        # same op order as the S>1 path => bit-identical in bf16
        xc = sum(conv_in[:, i : i + 1] * p["conv_w"].value[i] for i in range(dc))
    else:
        pad = jnp.zeros((B, dc - 1, di), xr.dtype) if state is None else state["conv"]
        conv_in = jnp.concatenate([pad, xr], axis=1)
        new_conv = conv_in[:, -(dc - 1):]
        xc = sum(
            conv_in[:, i : i + S] * p["conv_w"].value[i]
            for i in range(dc)
        )
    xc = jax.nn.silu(xc + p["conv_b"].value)

    dtr = p["dt_proj"].value.shape[0]
    xdb = jnp.einsum("bsi,ie->bse", xc, p["x_proj"].value)
    dt, Bs, Cs = jnp.split(xdb, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"].value)
                         + p["dt_bias"].value)
    A = -jnp.exp(p["A_log"].value.astype(jnp.float32))  # [di, N]
    sdt = jnp.bfloat16 if scan_dtype == "bfloat16" else jnp.float32
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A).astype(sdt)     # [B,S,di,N]
    dBx = ((dt * xc)[..., None].astype(jnp.float32)
           * Bs[:, :, None, :].astype(jnp.float32)).astype(sdt)

    h0 = state["ssm"] if state is not None else jnp.zeros((B, di, N), jnp.float32)
    if S == 1:
        h_last = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bin,bn->bi", h_last, Cs[:, 0].astype(jnp.float32))[:, None]
    else:
        y, h_last = _ssm_scan_chunked(dA, dBx, Cs.astype(jnp.float32), h0, chunk, unroll)
    y = y.astype(x.dtype) + xc * p["D"].value
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].value)
    new_state = {"conv": new_conv, "ssm": h_last}
    return ctx.constrain(out, ("batch", "seq", "embed")), new_state


def init_mamba_state(cfg, batch: int, dtype) -> dict:
    mc = cfg.mamba
    di = d_inner_of(cfg.d_model, mc)
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }
